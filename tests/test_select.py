"""Predicate pushdown (DESIGN.md §16): property + fault-injection tests.

The single invariant everything here checks: ``select(where)`` is
byte-identical to decoding EVERYTHING and filtering with numpy — across
random dtypes / shapes / chunk sizes / codecs / predicates, on all-pruned
and none-pruned extremes, NaN-laden floats, rows straddling chunk
boundaries, local directories and a loopback byte-range server. Stats
that are missing, corrupt, truncated, or from an unknown version may cost
the pruning, never the answer.
"""

import os
import subprocess
import sys
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as ra
from repro.core import codec as chunked_codec
from repro.core import col
from repro.core.racat import main as racat
from repro.core.stats import ChunkStats, split_stats
from repro.data import DataLoader, DatasetBuilder, RaDataset


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def _build(root, t, x, *, chunk_bytes=512, shard_rows=64, chunked=True,
           stats=None, codec=None):
    b = DatasetBuilder(
        str(root),
        {"t": ((), str(t.dtype)), "x": (x.shape[1:], str(x.dtype))},
        shard_rows=shard_rows, chunked=chunked, chunk_bytes=chunk_bytes,
        codec=codec, stats=stats,
    )
    b.append(t=t, x=x)
    b.finish()
    return str(root)


def _ref(where, data, fields):
    """Full-scan numpy reference: decode everything, mask, slice."""
    mask = where.mask(data)
    return {f: data[f][mask] for f in fields}


def _check(ds, where, data, fields=("t", "x")):
    got = ds.select(where=where, fields=list(fields))
    want = _ref(where, data, fields)
    for f in fields:
        assert got[f].dtype == want[f].dtype, f
        assert got[f].shape == want[f].shape, f
        assert got[f].tobytes() == want[f].tobytes(), f
    idx = ds.select_indices(where)
    assert np.array_equal(idx, np.nonzero(where.mask(data))[0])


# ------------------------------------------------------------ property suite
@settings(max_examples=12, deadline=None)
@given(
    dtype=st.sampled_from(["int16", "int32", "int64", "uint8", "float32", "float64"]),
    nrows=st.integers(min_value=1, max_value=257),
    width=st.integers(min_value=1, max_value=5),
    chunk_bytes=st.sampled_from([96, 256, 1024]),
    opi=st.integers(min_value=0, max_value=5),
    thresh=st.integers(min_value=-2, max_value=9),
    shard_rows=st.sampled_from([48, 300]),
)
def test_select_matches_numpy_filter(tmp_path, dtype, nrows, width,
                                     chunk_bytes, opi, thresh, shard_rows):
    rng = np.random.default_rng(nrows * 1000 + chunk_bytes + opi)
    dt = np.dtype(dtype)
    t = rng.integers(0, 8, size=nrows).astype(dt)
    x = rng.integers(0, 8, size=(nrows, width)).astype(dt)
    if dt.kind == "f":  # sprinkle NaNs into both the key and the payload
        t[rng.random(nrows) < 0.2] = np.nan
        x[rng.random((nrows, width)) < 0.2] = np.nan
    root = _build(tmp_path / "ds", t, x, chunk_bytes=chunk_bytes,
                  shard_rows=shard_rows)
    ds = RaDataset(root)
    data = ds.rows(0, nrows)
    c = col("t")
    ops = [c == thresh, c != thresh, c < thresh, c <= thresh,
           c > thresh, c >= thresh]
    _check(ds, ops[opi], data)
    # vector-field predicate: row-true iff ALL elements satisfy it
    _check(ds, col("x") >= thresh, data)
    # compound forms
    _check(ds, (c >= 2) & (c < 6), data)
    _check(ds, (c == 0) | ~(col("x") < 7), data)


def test_all_pruned_and_none_pruned(tmp_path, rng):
    t = np.arange(300, dtype=np.int64)
    x = rng.normal(size=(300, 4)).astype(np.float32)
    ds = RaDataset(_build(tmp_path / "ds", t, x, chunk_bytes=256))
    data = ds.rows(0, 300)

    # all-pruned: zero payload reads, empty-but-typed result
    chunked_codec.reset_stats()
    got = ds.select(where=col("t") > 10_000, fields=["t", "x"])
    assert got["t"].shape == (0,) and got["t"].dtype == np.int64
    assert got["x"].shape == (0, 4) and got["x"].dtype == np.float32
    assert chunked_codec.stats()["chunk_reads"] == 0

    # none-pruned (take-all): full result, no predicate-field re-decode
    _check(ds, col("t") >= 0, data)

    # partial window: fewer payload bytes than the full scan
    chunked_codec.reset_stats()
    _check(ds, (col("t") >= 100) & (col("t") < 110), data)
    part = chunked_codec.stats()["chunk_stored_bytes"]
    chunked_codec.reset_stats()
    ds.rows(0, 300)
    full = chunked_codec.stats()["chunk_stored_bytes"]
    assert 0 < part < full


def test_nan_semantics(tmp_path):
    t = np.array([1.0, np.nan, 3.0, np.nan, 5.0], dtype=np.float64)
    x = np.arange(10, dtype=np.float32).reshape(5, 2)
    ds = RaDataset(_build(tmp_path / "ds", t, x, chunk_bytes=64))
    data = ds.rows(0, 5)
    for where in [col("t") == 3.0, col("t") != 3.0, col("t") < 4.0,
                  col("t") >= 1.0, col("t").isnan(), ~col("t").isnan()]:
        _check(ds, where, data)
    # NaN fails everything except != (IEEE-754)
    assert list(ds.select_indices(col("t") != 3.0)) == [0, 1, 3, 4]
    assert list(ds.select_indices(col("t") < 4.0)) == [0, 2]
    assert list(ds.select_indices(col("t").isnan())) == [1, 3]


def test_chunk_boundary_straddling_rows(tmp_path, rng):
    # 12-byte rows vs 64-byte chunks: every ~5th row straddles a boundary
    t = np.repeat(np.arange(40, dtype=np.int32), 3).reshape(40, 3)
    b = DatasetBuilder(str(tmp_path / "ds"), {"t": ((3,), "int32")},
                       shard_rows=1000, chunked=True, chunk_bytes=64)
    b.append(t=t)
    b.finish()
    ds = RaDataset(str(tmp_path / "ds"))
    data = ds.rows(0, 40)
    for k in (0, 5, 21, 39):
        _check(ds, col("t") == k, data, fields=("t",))
        _check(ds, (col("t") >= k) & (col("t") < k + 3), data, fields=("t",))


def test_select_over_loopback_server(tmp_path, rng):
    from repro import remote

    t = np.arange(500, dtype=np.int64)
    x = rng.normal(size=(500, 8)).astype(np.float32)
    _build(tmp_path / "ds", t, x, chunk_bytes=1024, shard_rows=128)
    server = remote.serve(str(tmp_path), port=0)
    try:
        ds = RaDataset(f"{server.url}/ds")
        local = RaDataset(str(tmp_path / "ds"))
        data = local.rows(0, 500)
        where = (col("t") >= 100) & (col("t") < 140)
        got = ds.select(where=where, fields=["t", "x"])
        want = local.select(where=where, fields=["t", "x"])
        for f in ("t", "x"):
            assert got[f].tobytes() == want[f].tobytes()
        assert np.array_equal(ds.select_indices(where),
                              local.select_indices(where))
        # stats resolve via ranged tail reads — remote matches local blocks
        for si in range(len(ds.shards)):
            r, l = ds.field_stats(si, "t"), local.field_stats(si, "t")
            assert r is not None and r.encode() == l.encode()
    finally:
        server.shutdown()
        server.server_close()


def test_dataloader_where(tmp_path, rng):
    t = np.arange(400, dtype=np.int64)
    x = rng.normal(size=(400, 4)).astype(np.float32)
    ds = RaDataset(_build(tmp_path / "ds", t, x, chunk_bytes=512))
    where = (col("t") >= 50) & (col("t") < 114)
    dl = DataLoader(ds, batch_size=16, where=where, shuffle=False)
    try:
        assert dl.steps_per_epoch() == 4  # 64 matching rows / 16
        seen = []
        for _ in range(dl.steps_per_epoch()):
            batch = next(dl)
            assert batch["t"].shape[0] == 16
            seen.append(batch["t"].copy())
    finally:
        dl.stop()
    got = np.concatenate(seen)
    assert np.array_equal(got, np.arange(50, 114))
    # shuffled epochs permute exactly the matching row set
    dl = DataLoader(ds, batch_size=16, where=where, shuffle=True, seed=7)
    try:
        seen = [next(dl)["t"].copy() for _ in range(dl.steps_per_epoch())]
    finally:
        dl.stop()
    assert sorted(np.concatenate(seen).tolist()) == list(range(50, 114))
    with pytest.raises(ValueError):
        DataLoader(ds, batch_size=16, where=where, naive=True)


# ------------------------------------------------------- fault injection
def _smash_stats(path, mutate):
    """Apply ``mutate(blob, i)`` at the rastats magic offset and rewrite."""
    blob = bytearray(open(path, "rb").read())
    i = blob.find(b"rastats_")
    assert i >= 0, "fixture should carry a stats block"
    mutate(blob, i)
    open(path, "wb").write(bytes(blob))


def _shard_path(root, field="t"):
    ds = RaDataset(str(root))
    return os.path.join(str(root), ds.shards[0].files[field])


def test_corrupt_stats_degrade_to_full_scan(tmp_path, rng):
    t = np.arange(200, dtype=np.int64)
    x = rng.normal(size=(200, 2)).astype(np.float32)
    root = _build(tmp_path / "ds", t, x, chunk_bytes=256, shard_rows=1000)

    def smash(blob, i):  # impossible geometry: block_bytes <- 0xff..
        blob[i + 16:i + 24] = b"\xff" * 8

    _smash_stats(_shard_path(root), smash)
    ds = RaDataset(root)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = ds.select(where=(col("t") >= 20) & (col("t") < 30), fields=["t"])
    assert np.array_equal(got["t"], np.arange(20, 30))
    assert any("rastats" in str(w.message) for w in rec)


def test_unknown_version_stats_degrade_to_full_scan(tmp_path, rng):
    t = np.arange(200, dtype=np.int64)
    x = rng.normal(size=(200, 2)).astype(np.float32)
    root = _build(tmp_path / "ds", t, x, chunk_bytes=256, shard_rows=1000)

    def smash(blob, i):  # version <- 99: framing sound, rules unknown
        blob[i + 8:i + 16] = (99).to_bytes(8, "little")

    _smash_stats(_shard_path(root), smash)
    ds = RaDataset(root)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = ds.select(where=col("t") == 7, fields=["t", "x"])
    assert np.array_equal(got["t"], np.array([7]))
    assert any("unknown version" in str(w.message) for w in rec)
    # the user metadata behind the unknown-version block still decodes
    # (quant JSON etc. live there), so readers are not locked out
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        assert ds.rows(0, 5)["t"].tolist() == [0, 1, 2, 3, 4]


def test_stale_stats_caught_by_verify(tmp_path):
    # same-geometry payload rewrite: select trusts the block (the file-level
    # CRC / ETag is the rewrite tripwire), racat verify recomputes and fails
    p = tmp_path / "x.ra"
    ra.write(str(p), np.arange(100, dtype=np.int32), stats=True, crc32=True)
    hdr = ra.header_of(str(p))
    with open(p, "r+b") as f:
        f.seek(hdr.nbytes)
        f.write((77777).to_bytes(4, "little"))
    rc = racat(["verify", str(p)])
    assert rc == 1  # CRC mismatch AND stats mismatch both fire


def test_truncated_stats_block(tmp_path):
    p = tmp_path / "x.ra"
    ra.write(str(p), np.arange(64, dtype=np.float32), stats=True)
    blob = open(p, "rb").read()
    i = blob.find(b"rastats_")
    # keep the head, drop the per-window arrays
    open(p, "wb").write(blob[:i + 40])
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        st_ = ra.read_stats(str(p))
    assert st_ is None
    assert any("rastats" in str(w.message) for w in rec)


# ---------------------------------------------------------------- backfill
def test_pre_stats_files_full_scan_and_verify_green(tmp_path, rng):
    # files written with stats off (== every pre-PR-9 file byte-for-byte)
    t = np.arange(150, dtype=np.int64)
    x = rng.normal(size=(150, 3)).astype(np.float32)
    root = _build(tmp_path / "ds", t, x, stats=False, chunk_bytes=256)
    ds = RaDataset(root)
    assert ds.field_stats(0, "t") is None
    data = ds.rows(0, 150)
    _check(ds, (col("t") >= 10) & (col("t") < 30), data)
    for sh in ds.shards:
        for f in sh.files.values():
            assert racat(["verify", os.path.join(root, f)]) == 0
    # and old-style plain/chunked/crc files verify green too
    for i, kw in enumerate([dict(), dict(crc32=True),
                            dict(chunked=True, chunk_bytes=128, crc32=True)]):
        p = tmp_path / f"old{i}.ra"
        ra.write(str(p), x, **kw)
        assert racat(["verify", str(p)]) == 0
        assert ra.read_stats(str(p)) is None


def test_metadata_roundtrip_with_stats(tmp_path):
    # user metadata survives the prepended stats block on every read path
    p = tmp_path / "m.ra"
    meta = b'{"captured": "live"}'
    ra.write(str(p), np.arange(32, dtype=np.int16), stats=True,
             metadata=meta, chunked=True, chunk_bytes=32, crc32=True)
    arr, back = ra.read(str(p), with_metadata=True)
    assert back == meta and np.array_equal(arr, np.arange(32, dtype=np.int16))
    assert ra.read_metadata(str(p)) == meta
    st_ = ra.read_stats(str(p))
    assert st_ is not None and st_.nchunks == 2


# ------------------------------------------------------------------ racat CLI
def _run_racat(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.core.racat", *args],
        capture_output=True, text=True, env=env)


def test_racat_stats_cli(tmp_path):
    p = tmp_path / "x.ra"
    arr = np.arange(1000, dtype=np.float32)
    arr[3] = np.nan
    ra.write(str(p), arr, stats=True, chunked=True, chunk_bytes=1024)
    r = _run_racat("stats", str(p))
    assert r.returncode == 0
    assert "nchunks      4" in r.stdout and "chunk_bytes  1024" in r.stdout
    # window 0 carries the NaN
    line0 = [l for l in r.stdout.splitlines() if l.strip().startswith("0 ")][0]
    assert " 1 " in " ".join(line0.split())
    # no-stats file: exit 1, explanatory message
    q = tmp_path / "old.ra"
    ra.write(str(q), arr)
    r = _run_racat("stats", str(q))
    assert r.returncode == 1 and "no rastats" in r.stderr
    # inspect shows the stats line
    r = _run_racat("inspect", str(p))
    assert r.returncode == 0 and "stats        4 windows" in r.stdout
    r = _run_racat("inspect", str(q))
    assert r.returncode == 0 and "stats        none" in r.stdout


def test_racat_verify_stats_mismatch_cli(tmp_path):
    p = tmp_path / "x.ra"
    ra.write(str(p), np.arange(100, dtype=np.int32), stats=True)
    assert racat(["verify", str(p)]) == 0
    hdr = ra.header_of(str(p))
    with open(p, "r+b") as f:
        f.seek(hdr.nbytes)
        f.write((424242).to_bytes(4, "little"))
    r = _run_racat("verify", str(p))
    assert r.returncode == 1 and "rastats" in r.stderr and "stale" in r.stderr


# --------------------------------------------------------------- unit corners
def test_expr_bool_raises():
    with pytest.raises(TypeError):
        bool(col("t") == 1)
    with pytest.raises(TypeError):
        (col("t") == 1) and (col("t") == 2)


def test_unknown_field_raises(tmp_path, rng):
    t = np.arange(10, dtype=np.int64)
    x = rng.normal(size=(10, 2)).astype(np.float32)
    ds = RaDataset(_build(tmp_path / "ds", t, x))
    with pytest.raises(ra.RawArrayError):
        ds.select(where=col("nope") == 1)


def test_split_stats_passthrough():
    # no magic: plain user metadata passes through untouched
    st_, rest = split_stats(b'{"k": 1}')
    assert st_ is None and rest == b'{"k": 1}'
    st_, rest = split_stats(b"")
    assert st_ is None and rest == b""


def test_stats_roundtrip_and_exactness():
    big = (1 << 53) + 1  # not f64-representable: bounds must round outward
    arr = np.array([0, big, 5], dtype=np.int64)
    st_ = ra.compute_stats(arr, 1024)
    blob = st_.encode()
    back = ChunkStats.decode(blob)
    assert back.encode() == blob
    assert back.mins[0] <= 0 and back.maxs[0] >= big
    # a pruning decision near the inexact value stays conservative
    info = {"t": (back, 8)}
    dt, df = (col("t") == big).row_verdicts(3, info)
    assert not dt.any()  # inexact bound: never proved equal
    assert not df.any()  # ...and never pruned away
