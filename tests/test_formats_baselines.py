"""Baseline format implementations (png / hdf5min / nrrd)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.formats import hdf5min, nrrd, png


# ------------------------------------------------------------------- png
@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 40),
    w=st.integers(1, 40),
    rgb=st.booleans(),
    level=st.sampled_from([0, 1, 6]),
)
def test_png_roundtrip_property(h, w, rgb, level):
    rng = np.random.default_rng(h * 41 + w)
    img = rng.integers(0, 256, (h, w, 3) if rgb else (h, w), dtype=np.uint8)
    assert np.array_equal(png.decode(png.encode(img, level=level)), img)


@pytest.mark.parametrize("filt", [1, 2, 3, 4])
def test_png_decode_all_filters(filt):
    """Our encoder emits filter 0; the decoder must handle 1-4 (real files)."""
    import struct
    import zlib

    rng = np.random.default_rng(filt)
    img = rng.integers(0, 256, (9, 13), dtype=np.uint8)
    h, w = img.shape
    raw = bytearray()
    prev = np.zeros(w, np.int16)
    for y in range(h):
        raw.append(filt)
        row = img[y].astype(np.int16)
        if filt == 1:
            enc = row.copy()
            enc[1:] -= row[:-1]
        elif filt == 2:
            enc = row - prev
        elif filt == 3:
            left = np.concatenate([[0], row[:-1]])
            enc = row - ((left + prev) // 2)
        else:  # paeth
            enc = np.empty_like(row)
            for x in range(w):
                a = int(row[x - 1]) if x else 0
                b = int(prev[x])
                c = int(prev[x - 1]) if x else 0
                pp = a + b - c
                pa, pb, pc = abs(pp - a), abs(pp - b), abs(pp - c)
                pred = a if (pa <= pb and pa <= pc) else (b if pb <= pc else c)
                enc[x] = row[x] - pred
        raw += (enc % 256).astype(np.uint8).tobytes()
        prev = row

    def chunk(tag, payload):
        return (
            struct.pack(">I", len(payload)) + tag + payload
            + struct.pack(">I", zlib.crc32(tag + payload) & 0xFFFFFFFF)
        )

    data = (
        b"\x89PNG\r\n\x1a\n"
        + chunk(b"IHDR", struct.pack(">IIBBBBB", w, h, 8, 0, 0, 0, 0))
        + chunk(b"IDAT", zlib.compress(bytes(raw)))
        + chunk(b"IEND", b"")
    )
    assert np.array_equal(png.decode(data), img)


# ------------------------------------------------------------------- hdf5
@pytest.mark.parametrize("dtype", ["int8", "uint16", "int32", "int64", "float32", "float64"])
def test_hdf5_dtype_roundtrip(tmp_path, dtype):
    arr = (np.arange(24) - 12).astype(dtype).reshape(2, 3, 4)
    p = str(tmp_path / "x.h5")
    hdf5min.write(p, arr)
    assert np.array_equal(hdf5min.read(p), arr)


def test_hdf5_signature_and_many_datasets(tmp_path):
    p = str(tmp_path / "m.h5")
    arrs = {f"ds{i:03d}": np.full((5,), i, np.float32) for i in range(50)}
    hdf5min.write_datasets(p, arrs)
    assert open(p, "rb").read(8) == b"\x89HDF\r\n\x1a\n"
    f = hdf5min.H5MinFile(p)
    assert set(f.names) == set(arrs)
    for n, a in arrs.items():
        assert np.array_equal(f.read(n), a)


def test_hdf5_incremental_equivalent(tmp_path):
    arrs = {f"d{i}": np.random.default_rng(i).normal(size=(7,)).astype(np.float32) for i in range(9)}
    p1, p2 = str(tmp_path / "a.h5"), str(tmp_path / "b.h5")
    hdf5min.write_datasets(p1, arrs)
    hdf5min.write_datasets_incremental(p2, arrs)
    f1, f2 = hdf5min.H5MinFile(p1), hdf5min.H5MinFile(p2)
    for n in arrs:
        assert np.array_equal(f1.read(n), f2.read(n))


# ------------------------------------------------------------------- nrrd
@settings(max_examples=15, deadline=None)
@given(
    dtype=st.sampled_from(["uint8", "int16", "int32", "float32", "float64"]),
    shape=st.lists(st.integers(1, 6), min_size=1, max_size=4),
)
def test_nrrd_roundtrip_property(tmp_path_factory, dtype, shape):
    d = tmp_path_factory.mktemp("nrrd")
    arr = np.arange(int(np.prod(shape)), dtype=dtype).reshape(shape)
    p = str(d / "x.nrrd")
    nrrd.write(p, arr)
    back = nrrd.read(p)
    assert back.shape == arr.shape and np.array_equal(back, arr)
