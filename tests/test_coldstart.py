"""Cold-start restore pipeline (DESIGN.md §13): ``restore_pipelined`` must
agree bit-exactly with ``restore_naive`` over every storage variant and
transport, respect the in-flight byte budget, pin the checkpoint's version
set at restore start (and fail FAST — never a silently mixed checkpoint —
when that set changes mid-restore, the server dies, or auth is denied).

Like test_remote.py, everything runs against a real loopback HTTP server —
no mocks; the conftest per-test SIGALRM timeout turns any hang into a
failure."""

import http.server as _http_server
import json
import os
import threading
import urllib.request

import numpy as np
import pytest

import repro.core as ra
from repro import remote
from repro.checkpoint import (
    ColdStartStats,
    restore_naive,
    restore_pipelined,
    restore_resharded,
    save_checkpoint,
    shardings_from_specs,
)


@pytest.fixture()
def served(tmp_path):
    server = remote.serve(str(tmp_path), port=0)
    try:
        yield str(tmp_path), server.url
    finally:
        server.shutdown()
        server.server_close()
        remote.close_readers()
        remote.reset_shared_cache()


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((96, 64)).astype(np.float32),
        "inner": {
            "b": rng.standard_normal((64,)).astype(np.float32),
            "k": rng.standard_normal((32, 48)).astype(np.float32),
        },
    }


def _like(tree):
    import jax

    return jax.tree_util.tree_map(lambda x: np.empty(x.shape, x.dtype), tree)


def _cold():
    remote.close_readers()
    remote.reset_shared_cache()


def _assert_trees_equal(a, b):
    import jax

    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        nx, ny = np.asarray(x), np.asarray(y)
        assert nx.dtype == ny.dtype
        np.testing.assert_array_equal(nx, ny)


# --------------------------------------------------- pipelined ≡ naive
@pytest.mark.parametrize("kw", [{}, {"chunked": True}, {"chunked": True, "quantize": "u8"}])
def test_pipelined_matches_naive_local(tmp_path, kw):
    tree = _tree()
    p = save_checkpoint(str(tmp_path), 1, tree, **kw)
    _cold()
    naive, _, _ = restore_naive(p, _like(tree))
    _cold()
    pipe, _, _ = restore_pipelined(p, _like(tree))
    _assert_trees_equal(pipe, naive)
    import jax

    for leaf in jax.tree_util.tree_leaves(pipe):
        assert isinstance(leaf, jax.Array)  # device-resident, not numpy


def test_pipelined_matches_naive_url_chunked_quant(served):
    root, base = served
    tree = _tree(1)
    p = save_checkpoint(root, 1, tree, chunked=True, quantize="u8")
    url = f"{base}/{os.path.basename(p)}"
    _cold()
    naive, _, _ = restore_naive(url, _like(tree))
    _cold()
    st = ColdStartStats()
    pipe, _, _ = restore_pipelined(url, _like(tree), stats=st)
    _assert_trees_equal(pipe, naive)
    assert st.leaves == 3
    assert st.restore_s > 0


def test_pipelined_restores_opt_state_too(tmp_path):
    tree = _tree(2)
    opt = {"m": np.zeros((96, 64), np.float32), "v": np.ones((96, 64), np.float32)}
    p = save_checkpoint(str(tmp_path), 3, tree, opt_state=opt, chunked=True,
                        extra={"step": 3})
    got_p, got_o, extra = restore_pipelined(p, _like(tree), _like(opt))
    _assert_trees_equal(got_p, tree)
    _assert_trees_equal(got_o, opt)
    assert extra["step"] == 3


def test_shape_mismatch_raises(tmp_path):
    tree = _tree()
    p = save_checkpoint(str(tmp_path), 1, tree)
    bad = _like(tree)
    bad["w"] = np.empty((8, 8), np.float32)
    with pytest.raises(ValueError, match="checkpoint"):
        restore_pipelined(p, bad)


# -------------------------------------------------- resharded onto a mesh
@pytest.mark.parametrize("transport", ["local", "url"])
def test_resharded_restore_onto_mesh(served, transport):
    import jax
    from jax.sharding import Mesh, PartitionSpec

    root, base = served
    tree = _tree(3)
    p = save_checkpoint(root, 1, tree, chunked=True, quantize="u8")
    path = p if transport == "local" else f"{base}/{os.path.basename(p)}"

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    specs = {"w": PartitionSpec("data", None), "inner": {"b": None, "k": None}}
    sh = shardings_from_specs(mesh, specs)

    # naive with the SAME shardings: sharded quantized leaves dequantize
    # host-side in both paths, so bit-exactness is by construction
    _cold()
    naive, _, _ = restore_naive(path, _like(tree), shardings=sh)
    _cold()
    pipe, _, _ = restore_pipelined(path, _like(tree), shardings=sh)
    _assert_trees_equal(pipe, naive)
    assert pipe["w"].sharding.mesh == mesh


def test_restore_resharded_rows_dequantize(tmp_path):
    tree = _tree(4)
    p = save_checkpoint(str(tmp_path), 1, tree, chunked=True, quantize="u8")
    # host-side dequant reference (restore_resharded dequantizes host-side)
    ref = np.asarray(ra.read(os.path.join(p, "param__w.ra"), dequantize=True))
    rows = restore_resharded(p, "param__w", row_start=16, row_stop=48, dequantize=True)
    np.testing.assert_array_equal(rows, ref[16:48])


# ------------------------------------------------------- in-flight budget
def test_inflight_cap_bounds_peak(tmp_path):
    tree = {f"l{i}": np.random.default_rng(i).standard_normal((128, 128)).astype(np.float32)
            for i in range(6)}  # 6 × 64 KiB
    p = save_checkpoint(str(tmp_path), 1, tree)
    leaf = 128 * 128 * 4
    cap = leaf + leaf // 2  # > largest single leaf, < 2 leaves — forces queuing
    st = ColdStartStats()
    got, _, _ = restore_pipelined(p, _like(tree), inflight_bytes=cap, stats=st)
    _assert_trees_equal(got, tree)
    assert 0 < st.peak_inflight_bytes <= cap
    assert st.inflight_cap == cap
    # uncapped: the whole wave may be resident at once
    st2 = ColdStartStats()
    restore_pipelined(p, _like(tree), stats=st2)
    assert st2.peak_inflight_bytes >= st.peak_inflight_bytes


def test_oversized_leaf_admitted_alone(tmp_path):
    """A cap smaller than the largest leaf must bound CONCURRENCY (that
    leaf streams alone), never deadlock the scheduler."""
    tree = _tree(5)
    p = save_checkpoint(str(tmp_path), 1, tree)
    largest = max(x.nbytes for x in [tree["w"], tree["inner"]["b"], tree["inner"]["k"]])
    st = ColdStartStats()
    got, _, _ = restore_pipelined(p, _like(tree), inflight_bytes=largest // 4, stats=st)
    _assert_trees_equal(got, tree)
    assert st.peak_inflight_bytes <= largest


# ------------------------------------------------ version pins: fail fast
def test_local_overwrite_mid_restore_fails_fast(tmp_path):
    tree = _tree(6)
    p = save_checkpoint(str(tmp_path), 1, tree, chunked=True)
    leaf = os.path.join(p, "param__w.ra")

    def clobber():
        ra.write(leaf, _tree(7)["w"], chunked=True)
        st = os.stat(leaf)
        os.utime(leaf, ns=(st.st_mtime_ns + 10_000_000, st.st_mtime_ns + 10_000_000))

    with pytest.raises(ra.RawArrayError, match="during restore"):
        restore_pipelined(p, _like(tree), _after_resolve=clobber)


def test_url_overwrite_mid_restore_fails_fast(served):
    """Same-shape overwrite between pin and payload read: the stored bytes
    would parse fine, so only the ETag pin can catch it."""
    root, base = served
    tree = _tree(8)
    p = save_checkpoint(root, 1, tree, chunked=True, quantize="u8")
    url = f"{base}/{os.path.basename(p)}"
    leaf = os.path.join(p, "param__w.ra")

    def clobber():
        st = os.stat(leaf)
        os.utime(leaf, ns=(st.st_mtime_ns + 10_000_000, st.st_mtime_ns + 10_000_000))

    _cold()
    with pytest.raises(ra.RawArrayError, match="overwritten during restore"):
        restore_pipelined(url, _like(tree), _after_resolve=clobber)


def test_server_death_mid_restore_raises_not_hangs(tmp_path):
    tree = _tree(9)
    p = save_checkpoint(str(tmp_path), 1, tree, chunked=True)
    server = remote.serve(str(tmp_path), port=0)
    url = f"{server.url}/{os.path.basename(p)}"
    killed = []

    def kill():
        server.shutdown()
        server.server_close()
        killed.append(True)

    try:
        _cold()
        with pytest.raises(ra.RawArrayError):
            restore_pipelined(url, _like(tree), _after_resolve=kill)
        assert killed  # the pipeline got as far as the pin wave
    finally:
        if not killed:
            server.shutdown()
            server.server_close()
        _cold()


class _DenyingHandler(_http_server.BaseHTTPRequestHandler):
    def _deny(self):
        body = b"denied\n"
        self.send_response(401)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    do_GET = do_HEAD = _deny

    def log_message(self, fmt, *args):
        pass


def test_auth_denial_fails_fast():
    srv = _http_server.ThreadingHTTPServer(("127.0.0.1", 0), _DenyingHandler)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        url = f"http://127.0.0.1:{srv.server_address[1]}/step_00000001"
        with pytest.raises(remote.RemoteAuthError):
            restore_pipelined(url, _like(_tree()))
    finally:
        srv.shutdown()
        srv.server_close()
        _cold()


# ------------------------------------------- /stat listing + pinned readers
def test_stat_endpoint_lists_sizes_and_etags(served):
    root, base = served
    tree = _tree(10)
    p = save_checkpoint(root, 1, tree)
    rel = os.path.basename(p)
    with urllib.request.urlopen(f"{base}/stat/{rel}") as resp:
        assert resp.status == 200
        files = json.loads(resp.read())["files"]
    on_disk = {n for n in os.listdir(p) if os.path.isfile(os.path.join(p, n))}
    assert set(files) == on_disk
    for name, ent in files.items():
        assert ent["size"] == os.path.getsize(os.path.join(p, name))
        assert ent["etag"]

    with pytest.raises(urllib.error.HTTPError):
        urllib.request.urlopen(f"{base}/stat/no_such_dir")
    with pytest.raises(urllib.error.HTTPError):  # escape attempt -> 404
        urllib.request.urlopen(f"{base}/stat/../etc")


def test_stat_dir_and_pinned_reader(served):
    root, base = served
    tree = _tree(11)
    p = save_checkpoint(root, 1, tree)
    dir_url = f"{base}/{os.path.basename(p)}"
    listing = remote.stat_dir(dir_url)
    assert "manifest.json" in listing

    # pinned construction skips the HEAD yet reads real bytes
    name = "param__w.ra"
    r = remote.get_reader(f"{dir_url}/{name}", pinned=listing[name])
    assert (r.size, r.etag) == listing[name]
    got = r.read_range(0, 8)
    with open(os.path.join(p, name), "rb") as f:
        assert got == f.read(8)

    # a stale pin fails loudly on the FIRST ranged response
    _cold()
    size, _ = listing[name]
    r2 = remote.get_reader(f"{dir_url}/{name}", pinned=(size, '"stale-0"'))
    with pytest.raises(ra.RawArrayError, match="changed on server"):
        r2.read_range(0, 8)

    with pytest.raises(ra.RawArrayError):
        remote.stat_dir(f"{base}/no_such_dir")


def test_prewarm_stats(served):
    root, base = served
    tree = {"big": np.random.default_rng(0).standard_normal((512, 512)).astype(np.float32)}
    p = save_checkpoint(root, 1, tree, chunked=True)
    url = f"{base}/{os.path.basename(p)}"
    _cold()
    st = ColdStartStats()
    restore_pipelined(url, _like(tree), stats=st)
    assert st.prewarmed_conns >= 1
    _cold()
    st2 = ColdStartStats()
    restore_pipelined(url, _like(tree), prewarm=False, stats=st2)
    assert st2.prewarmed_conns == 0


# ------------------------------------------------------------ racat inspect
def test_racat_inspect_checkpoint(tmp_path, capsys):
    from repro.core.racat import main as racat_main

    tree = _tree(12)
    p = save_checkpoint(str(tmp_path), 1, tree, chunked=True, quantize="u8")
    assert racat_main(["inspect", p]) == 0
    out = capsys.readouterr().out
    assert "param__w" in out
    assert "param__inner__b" in out
    assert "u8" in out  # quant schema surfaced
