"""Devtools suite: ralint rules on fixture snippets, the layouts registry,
the tsan concurrency sanitizer (including seeded recreations of two real
historical races), and ``racat doctor`` geometry checks."""

import os
import struct
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.core import io as ra_io
from repro.core import layouts
from repro.core.racat import main as racat_main
from repro.devtools import doctor, lint, tsan

# --------------------------------------------------------------------- lint


def _lint(src, **kw):
    return lint.lint_source(textwrap.dedent(src), **kw)


def _rules(violations):
    return [v.rule for v in violations]


class TestLintGuardedBy:
    def test_unlocked_mutation_fires(self):
        vs = _lint(
            """
            import threading

            class Cache:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.hits = 0  # guarded-by: _lock

                def bump(self):
                    self.hits += 1
            """
        )
        assert _rules(vs) == ["guarded-by"]
        assert "hits" in vs[0].msg

    def test_locked_mutation_clean(self):
        vs = _lint(
            """
            import threading

            class Cache:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.hits = 0  # guarded-by: _lock

                def bump(self):
                    with self._lock:
                        self.hits += 1
            """
        )
        assert vs == []

    def test_init_and_locked_suffix_exempt(self):
        vs = _lint(
            """
            import threading

            class Cache:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.hits = 0  # guarded-by: _lock
                    self.hits = 1  # re-assignment in __init__ is still setup

                def _bump_locked(self):
                    self.hits += 1
            """
        )
        assert vs == []

    def test_mutator_method_fires(self):
        vs = _lint(
            """
            import threading

            class Index:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.entries = []  # guarded-by: _lock

                def add(self, x):
                    self.entries.append(x)
            """
        )
        assert _rules(vs) == ["guarded-by"]

    def test_waiver_suppresses(self):
        vs = _lint(
            """
            import threading

            class Cache:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.hits = 0  # guarded-by: _lock

                def bump(self):
                    self.hits += 1  # ralint: allow=guarded-by -- test fixture
            """
        )
        assert vs == []

    def test_nested_function_loses_lock(self):
        # a closure handed to another thread cannot inherit the held set
        vs = _lint(
            """
            import threading

            class Cache:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.hits = 0  # guarded-by: _lock

                def bump(self):
                    with self._lock:
                        def cb():
                            self.hits += 1
                        return cb
            """
        )
        assert _rules(vs) == ["guarded-by"]


class TestLintThreadLifecycle:
    def test_bare_thread_fires(self):
        vs = _lint(
            """
            import threading

            class Pump:
                def start(self):
                    self._t = threading.Thread(target=self._run)
                    self._t.start()
            """
        )
        assert _rules(vs) == ["thread-lifecycle"]

    def test_event_plus_joined_stop_clean(self):
        vs = _lint(
            """
            import threading

            class Pump:
                def start(self):
                    self._stop = threading.Event()
                    self._t = threading.Thread(target=self._run, daemon=True)
                    self._t.start()

                def stop(self):
                    self._stop.set()
                    self._t.join(timeout=5.0)
            """
        )
        assert vs == []


class TestLintSleepLoop:
    def test_sleep_in_loop_fires(self):
        vs = _lint(
            """
            import time

            def wait_ready(x):
                while not x.ready:
                    time.sleep(0.05)
            """
        )
        assert _rules(vs) == ["sleep-loop"]

    def test_sleep_outside_loop_clean(self):
        vs = _lint(
            """
            import time

            def backoff_once():
                time.sleep(0.05)
            """
        )
        assert vs == []


class TestLintStructLayout:
    def test_unregistered_format_fires(self):
        vs = _lint(
            """
            import struct

            HEAD = struct.Struct("<QQQ")
            """
        )
        assert _rules(vs) == ["struct-layout"]

    def test_registered_format_clean(self):
        vs = _lint(
            """
            import struct

            HEAD = struct.Struct("<QQQQQQ")
            TRAILER = struct.Struct("<I")
            """
        )
        assert vs == []


class TestLintEnvKnob:
    def test_raw_environ_read_fires(self):
        vs = _lint(
            """
            import os

            def knob():
                return os.environ.get("RA_MY_KNOB", "0")
            """
        )
        assert "env-knob" in _rules(vs)

    def test_spec_helper_clean_and_doc_checked(self):
        src = """
            from repro.core.spec import env_int

            def knob():
                return env_int("RA_DOCUMENTED", 4)
        """
        assert _lint(src, readme_knobs={"RA_DOCUMENTED"}) == []
        vs = _lint(src, readme_knobs={"RA_OTHER"})
        assert _rules(vs) == ["env-doc"]
        assert "RA_DOCUMENTED" in vs[0].msg


class TestLintTree:
    def test_src_tree_is_clean(self):
        # the shipped tree must satisfy its own invariants
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        src = os.path.join(repo, "src")
        readme = os.path.join(repo, "README.md")
        vs = lint.lint_paths([src], readme=readme if os.path.isfile(readme) else None)
        assert vs == [], "\n".join(str(v) for v in vs)

    def test_collect_guards_reads_annotations(self):
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        cache_py = os.path.join(repo, "src", "repro", "remote", "cache.py")
        guards = lint.collect_guards(cache_py)
        assert guards["BlockCache"]["hits"] == "_lock"
        assert guards["BlockCache"]["_blocks"] == "_lock"


# ------------------------------------------------------------------ layouts


class TestLayouts:
    def test_header_geometry(self):
        H = layouts.HEADER
        assert H.magic == b"rawarray"
        assert H.head_bytes == 48
        assert H.nbytes(3) == 48 + 24
        assert H.magic_int == int.from_bytes(b"rawarray", "little")

    def test_chunk_table_and_stats_geometry(self):
        assert layouts.CHUNK_TABLE.head_bytes == 32
        assert layouts.CHUNK_TABLE.entry_bytes == 32
        assert layouts.RASTATS.head_bytes == 40
        assert layouts.RASTATS.entry_bytes == 32
        assert layouts.CRC32.head_bytes == 4

    def test_registered_formats_closed_set(self):
        for fmt in ("<QQQQQQ", "<QQQQ", "<QQQQQ", "<Q", "<I"):
            assert fmt in layouts.REGISTERED_FORMATS
        # registry sizes agree with struct itself
        for lay in layouts.LAYOUTS.values():
            assert struct.calcsize(lay.head_fmt) == lay.head_bytes


# --------------------------------------------------------------------- tsan

_SCOPE = ("/tests/", "/repro/", os.sep + "tests" + os.sep)


@pytest.fixture
def sanitizer():
    """Locally-installed sanitizer; restores global state afterwards."""
    was_installed = tsan.installed()
    tsan.install(scope=_SCOPE, hold_ms=60_000)
    yield tsan
    tsan.drain()
    if not was_installed:
        tsan.uninstall()
    else:  # suite runs under --ra-sanitize: restore its default config
        tsan.install()


class TestTsanLocks:
    def test_lock_order_inversion_detected(self, sanitizer):
        a = threading.Lock()
        b = threading.Lock()
        with a:
            with b:
                pass
        with b:
            with a:
                pass
        kinds = [r.kind for r in sanitizer.drain()]
        assert "lock-order-inversion" in kinds

    def test_consistent_order_clean(self, sanitizer):
        a = threading.Lock()
        b = threading.Lock()
        for _ in range(3):
            with a:
                with b:
                    pass
        assert [r for r in sanitizer.drain() if r.severity == "error"] == []

    def test_acquire_after_finalize(self, sanitizer):
        lk = threading.Lock()
        lk.finalize()
        with lk:
            pass
        kinds = [r.kind for r in sanitizer.drain()]
        assert kinds == ["acquire-after-finalize"]

    def test_long_hold_warns(self, sanitizer):
        sanitizer.install(scope=_SCOPE, hold_ms=5)
        lk = threading.Lock()
        with lk:
            time.sleep(0.03)
        reps = sanitizer.drain()
        assert [r.kind for r in reps] == ["long-hold"]
        assert reps[0].severity == "warn"

    def test_condition_wait_notify_works_instrumented(self, sanitizer):
        cond = threading.Condition()
        ready = []

        def waiter():
            with cond:
                while not ready:
                    cond.wait(timeout=2.0)

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.05)
        with cond:
            ready.append(1)
            cond.notify_all()
        t.join(timeout=5.0)
        assert not t.is_alive()
        assert [r for r in sanitizer.drain() if r.severity == "error"] == []

    def test_rlock_reentrancy_no_false_positive(self, sanitizer):
        r = threading.RLock()
        with r:
            with r:
                pass
        assert [x for x in sanitizer.drain() if x.severity == "error"] == []


class TestTsanFieldTracer:
    def test_cross_thread_unguarded_write_flagged(self, sanitizer):
        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0  # guarded-by: _lock

        sanitizer.watch_class(Counter, {"n": "_lock"})
        try:
            c = Counter()
            c.n += 1  # creator thread: single-owner idiom, exempt

            def locked_bump():
                with c._lock:
                    c.n += 1

            def racy_bump():
                c.n += 1

            for fn, expect in ((locked_bump, 0), (racy_bump, 1)):
                t = threading.Thread(target=fn)
                t.start()
                t.join()
                reps = [r for r in sanitizer.drain() if r.kind == "unguarded-write"]
                assert len(reps) == expect, (fn.__name__, reps)
        finally:
            sanitizer.unwatch_all()


class TestSeededRaces:
    """Recreations of two races this repo actually shipped and later fixed.

    These prove the sanitizer would have caught both at the time."""

    def test_pr5_zombie_ring_writer(self, sanitizer):
        # PR 5's loader ring: stop() set a flag but never joined the
        # producer, which could wake after shutdown and write into a ring
        # whose owner considered it dead.
        class Ring:
            def __init__(self):
                self._lock = threading.Lock()
                self.depth = 0  # guarded-by: _lock

        sanitizer.watch_class(Ring, {"depth": "_lock"})
        try:
            ring = Ring()
            ring.depth = 1  # creator warms the ring
            wake = threading.Event()

            def zombie():
                wake.wait(timeout=5.0)
                with ring._lock:  # acquire-after-finalize
                    pass
                ring.depth += 1  # unguarded cross-thread write

            t = threading.Thread(target=zombie)
            t.start()
            # "shutdown": owner declares the ring dead without joining
            ring._lock.finalize()
            wake.set()
            t.join(timeout=5.0)

            kinds = [r.kind for r in sanitizer.drain() if r.severity == "error"]
            assert "acquire-after-finalize" in kinds
            assert "unguarded-write" in kinds
        finally:
            sanitizer.unwatch_all()

    def test_pr7_cache_counter_race(self, sanitizer):
        # PR 7's BlockCache counters: `cache.hits += 1` outside _lock.
        # The real class + its real guarded-by annotations, via the same
        # lint-derived map the pytest plugin uses.
        import repro.remote.cache as cache_mod

        watched = sanitizer.watch_module(cache_mod)
        try:
            assert "BlockCache" in watched
            cache = cache_mod.BlockCache(capacity_bytes=1 << 20)
            barrier = threading.Barrier(2)

            def racy_reader():
                barrier.wait(timeout=5.0)
                for _ in range(50):
                    cache.hits += 1  # the shipped bug: no self._lock

            ts = [threading.Thread(target=racy_reader) for _ in range(2)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(timeout=5.0)

            reps = [r for r in sanitizer.drain() if r.kind == "unguarded-write"]
            assert reps, "sanitizer missed the PR 7 counter race"
            assert any("hits" in r.where for r in reps)
        finally:
            sanitizer.unwatch_all()

    def test_guarded_cache_use_is_clean(self, sanitizer):
        import repro.remote.cache as cache_mod

        sanitizer.watch_module(cache_mod)
        try:
            cache = cache_mod.BlockCache(capacity_bytes=1 << 20)

            def worker(i):
                cache.put(f"k{i}", 0, b"x" * 64)
                cache.get(f"k{i}", 0)
                cache.get("missing", 0)

            ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(timeout=5.0)
            errors = [r for r in sanitizer.drain() if r.severity == "error"]
            assert errors == [], errors
        finally:
            sanitizer.unwatch_all()


# ------------------------------------------------------------------- doctor


@pytest.fixture
def corpus(tmp_path):
    a = np.arange(4096, dtype=np.float32).reshape(64, 64)
    plain = tmp_path / "plain.ra"
    chunked = tmp_path / "chunked.ra"
    ra_io.write(str(plain), a)
    ra_io.write(str(chunked), a, codec="zlib", chunk_bytes=4096, stats=True)
    return tmp_path, plain, chunked


class TestDoctor:
    def test_clean_files_pass(self, corpus):
        _dir, plain, chunked = corpus
        assert doctor.doctor_file(str(plain)) == []
        assert doctor.doctor_file(str(chunked)) == []

    def test_truncated_stats_block_is_drift(self, corpus):
        d, _plain, chunked = corpus
        bad = d / "bad.ra"
        bad.write_bytes(chunked.read_bytes()[:-16])
        problems = doctor.doctor_file(str(bad))
        assert problems and any("rastats" in p for p in problems)

    def test_stale_stats_window_count_is_drift(self, corpus):
        # rewrite the rastats head to claim one window fewer: framing stays
        # internally consistent but disagrees with the file's geometry
        from repro.core import stats as stats_mod

        d, _plain, chunked = corpus
        data = bytearray(chunked.read_bytes())
        idx = data.find(stats_mod.RASTATS_MAGIC_BYTES)
        assert idx > 0
        head = layouts.RASTATS.head_struct
        magic, ver, block, n, cb = head.unpack_from(data, idx)
        assert n >= 2
        shrunk = head.pack(magic, ver, layouts.RASTATS.nbytes(n - 1), n - 1, cb)
        trimmed = (
            bytes(data[:idx])
            + shrunk
            + bytes(data[idx + head.size:idx + layouts.RASTATS.nbytes(n - 1)])
            + bytes(data[idx + layouts.RASTATS.nbytes(n):])
        )
        stale = d / "stale.ra"
        stale.write_bytes(trimmed)
        problems = doctor.doctor_file(str(stale))
        assert any("stale" in p for p in problems), problems

    def test_racat_doctor_exit_codes(self, corpus, capsys):
        d, plain, _chunked = corpus
        assert racat_main(["doctor", str(plain)]) == 0
        bad = d / "bad2.ra"
        bad.write_bytes(plain.read_bytes()[:20])
        assert racat_main(["doctor", str(bad)]) == 1
        assert racat_main(["doctor", str(d)]) == 1  # dir walk finds bad2.ra
        out = capsys.readouterr()
        assert "DRIFT" in out.err

    def test_directory_without_ra_files(self, tmp_path):
        res = doctor.doctor_paths([str(tmp_path)])
        assert any(problems for problems in res.values())
