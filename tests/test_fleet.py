"""Serving fleet (DESIGN.md §14): consistent-hash ring math, the
router/proxy, the edge read-through tiers, single-flight coalescing,
failover, ETag invalidation, and the loadgen trace builders.

Every networked test runs a real in-process fleet — origin + edge
replicas + router on ephemeral loopback ports, actual sockets."""

import json
import os
import threading
import time
import urllib.request

import numpy as np
import pytest

import repro.core as ra
from repro import fleet, remote
from repro.data.dataset import RaDataset, RaDatasetWriter
from repro.fleet.edge import SingleFlight, SpillCache
from repro.fleet.loadgen import (
    build_trace,
    percentile,
    trace_coldstart,
    trace_gather,
    trace_rows,
)
from repro.fleet.router import HashRing, route_key


# ------------------------------------------------------------- ring math
def test_ring_deterministic_and_balanced():
    nodes = [f"http://127.0.0.1:{9000 + i}" for i in range(3)]
    r1 = HashRing(nodes, vnodes=64)
    r2 = HashRing(list(reversed(nodes)), vnodes=64)
    keys = [f"/shard{i}.ra#{j}" for i in range(40) for j in range(50)]
    owners = {}
    for k in keys:
        o = r1.lookup(k)
        # deterministic across instances and insertion orders
        assert o == r2.lookup(k)
        assert o == r1.preference(k)[0]
        owners[o] = owners.get(o, 0) + 1
    assert set(owners) == set(nodes)
    for n, cnt in owners.items():
        assert cnt > len(keys) * 0.15, f"{n} owns only {cnt}/{len(keys)} keys"


def test_ring_minimal_disruption_on_removal():
    nodes = [f"n{i}" for i in range(4)]
    ring = HashRing(nodes, vnodes=64)
    keys = [f"k{i}" for i in range(1000)]
    before = {k: ring.lookup(k) for k in keys}
    ring.remove("n2")
    moved = sum(1 for k in keys
                if before[k] != "n2" and ring.lookup(k) != before[k])
    # keys not owned by the removed node must not move at all
    assert moved == 0
    # and the removed node's keys redistribute across the survivors
    heirs = {ring.lookup(k) for k in keys if before[k] == "n2"}
    assert heirs <= {"n0", "n1", "n3"} and len(heirs) > 1


def test_ring_preference_distinct_and_empty():
    ring = HashRing([], vnodes=8)
    assert ring.lookup("x") is None and ring.preference("x") == []
    for n in ("a", "b", "c"):
        ring.add(n)
    pref = ring.preference("some-key")
    assert sorted(pref) == ["a", "b", "c"]
    assert ring.preference("some-key", limit=2) == pref[:2]


def test_route_key_colocates_metadata_with_bytes():
    assert route_key("/a.ra", 0, 1 << 20) == route_key("/header/a.ra", 0, 1 << 20)
    assert route_key("/a.ra", 0, 1 << 20) == route_key("/stat/a.ra", 0, 1 << 20)
    # different blocks of one path spread across the ring
    assert route_key("/a.ra", 0, 1 << 20) != route_key("/a.ra", 1 << 20, 1 << 20)


# ------------------------------------------------------ fleet end-to-end
@pytest.fixture()
def fleet3(tmp_path):
    """(root, Fleet) with 3 edges over a local origin; revalidates every
    request so overwrite tests see changes immediately."""
    fl = fleet.serve(str(tmp_path), replicas=3, revalidate_s=0.0)
    try:
        yield str(tmp_path), fl
    finally:
        fl.shutdown()
        remote.close_readers()
        remote.reset_shared_cache()
        remote.reset_breakers()


def _metrics(url):
    with urllib.request.urlopen(url + "/metrics") as resp:
        return json.load(resp)


def test_byte_identity_through_router(fleet3):
    root, fl = fleet3
    rng = np.random.default_rng(0)
    plain = rng.normal(size=(200, 33)).astype(np.float64)
    ra.write(os.path.join(root, "plain.ra"), plain)
    chunked = rng.integers(0, 255, size=500_000, dtype=np.uint8)
    ra.write(os.path.join(root, "chunked.ra"), chunked, chunked=True)

    got_p = ra.read(f"{fl.url}/plain.ra")
    got_c = ra.read(f"{fl.url}/chunked.ra")
    assert got_p.dtype == plain.dtype and np.array_equal(got_p, plain)
    assert got_c.dtype == chunked.dtype and np.array_equal(got_c, chunked)
    # metadata views route through to the origin
    assert tuple(remote.remote_header_of(f"{fl.url}/plain.ra").shape) == plain.shape
    listing = remote.stat_dir(fl.url + "/")
    assert {"plain.ra", "chunked.ra"} <= set(listing)


def test_dataset_gather_through_router(fleet3):
    root, fl = fleet3
    rng = np.random.default_rng(6)
    w = RaDatasetWriter(os.path.join(root, "ds"),
                        {"tok": ((8,), "uint32"), "y": ((), "float32")},
                        shard_rows=64)
    w.append(tok=rng.integers(0, 1000, size=(200, 8)).astype(np.uint32),
             y=rng.normal(size=200).astype(np.float32))
    w.finish()
    local = RaDataset(os.path.join(root, "ds"))
    prox = RaDataset(f"{fl.url}/ds")
    try:
        idx = np.random.default_rng(7).permutation(local.total_rows)[:64]
        gl, gp = local.gather(idx), prox.gather(idx)
        for f in ("tok", "y"):
            assert np.array_equal(gp[f], gl[f])
    finally:
        prox.close()
        local.close()


def test_healthz_and_metrics_endpoints(fleet3):
    root, fl = fleet3
    ra.write(os.path.join(root, "a.ra"), np.arange(1000, dtype=np.float32))
    ra.read(f"{fl.url}/a.ra")

    with urllib.request.urlopen(fl.url + "/healthz") as resp:
        h = json.load(resp)
    assert h["ok"] and h["role"] == "router" and h["replicas"] == 3

    rm = _metrics(fl.url)
    assert rm["role"] == "router" and rm["requests"] > 0
    assert set(rm["replicas"]) == {e.url for e in fl.edges}

    served = 0
    for e in fl.edges:
        em = _metrics(e.url)
        assert em["role"] == "edge" and em["origin"] == fl.origin.url
        assert em["ram"]["hits"] + em["ram"]["misses"] >= em["origin_fetches"]
        served += em["origin_fetches"]
    assert served > 0

    om = _metrics(fl.origin.url)
    assert om["role"] == "origin" and om["bytes_out"] > 0


def test_single_flight_coalesces_a_herd(tmp_path):
    ra.write(os.path.join(str(tmp_path), "hot.ra"),
             np.arange(500_000, dtype=np.float32))
    # a slow origin makes the race window real: the herd arrives while the
    # leader's fetch is still in flight
    fl = fleet.serve(str(tmp_path), replicas=3, delay_s=0.05,
                     revalidate_s=30.0)
    try:
        block = fl.edges[0].block_bytes
        rep = fleet.run_load(fl.url, [("/hot.ra", 0, block)] * 40, clients=40)
        assert rep["errors"] == 0
        fetches = sum(e._fetches_by_path.get("/hot.ra", 0) for e in fl.edges)
        assert fetches == 1, f"herd cost {fetches} origin fetches, wanted 1"
        assert sum(e.flights.coalesced_waits for e in fl.edges) > 0
    finally:
        fl.shutdown()
        remote.close_readers()
        remote.reset_shared_cache()
        remote.reset_breakers()


def test_single_flight_unit_exactly_one_call():
    sf = SingleFlight()
    calls = []
    gate = threading.Event()

    def work():
        calls.append(1)
        gate.wait(2.0)
        return b"payload"

    results = []
    threads = [threading.Thread(target=lambda: results.append(sf.do(("t", 0), work)))
               for _ in range(16)]
    for t in threads:
        t.start()
    time.sleep(0.05)  # let every follower park on the flight
    gate.set()
    for t in threads:
        t.join(5.0)
    assert len(calls) == 1 and results == [b"payload"] * 16
    assert sf.coalesced_waits == 15 and sf.leaders == 1
    # errors propagate to every waiter, and the flight table drains
    with pytest.raises(RuntimeError):
        sf.do(("t", 1), lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    assert not sf._flights


def test_failover_on_replica_death(fleet3):
    root, fl = fleet3
    arr = np.arange(200_000, dtype=np.int32)
    ra.write(os.path.join(root, "f.ra"), arr)
    assert np.array_equal(ra.read(f"{fl.url}/f.ra"), arr)

    # kill the replica that OWNS the file's routing key, so the next read
    # must walk the preference list
    owner = fl.router.plan(route_key("/f.ra", 0, fl.router.hash_block))[0]
    victim = next(e for e in fl.edges if e.url == owner)
    victim.shutdown()
    victim.server_close()
    remote.close_readers()
    remote.reset_shared_cache()
    remote.reset_breakers()

    # every key still resolves: dead replica's keys walk to the next ring node
    assert np.array_equal(ra.read(f"{fl.url}/f.ra"), arr)
    rm = _metrics(fl.url)
    assert rm["failovers"] > 0 and rm["fallback_served"] > 0
    assert rm["replicas"][victim.url]["down"] is True


def test_membership_change_rebalances(fleet3):
    root, fl = fleet3
    arr = np.random.default_rng(1).normal(size=60_000).astype(np.float32)
    ra.write(os.path.join(root, "m.ra"), arr)
    assert np.array_equal(ra.read(f"{fl.url}/m.ra"), arr)

    added = fl.add_replica()
    assert added.url in fl.router.replica_urls()
    remote.close_readers()
    remote.reset_shared_cache()
    assert np.array_equal(ra.read(f"{fl.url}/m.ra"), arr)

    fl.remove_replica(added)
    assert added.url not in fl.router.replica_urls()
    remote.close_readers()
    remote.reset_shared_cache()
    assert np.array_equal(ra.read(f"{fl.url}/m.ra"), arr)


def test_etag_change_invalidates_edges(fleet3):
    root, fl = fleet3
    p = os.path.join(root, "v.ra")
    v1 = np.zeros(50_000, dtype=np.float32)
    ra.write(p, v1)
    assert np.array_equal(ra.read(f"{fl.url}/v.ra"), v1)

    time.sleep(0.01)  # mtime_ns tick so the ETag provably changes
    v2 = np.ones(50_000, dtype=np.float32)
    ra.write(p, v2)
    remote.close_readers()
    remote.reset_shared_cache()

    got = ra.read(f"{fl.url}/v.ra")
    assert np.array_equal(got, v2), "edge served stale blocks after overwrite"
    assert sum(e.invalidated_paths for e in fl.edges) >= 1
    assert sum(e.cache.stats()["invalidations"] for e in fl.edges) >= 1


def test_edge_serves_origin_etag_and_304(fleet3):
    root, fl = fleet3
    ra.write(os.path.join(root, "e.ra"), np.arange(10_000, dtype=np.uint16))
    req = urllib.request.Request(f"{fl.url}/e.ra", headers={"Range": "bytes=0-99"})
    with urllib.request.urlopen(req) as resp:
        etag = resp.headers["ETag"]
        assert resp.status == 206 and etag
    st = os.stat(os.path.join(root, "e.ra"))
    from repro.remote.server import file_etag

    assert etag == file_etag(st)  # edge relays the ORIGIN's version
    req = urllib.request.Request(f"{fl.url}/e.ra",
                                 headers={"If-None-Match": etag})
    try:
        with urllib.request.urlopen(req) as resp:
            status = resp.status
    except urllib.error.HTTPError as exc:  # urllib treats 304 as an error
        status = exc.code
    assert status == 304


def test_edge_rejects_writes_but_router_forwards_put(tmp_path):
    fl = fleet.serve(str(tmp_path), replicas=2, upload_token="tok",
                     revalidate_s=0.0)
    try:
        arr = np.arange(5_000, dtype=np.float32)
        os.environ["RA_REMOTE_TOKEN"] = "tok"
        try:
            ra.write(f"{fl.url}/up.ra", arr)  # PUT through the router
        finally:
            os.environ.pop("RA_REMOTE_TOKEN", None)
        assert os.path.exists(os.path.join(str(tmp_path), "up.ra"))
        assert np.array_equal(ra.read(f"{fl.url}/up.ra"), arr)
        # direct PUT at an edge is refused: replicas are read-only
        req = urllib.request.Request(fl.edges[0].url + "/nope.ra",
                                     data=b"x", method="PUT")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 405
    finally:
        fl.shutdown()
        remote.close_readers()
        remote.reset_shared_cache()
        remote.reset_breakers()


# ------------------------------------------------------------- spill tier
def test_spill_cache_roundtrip_lru_and_invalidate(tmp_path):
    sp = SpillCache(str(tmp_path / "spill"), capacity_bytes=3 * 100)
    blob = bytes(100)
    sp.put("t@1", 0, blob)
    sp.put("t@1", 1, blob)
    sp.put("u@1", 0, blob)
    assert sp.get("t@1", 0) == blob
    assert sp.get("missing", 9) is None
    sp.put("u@1", 1, blob)  # over capacity: evicts the LRU entry (t@1,1)
    s = sp.stats()
    assert s["evictions"] == 1 and s["blocks"] == 3
    assert sp.get("t@1", 1) is None
    dropped = sp.invalidate("u@1")
    assert dropped == 2 and sp.get("u@1", 0) is None
    # only the surviving block's file remains on disk
    files = [f for f in os.listdir(tmp_path / "spill") if f.endswith(".blk")]
    assert len(files) == 1


def test_edge_promotes_from_disk_after_ram_flush(tmp_path):
    arr = np.arange(300_000, dtype=np.float32)
    ra.write(os.path.join(str(tmp_path), "d.ra"), arr)
    fl = fleet.serve(str(tmp_path), replicas=1, revalidate_s=30.0)
    try:
        assert np.array_equal(ra.read(f"{fl.url}/d.ra"), arr)
        edge = fl.edges[0]
        before = edge.origin_fetches
        assert before > 0 and edge.spill is not None
        # drop the RAM tier; the spill tier must refill it without origin I/O
        edge.cache.clear()
        remote.close_readers()
        remote.reset_shared_cache()
        assert np.array_equal(ra.read(f"{fl.url}/d.ra"), arr)
        assert edge.origin_fetches == before
        assert edge.spill.stats()["hits"] > 0
    finally:
        fl.shutdown()
        remote.close_readers()
        remote.reset_shared_cache()
        remote.reset_breakers()


# ----------------------------------------------------------------- loadgen
def test_trace_builders_shapes_and_bounds():
    files = [("/a.ra", 1_000_000), ("/b.ra", 300_000)]
    g = trace_gather(files, req_bytes=1 << 16, requests=50, seed=3)
    assert len(g) == 50
    sizes = dict(files)
    for path, off, ln in g:
        assert 0 <= off < sizes[path] and 0 < ln <= 1 << 16
        assert off + ln <= sizes[path]
    r = trace_rows(files, req_bytes=1 << 16, requests=20)
    assert len(r) == 20 and r[0][0] == "/a.ra" and r[1][0] == "/b.ra"
    c = trace_coldstart(files, req_bytes=1 << 17)
    assert sum(ln for _, _, ln in c) == sum(sizes.values())
    assert c[0][0] == "/a.ra"  # largest object first
    with pytest.raises(ra.RawArrayError):
        build_trace("nope", files, req_bytes=1, requests=1)


def test_percentile_nearest_rank():
    assert percentile([], 0.99) == 0.0
    vals = sorted(float(i) for i in range(100))
    assert percentile(vals, 0.50) == 50.0
    assert percentile(vals, 0.99) == 99.0


def test_loadgen_against_live_fleet(fleet3):
    root, fl = fleet3
    ra.write(os.path.join(root, "lg.ra"),
             np.arange(250_000, dtype=np.float32))
    files = fleet.files_from_stat(fl.url, suffix=".ra")
    assert ("/lg.ra", os.path.getsize(os.path.join(root, "lg.ra"))) in files
    trace = build_trace("gather", files, req_bytes=1 << 15, requests=48, seed=5)
    rep = fleet.run_load(fl.url, trace, clients=12)
    assert rep["errors"] == 0 and rep["requests"] == 48
    assert rep["bytes"] > 0 and rep["p99_ms"] >= rep["p50_ms"] >= 0
