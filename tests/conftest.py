"""Test-suite bootstrap: make ``src`` importable without an installed
package and register the hypothesis fallback (tests/_compat.py) when the
real package is missing, so the suite collects and runs everywhere."""

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")

for p in (_HERE, _SRC):
    if p not in sys.path:
        sys.path.insert(0, p)

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _compat

    sys.modules.setdefault("hypothesis", _compat)
    sys.modules.setdefault("hypothesis.strategies", _compat.strategies)
