"""Test-suite bootstrap: make ``src`` importable without an installed
package, register the hypothesis fallback (tests/_compat.py) when the
real package is missing, and enforce a per-test wall-clock timeout so a
hung socket (remote-plane tests talk to real servers) can never wedge the
whole suite."""

import os
import signal
import sys
import threading

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")

for p in (_HERE, _SRC):
    if p not in sys.path:
        sys.path.insert(0, p)

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _compat

    sys.modules.setdefault("hypothesis", _compat)
    sys.modules.setdefault("hypothesis.strategies", _compat.strategies)


# --------------------------------------------------------- per-test timeout
# Stdlib-only (no pytest-timeout in the image): SIGALRM interrupts the test
# body — including a blocking socket read — and fails it with a traceback.
# Knob: RA_TEST_TIMEOUT seconds; 0 disables. Only armed where SIGALRM works
# (main thread, non-Windows).
def _test_timeout_s() -> int:
    try:
        return int(os.environ.get("RA_TEST_TIMEOUT", "120"))
    except ValueError:
        return 120


# ------------------------------------------------- --ra-sanitize (tsan)
# Opt-in concurrency sanitizer (DESIGN.md §17): instrumented locks +
# guarded-field write tracer over the threaded data plane. A test that
# leaves error-severity reports behind fails, even if its asserts passed.
def pytest_addoption(parser):
    parser.addoption(
        "--ra-sanitize",
        action="store_true",
        default=False,
        help="instrument repro locks and guarded fields with the "
        "repro.devtools.tsan concurrency sanitizer",
    )


def pytest_configure(config):
    if not config.getoption("--ra-sanitize"):
        return
    from repro.devtools import tsan

    tsan.install()
    watched = tsan.watch_all()
    config._ra_tsan = tsan
    sys.stderr.write(
        f"ra-sanitize: instrumented locks + {len(watched)} watched classes\n"
    )


def pytest_unconfigure(config):
    tsan = getattr(config, "_ra_tsan", None)
    if tsan is not None:
        tsan.unwatch_all()
        tsan.uninstall()


def pytest_runtest_teardown(item, nextitem):
    tsan = getattr(item.config, "_ra_tsan", None)
    if tsan is None:
        return
    errors = [r for r in tsan.drain() if r.severity == "error"]
    if errors:
        lines = "\n".join(f"  {r}" for r in errors)
        pytest.fail(
            f"concurrency sanitizer reported {len(errors)} error(s) "
            f"during {item.nodeid}:\n{lines}",
            pytrace=False,
        )


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    timeout = _test_timeout_s()
    armed = (
        timeout > 0
        and hasattr(signal, "SIGALRM")
        and threading.current_thread() is threading.main_thread()
    )
    if armed:
        def _alarm(signum, frame):
            raise TimeoutError(
                f"{item.nodeid} exceeded the {timeout}s per-test timeout "
                f"(RA_TEST_TIMEOUT)"
            )

        prev = signal.signal(signal.SIGALRM, _alarm)
        signal.alarm(timeout)
    try:
        yield
    finally:
        if armed:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, prev)
