"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention, dequant_u8, flash_attention, ssd_scan
from repro.kernels import ref

_rng = np.random.default_rng(0)


def _arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(_rng.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),
    (1, 8, 2, 384, 128),   # S not a multiple of block_k=128? 384 = 3x128 ok
    (2, 2, 1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(B, H, KV, S, hd, dtype, causal, window):
    q, k, v = _arr(B, H, S, hd, dtype=dtype), _arr(B, KV, S, hd, dtype=dtype), _arr(B, KV, S, hd, dtype=dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("B,KV,g,S,hd,pos", [
    (1, 2, 4, 256, 64, 100),
    (2, 1, 8, 512, 128, 511),
    (2, 4, 1, 128, 64, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, KV, g, S, hd, pos, dtype):
    q = _arr(B, KV * g, hd, dtype=dtype)
    k, v = _arr(B, KV, S, hd, dtype=dtype), _arr(B, KV, S, hd, dtype=dtype)
    out = decode_attention(q, k, v, pos)
    want = ref.decode_attention_ref(q.reshape(B, KV, g, hd), k, v, pos).reshape(B, KV * g, hd)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_decode_attention_masks_beyond_pos():
    """Cache rows beyond pos must be completely dead."""
    B, KV, g, S, hd = 1, 1, 2, 128, 64
    q = _arr(B, KV * g, hd)
    k, v = _arr(B, KV, S, hd), _arr(B, KV, S, hd)
    out1 = decode_attention(q, k, v, 10)
    k2 = k.at[:, :, 11:].set(999.0)
    v2 = v.at[:, :, 11:].set(-999.0)
    out2 = decode_attention(q, k2, v2, 10)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


@pytest.mark.parametrize("B,H,L,P,N,chunk", [
    (1, 2, 128, 32, 16, 32),
    (2, 3, 256, 64, 32, 64),
    (1, 1, 64, 16, 8, 64),   # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan_sweep(B, H, L, P, N, chunk, dtype):
    x = _arr(B, H, L, P, dtype=dtype, scale=0.5)
    dtA = -jnp.abs(_arr(B, H, L, dtype=dtype, scale=0.3))
    Bm, Cm = _arr(B, L, N, dtype=dtype, scale=0.5), _arr(B, L, N, dtype=dtype, scale=0.5)
    out = ssd_scan(x, dtA, Bm, Cm, chunk=chunk)
    want = ref.ssd_scan_ref(x, dtA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_ssd_kernel_matches_model_ssd():
    """Kernel must agree with the model-side pure-JAX chunked SSD too."""
    from repro.models.mamba import ssd_chunked

    B, H, L, P, N = 2, 2, 128, 16, 8
    x = _arr(B, L, H, P, scale=0.4)           # model layout (B, L, H, P)
    dtA = -jnp.abs(_arr(B, L, H, scale=0.2))
    Bm, Cm = _arr(B, L, N, scale=0.5), _arr(B, L, N, scale=0.5)
    y_model, _ = ssd_chunked(x, dtA, Bm, Cm, chunk=32)
    y_kernel = ssd_scan(
        jnp.moveaxis(x, 2, 1), jnp.moveaxis(dtA, 2, 1), Bm, Cm, chunk=32
    )
    np.testing.assert_allclose(
        np.asarray(jnp.moveaxis(y_kernel, 1, 2)), np.asarray(y_model), rtol=1e-3, atol=1e-4
    )


@pytest.mark.parametrize("rows,C", [(10, 8), (300, 24), (257, 128)])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_sweep(rows, C, out_dtype):
    x = jnp.asarray(_rng.integers(0, 256, (rows, C)), jnp.uint8)
    scale, bias = _arr(C, scale=0.01), _arr(C)
    out = dequant_u8(x, scale, bias, out_dtype=out_dtype)
    want = ref.dequant_u8_ref(x, scale, bias, out_dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )
