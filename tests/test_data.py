"""Data pipeline invariants: dataset reads, loader determinism/resume,
multi-host partition coverage (hypothesis)."""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataLoader, LoaderState, RaDataset, RaDatasetWriter, make_token_dataset


@pytest.fixture(scope="module")
def token_ds(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("ds") / "toks")
    make_token_dataset(root, n_docs=300, seq_len=32, vocab=64, shard_rows=128)
    return RaDataset(root)


def test_rows_cross_shard(token_ds):
    assert len(token_ds) == 300 and len(token_ds.shards) == 3
    b = token_ds.rows(120, 140)  # spans shard 0/1 boundary at 128
    assert b["tokens"].shape == (20, 32)
    # equality with per-shard reads
    lo = token_ds.rows(120, 128)["tokens"]
    hi = token_ds.rows(128, 140)["tokens"]
    assert np.array_equal(b["tokens"], np.concatenate([lo, hi]))


def test_gather_matches_rows(token_ds):
    idx = np.array([5, 131, 250, 131])
    g = token_ds.gather(idx)["tokens"]
    for i, gi in zip(idx, g):
        assert np.array_equal(gi, token_ds.rows(int(i), int(i) + 1)["tokens"][0])


def test_loader_deterministic(token_ds):
    a = DataLoader(token_ds, 16, seed=7)
    b = DataLoader(token_ds, 16, seed=7)
    for _ in range(4):
        x, y = next(a), next(b)
        assert np.array_equal(x["tokens"], y["tokens"])
    a.stop(), b.stop()


def test_loader_resume_exact(token_ds):
    a = DataLoader(token_ds, 16, seed=3)
    batches = [next(a) for _ in range(6)]
    a.stop()
    st_ = batches[3]["_state"]
    b = DataLoader(token_ds, 16, seed=3)
    b.restore(st_)
    nxt = next(b)
    b.stop()
    assert nxt["_state"].__dict__ == batches[4]["_state"].__dict__
    assert np.array_equal(nxt["tokens"], batches[4]["tokens"])


def test_loader_epoch_rollover(token_ds):
    dl = DataLoader(token_ds, 64, seed=0)  # 300//64 = 4 steps/epoch
    states = [next(dl)["_state"] for _ in range(9)]
    dl.stop()
    assert states[3].epoch == 0 and states[4].epoch == 1
    assert states[4].step == 0


@settings(max_examples=20, deadline=None)
@given(hosts=st.integers(1, 7), seed=st.integers(0, 5))
def test_host_partition_covers_exactly_once(token_ds, hosts, seed):
    rows = []
    for h in range(hosts):
        dl = DataLoader(token_ds, 8, seed=seed, host_id=h, host_count=hosts)
        rows.append(dl._epoch_order(0))
    allrows = np.concatenate(rows)
    assert len(np.unique(allrows)) == len(allrows)  # disjoint
    assert len(allrows) == len(token_ds)            # complete


def test_writer_shard_rolling(tmp_path):
    w = RaDatasetWriter(str(tmp_path / "w"), {"x": ((4,), "float32")}, shard_rows=10)
    for _ in range(7):
        w.append(x=np.ones((4, 4), np.float32))
    man = w.finish()
    assert man["total_rows"] == 28
    assert [s["rows"] for s in man["shards"]] == [10, 10, 8]
    ds = RaDataset(str(tmp_path / "w"))
    assert np.array_equal(ds.rows(0, 28)["x"], np.ones((28, 4), np.float32))
