"""Checkpoint store: roundtrip, atomicity, GC, elastic reshard, async."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (
    CheckpointManager,
    load_checkpoint,
    restore_resharded,
    save_checkpoint,
)
from repro.checkpoint.store import latest_step
from repro.distributed import optimizer as optim


def _params():
    return {
        "embed": jnp.arange(32, dtype=jnp.bfloat16).reshape(8, 4),
        "layers": {"w": jnp.ones((3, 4, 4), jnp.float32), "b": jnp.zeros((3, 4))},
        "step_like": jnp.asarray(5, jnp.int32),
    }


def _eq(a, b):
    return np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_roundtrip_with_opt_state(tmp_path):
    params = _params()
    state = optim.init_state(params, optim.AdamWConfig(moment_dtype="int8"))
    p = save_checkpoint(str(tmp_path), 42, params, state, extra={"foo": [1, 2]})
    assert p.endswith("step_00000042")
    p2, s2, extra = load_checkpoint(p, params, state)
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(_eq, params, p2))
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(_eq, state, s2))
    assert extra == {"foo": [1, 2]}


def test_every_leaf_is_a_rawarray_file(tmp_path):
    import repro.core as ra

    p = save_checkpoint(str(tmp_path), 1, _params())
    ra_files = [f for f in os.listdir(p) if f.endswith(".ra")]
    assert len(ra_files) == 4  # one per leaf
    for f in ra_files:
        hdr = ra.header_of(os.path.join(p, f))  # parses => valid RawArray
        assert hdr.data_length >= 0


def test_no_tmp_dir_left_behind(tmp_path):
    save_checkpoint(str(tmp_path), 7, _params())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert latest_step(str(tmp_path)) == 7


def test_shape_mismatch_rejected(tmp_path):
    p = save_checkpoint(str(tmp_path), 1, _params())
    bad = _params()
    bad["embed"] = jnp.zeros((9, 4), jnp.bfloat16)
    with pytest.raises(ValueError, match="checkpoint"):
        load_checkpoint(p, bad)


def test_elastic_reshard_row_slices(tmp_path):
    params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4)}
    p = save_checkpoint(str(tmp_path), 1, params)
    # two "hosts" of a new mesh each read only their row slab
    a = restore_resharded(p, "param__w", row_start=0, row_stop=8)
    b = restore_resharded(p, "param__w", row_start=8, row_stop=16)
    assert np.array_equal(np.concatenate([a, b]), np.asarray(params["w"]))


def test_manager_keep_last_k_and_async(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    params = _params()
    for s in (10, 20, 30, 40):
        cm.save(s, params)
    cm.wait()
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == [30, 40]
    assert cm.latest() == 40


def test_snapshot_semantics(tmp_path):
    """Async save must capture the values at save() time, not at write time."""
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    x = np.ones((256, 256), np.float32)
    params = {"w": jnp.asarray(x)}
    cm.save(1, params)
    params = {"w": params["w"] * 0.0}  # mutate AFTER save
    cm.wait()
    back, _, _ = load_checkpoint(cm.path(1), {"w": jnp.zeros((256, 256))})
    assert float(np.asarray(back["w"]).sum()) == 256 * 256
