"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finite values, plus serving-path consistency.
The FULL configs are exercised only via the dry-run (no allocation)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import all_arch_ids, get_config
from repro.models import build_model

ARCHS = all_arch_ids(include_paper=True)


def _batch(cfg, B=2, S=32, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    b = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)) * 0.1, jnp.float32
        )
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(rng.normal(size=(B, 64, cfg.d_model)) * 0.1, jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return model.train_loss(p, batch)

    (loss, metrics), grads = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # every grad leaf finite and at least one nonzero
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves), arch
    assert any(float(jnp.max(jnp.abs(l.astype(jnp.float32)))) > 0 for l in leaves), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_sane(arch):
    """Analytic parameter count of the FULL config lands near its nameplate."""
    cfg = get_config(arch)
    n = cfg.param_count()
    nameplate = {
        "gemma3-12b": 12e9, "olmo-1b": 1.2e9, "internlm2-1.8b": 1.9e9,
        "qwen2.5-14b": 14e9, "llava-next-mistral-7b": 7.1e9,
        "deepseek-v3-671b": 671e9, "kimi-k2-1t-a32b": 1.0e12,
        "whisper-medium": 0.76e9, "mamba2-780m": 0.78e9, "zamba2-1.2b": 1.2e9,
        "paper_lm": 6e6,
    }[cfg.name]
    assert 0.5 * nameplate < n < 1.7 * nameplate, (arch, n, nameplate)


@pytest.mark.parametrize("arch", ["qwen2_5_14b", "gemma3_12b", "mamba2_780m", "zamba2_1_2b"])
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)
    logits_pf, _ = jax.jit(model.prefill)(params, {"tokens": tokens})
    cache = model.empty_cache(B, S + 4)
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits_dec, cache = step(params, cache, tokens[:, t : t + 1])
    np.testing.assert_allclose(
        np.asarray(logits_pf), np.asarray(logits_dec), rtol=2e-3, atol=2e-4
    )


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3_12b")
    model = build_model(cfg)
    g, th = model._layer_flags(cfg.n_layers)
    g = np.asarray(g)
    assert g.sum() == cfg.n_layers // 6            # 1 global in 6
    assert g[5] == 1 and g[0] == 0 and g[11] == 1  # positions 6, 12, ...
    th = np.asarray(th)
    assert th[5] == 1_000_000.0 and th[0] == 10_000.0


def test_vlm_prefix_masking():
    """Loss must only cover text positions (patches are prefix)."""
    cfg = get_config("llava_next_mistral_7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = _batch(cfg)
    loss1, _ = jax.jit(model.train_loss)(params, b)
    # change ONLY the patch embeddings: loss must change (prefix feeds in)
    b2 = dict(b)
    b2["patch_embeds"] = b["patch_embeds"] * 2.0
    loss2, _ = jax.jit(model.train_loss)(params, b2)
    assert not np.isclose(float(loss1), float(loss2))


def test_mtp_loss_included():
    cfg = get_config("deepseek_v3_671b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = _batch(cfg)
    loss, metrics = jax.jit(model.train_loss)(params, b)
    assert "mtp" in metrics
    assert np.isfinite(float(metrics["mtp"]))
    np.testing.assert_allclose(
        float(loss),
        float(metrics["ce"] + metrics["aux"] + cfg.mtp_weight * metrics["mtp"]),
        rtol=1e-5,
    )


def test_sliding_window_shrinks_context():
    """A token far outside the window must not influence the last logits."""
    cfg = get_config("llava_next_mistral_7b").reduced().with_(
        family="dense", n_patches=0, sliding_window=8
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (1, 32)), jnp.int32)
    tokens2 = tokens.at[0, 0].set((int(tokens[0, 0]) + 1) % cfg.vocab)
    l1, _ = jax.jit(model.prefill)(params, {"tokens": tokens})
    l2, _ = jax.jit(model.prefill)(params, {"tokens": tokens2})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_ssd_chunk_invariance():
    """Mamba2 output must not depend on the chunk size (algebraic identity)."""
    import dataclasses

    cfg = get_config("mamba2_780m").reduced()
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (2, 64)), jnp.int32)
    outs = []
    for chunk in (16, 32, 64):
        c = cfg.with_(ssm=dataclasses.replace(cfg.ssm, chunk=chunk))
        model = build_model(c)
        params = model.init(jax.random.PRNGKey(0))
        l, _ = jax.jit(model.prefill)(params, {"tokens": tokens})
        outs.append(np.asarray(l))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-5)
