"""Data-mesh invariants (DESIGN.md §15): shard ownership determinism and
minimal movement, exactly-once delivery under any host count, the
host-agnostic global shuffle, mid-epoch repartition (join AND leave)
preserving exactly-once, owned-shards-only I/O, elastic-state resume,
lockstep steps_per_epoch, stats aggregation, and global-array assembly."""

import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.core.spec import RawArrayError
from repro.data import DataLoader, LoaderState, RaDataset
from repro.data.dataset import DatasetBuilder
from repro.distributed.data_mesh import (
    DataMesh,
    EpochPlan,
    aggregate_stats,
    owners_table,
    shard_owners,
)

TOTAL, SHARD_ROWS, W = 320, 16, 2  # 20 shards; row i holds [i, i]


@pytest.fixture(scope="module")
def rid_root(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("mesh") / "ds")
    b = DatasetBuilder(root, {"rid": ((W,), np.int64)}, shard_rows=SHARD_ROWS)
    ids = np.arange(TOTAL, dtype=np.int64)
    b.append(rid=np.stack([ids] * W, axis=1))
    b.finish()
    return root


def _drain(dl, steps):
    out = [next(dl)["rid"][:, 0].copy() for _ in range(steps)]
    return np.concatenate(out) if out else np.empty(0, np.int64)


# ---- ownership ------------------------------------------------------------


def test_ownership_deterministic_and_minimal_movement():
    before = shard_owners(128, ["h0", "h1", "h2", "h3"], epoch=1)
    assert before == shard_owners(128, ["h0", "h1", "h2", "h3"], epoch=1)
    after = shard_owners(128, ["h0", "h1", "h2", "h3", "h4"], epoch=1)
    moved = [(x, y) for x, y in zip(before, after) if x != y]
    # consistent hashing: a new member only RECEIVES shards, and roughly 1/N
    assert 0 < len(moved) <= 64
    assert all(y == "h4" for _, y in moved)


def test_ownership_epoch_redeal(monkeypatch):
    hosts = ["a", "b", "c"]
    assert shard_owners(64, hosts, epoch=0) != shard_owners(64, hosts, epoch=1)
    monkeypatch.setenv("RA_MESH_EPOCH_REOWN", "0")
    assert shard_owners(64, hosts, epoch=0) == shard_owners(64, hosts, epoch=1)


# ---- plan invariants (pure, no dataset) -----------------------------------


@settings(max_examples=25, deadline=None)
@given(nhosts=st.integers(1, 6), seed=st.integers(0, 5), epoch=st.integers(0, 3))
def test_plan_streams_cover_exactly_once(nhosts, seed, epoch):
    shard_rows = [17, 3, 64, 1, 29, 16, 16, 40, 8, 11]
    hosts = [f"h{i}" for i in range(nhosts)]
    plan = EpochPlan(
        shard_rows, seed=seed, epoch=epoch, segments=[(0, hosts)], batch_size=4
    )
    allr = np.concatenate([plan.host_stream(h) for h in hosts])
    assert len(np.unique(allr)) == len(allr) == sum(shard_rows)


def test_global_shuffle_host_agnostic():
    shard_rows = [16] * 12
    hosts = ["a", "b", "c"]
    plans = [
        DataMesh(h, hosts).plan(shard_rows, seed=9, epoch=2, batch_size=4)
        for h in hosts
    ]
    assert len({p.steps() for p in plans}) == 1
    for h in hosts:
        ref = plans[0].host_order(h)
        for p in plans[1:]:
            assert np.array_equal(p.host_order(h), ref)


def test_shuffle_varies_by_epoch():
    mesh = DataMesh("a", ["a", "b"])
    p0 = mesh.plan([16] * 12, seed=1, epoch=0, batch_size=4)
    p1 = mesh.plan([16] * 12, seed=1, epoch=1, batch_size=4)
    assert not np.array_equal(
        p0.host_order("a")[: 4 * min(p0.steps(), p1.steps())],
        p1.host_order("a")[: 4 * min(p0.steps(), p1.steps())],
    )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 5),
    n0=st.integers(1, 4),
    n1=st.integers(1, 5),
    t_frac=st.floats(0.0, 1.0),
)
def test_repartition_plan_preserves_exactly_once(seed, n0, n1, t_frac):
    shard_rows = [16] * 14
    B = 4
    start = [f"h{i}" for i in range(n0)]
    p0 = EpochPlan(shard_rows, seed=seed, epoch=0, segments=[(0, start)], batch_size=B)
    t = int(round(t_frac * p0.steps()))
    new = [f"h{i}" for i in range(n1)]
    plan = EpochPlan(
        shard_rows, seed=seed, epoch=0, segments=[(0, start), (t, new)], batch_size=B
    )
    union = sorted(set(start) | set(new))
    orders = [plan.host_order(h) for h in union]
    allr = np.concatenate([o[o >= 0] for o in orders])
    assert len(np.unique(allr)) == len(allr)  # no row delivered twice
    expected = t * B * len(start) + (plan.steps() - t) * B * len(new)
    assert len(allr) == expected  # no row dropped (vs the segment schedule)
    assert plan.dropped_rows() == sum(shard_rows) - len(allr)


# ---- loader end-to-end ----------------------------------------------------


def test_mesh_epoch_exactly_once_owned_only_byte_exact(rid_root):
    hosts, B = ["a", "b", "c"], 4
    loaders = {
        h: DataLoader(RaDataset(rid_root), B, seed=5, mesh=DataMesh(h, hosts))
        for h in hosts
    }
    spes = {h: dl.steps_per_epoch() for h, dl in loaders.items()}
    assert len(set(spes.values())) == 1  # lockstep across hosts
    n = spes["a"]
    seen = {h: _drain(dl, n) for h, dl in loaders.items()}
    for dl in loaders.values():
        dl.stop()
    allr = np.concatenate(list(seen.values()))
    assert len(np.unique(allr)) == len(allr)
    plan = loaders["a"]._mesh_plan(0)
    assert len(allr) + plan.dropped_rows() == TOTAL
    # a host only ever touches shards it owns (fd/fetch counter witness);
    # the prefetcher legitimately runs ahead into epoch 1's re-dealt deal
    for h, dl in loaders.items():
        owned = set(plan.owned_shards(h)) | set(dl._mesh_plan(1).owned_shards(h))
        assert set(dl.ds.shards_touched()) <= owned
    # byte-exact against a direct gather of the planned order
    ref = RaDataset(rid_root)
    for h in hosts:
        order = plan.host_order(h)[: n * B]
        assert np.array_equal(ref.gather(order)["rid"][:, 0], seen[h])


def test_loader_repartition_join_exactly_once(rid_root):
    start, B, T = ["a", "b"], 4, 3
    loaders = {
        h: DataLoader(RaDataset(rid_root), B, seed=13, mesh=DataMesh(h, start))
        for h in start
    }
    seen = {h: [_drain(loaders[h], 1) for _ in range(T)] for h in start}
    new = ["a", "b", "c"]
    for h in start:
        st_ = loaders[h].repartition(new)
        assert (st_.epoch, st_.step) == (0, T)
    # the joining host rebuilds the schedule from the segment history alone
    segs = loaders["a"].mesh.segments_for(0)
    mesh_c = DataMesh("c", new)
    mesh_c.load_segments(0, segs)
    dl_c = DataLoader(RaDataset(rid_root), B, seed=13, mesh=mesh_c)
    dl_c.seek(0, T)
    loaders["c"] = dl_c
    seen["c"] = []
    spe = loaders["a"].steps_per_epoch()
    assert spe > T
    for h, dl in loaders.items():
        while len(seen[h]) < spe - (T if h == "c" else 0):
            seen[h].append(_drain(dl, 1))
        dl.stop()
    allr = np.concatenate([np.concatenate(v) for v in seen.values()])
    assert len(np.unique(allr)) == len(allr)
    assert len(allr) == T * B * 2 + (spe - T) * B * 3


def test_loader_repartition_leave_exactly_once(rid_root):
    hosts, B, T = ["a", "b", "c"], 4, 2
    loaders = {
        h: DataLoader(RaDataset(rid_root), B, seed=11, mesh=DataMesh(h, hosts))
        for h in hosts
    }
    seen = {h: [_drain(loaders[h], 1) for _ in range(T)] for h in hosts}
    survivors = ["a", "b"]
    for h in survivors:
        assert loaders[h].repartition(survivors).step == T
    loaders["c"].stop()
    spe = loaders["a"].steps_per_epoch()
    for h in survivors:
        while len(seen[h]) < spe:
            seen[h].append(_drain(loaders[h], 1))
        loaders[h].stop()
    allr = np.concatenate([np.concatenate(v) for v in seen.values()])
    assert len(np.unique(allr)) == len(allr)
    assert len(allr) == T * B * 3 + (spe - T) * B * 2


def test_mesh_state_resume_after_repartition(rid_root):
    B = 4
    hosts = ["a", "b"]
    loaders = {
        h: DataLoader(RaDataset(rid_root), B, seed=21, mesh=DataMesh(h, hosts))
        for h in hosts
    }
    for h in hosts:
        _drain(loaders[h], 2)
    for h in hosts:
        loaders[h].repartition(["a"])
    loaders["b"].stop()
    bt = next(loaders["a"])
    st_ = bt["_state"]
    assert st_.mesh_segments == [(0, ("a", "b")), (2, ("a",))]
    # serialization round-trip (what rides in a checkpoint)
    rt = LoaderState.from_dict(st_.to_dict())
    assert rt.__dict__ == st_.__dict__
    follow = next(loaders["a"])
    loaders["a"].stop()
    # a fresh loader + mesh restored from the state reproduces the follower
    dl2 = DataLoader(RaDataset(rid_root), B, seed=21, mesh=DataMesh("a", ["a", "b"]))
    dl2.restore(rt)
    nxt = next(dl2)
    dl2.stop()
    assert nxt["_state"].__dict__ == follow["_state"].__dict__
    assert np.array_equal(nxt["rid"], follow["rid"])


def test_single_host_defaults_byte_identical(rid_root):
    """mesh=None keeps the seed-era contract bit for bit: the epoch order is
    ``default_rng((seed, epoch)).permutation(host_rows)`` sliced per step."""
    ds = RaDataset(rid_root)
    dl = DataLoader(ds, 16, seed=4)
    got = [next(dl) for _ in range(4)]
    dl.stop()
    order = np.random.default_rng((4, 0)).permutation(np.arange(TOTAL))
    for t, bt in enumerate(got):
        assert np.array_equal(bt["rid"][:, 0], order[t * 16 : (t + 1) * 16])
        assert (bt["_state"].epoch, bt["_state"].step) == (0, t)


def test_steps_per_epoch_uniform_nonmesh(rid_root):
    ds = RaDataset(rid_root)
    for hosts in (2, 3, 7):
        spes = {
            DataLoader(ds, 8, host_id=h, host_count=hosts).steps_per_epoch()
            for h in range(hosts)
        }
        assert len(spes) == 1  # remainder host no longer diverges
        spe = spes.pop()
        dl = DataLoader(ds, 8, host_id=hosts - 1, host_count=hosts)
        assert dl.stats()["dropped_tail_rows"] == TOTAL - spe * 8 * hosts


def test_zero_steps_is_sticky_error(rid_root):
    dl = DataLoader(RaDataset(rid_root), TOTAL + 1, mesh=DataMesh("a", ["a"]))
    with pytest.raises(RawArrayError, match="zero steps"):
        next(dl)
    with pytest.raises(RawArrayError):  # sticky, not a hang
        next(dl)
    dl.stop()


# ---- observability --------------------------------------------------------


def test_owners_table_and_racat(rid_root, capsys):
    table = owners_table(rid_root, ["a", "b", "c"])
    assert len(table["shards"]) == TOTAL // SHARD_ROWS
    assert table["total_rows"] == TOTAL
    assert table["total_bytes"] == TOTAL * W * 8
    assert sum(t["bytes"] for t in table["per_host"].values()) == table["total_bytes"]
    assert table["imbalance"] >= 1.0
    # the CLI: zero payload reads, table + totals + imbalance
    from repro.core import racat

    assert racat.main(["owners", rid_root, "--hosts", "3"]) == 0
    out = capsys.readouterr().out
    assert "imbalance" in out and "host0" in out and "shard" in out


def test_aggregate_stats_straggler():
    stats = [
        {"host_id": 0.0, "loader_produce_s": 1.0, "loader_wait_s": 0.1,
         "batches": 10.0, "dropped_tail_rows": 5.0},
        {"host_id": 1.0, "loader_produce_s": 3.0, "loader_wait_s": 0.2,
         "batches": 10.0, "dropped_tail_rows": 5.0},
    ]
    agg = aggregate_stats(stats)
    assert agg["hosts"] == 2.0
    assert agg["batches"] == 20.0
    assert agg["loader_produce_s"] == 4.0
    assert agg["loader_produce_s_max"] == 3.0
    assert agg["straggler_host"] == 1.0
    assert agg["produce_skew"] == 1.5
    assert agg["dropped_tail_rows"] == 5.0  # global: agreed across hosts


def test_loader_stats_are_aggregatable(rid_root):
    hosts = ["a", "b"]
    per = []
    for h in hosts:
        dl = DataLoader(RaDataset(rid_root), 8, seed=2, mesh=DataMesh(h, hosts))
        _drain(dl, 2)
        dl.stop()
        per.append(dl.stats())
    agg = aggregate_stats(per)
    assert agg["hosts"] == 2.0 and agg["batches"] == 4.0
    assert "straggler_host" in agg and "dropped_tail_rows" in agg


# ---- device / global assembly ---------------------------------------------


def test_device_loader_global_single_host(rid_root):
    jax = pytest.importorskip("jax")
    from repro.data import DeviceLoader

    mesh = DataMesh("solo", ["solo"])
    dev = DeviceLoader(DataLoader(RaDataset(rid_root), 8, seed=2, mesh=mesh))
    assert dev.global_arrays
    bt = next(dev)
    assert isinstance(bt["rid"], jax.Array) and bt["rid"].shape == (8, W)
    ref = DataLoader(RaDataset(rid_root), 8, seed=2, mesh=DataMesh("solo", ["solo"]))
    want = next(ref)["rid"]
    ref.stop()
    assert np.array_equal(np.asarray(bt["rid"]), want)
    dev.stop()


_SIM = r"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec
from repro.data import DataLoader, RaDataset
from repro.data.dataset import DatasetBuilder
from repro.distributed.data_mesh import DataMesh

# 64 shards so every one of 4 hosts owns a workable share under the ring
root = os.path.join(os.environ["DS_ROOT"], "sim_ds")
b = DatasetBuilder(root, {"rid": ((2,), np.int64)}, shard_rows=8)
ids = np.arange(512, dtype=np.int64)
b.append(rid=np.stack([ids, ids], axis=1))
b.finish()
hosts = ["h0", "h1", "h2", "h3"]
B = 4
devs = jax.devices()
assert len(devs) == 4, devs
sharding = NamedSharding(Mesh(np.array(devs), ("data",)), PartitionSpec("data"))
loaders = [DataLoader(RaDataset(root), B, seed=3, mesh=DataMesh(h, hosts)) for h in hosts]
spe = loaders[0].steps_per_epoch()
assert spe > 0

@jax.jit
def step(x):  # a collective-shaped reduction over the global batch
    return jnp.sum(x)

for t in range(min(spe, 3)):
    batches = [next(dl) for dl in loaders]
    shards = [jax.device_put(b["rid"], d) for b, d in zip(batches, devs)]
    g = jax.make_array_from_single_device_arrays((B * 4, 2), sharding, shards)
    assert g.shape == (B * 4, 2)
    want = sum(int(b["rid"].sum()) for b in batches)
    assert int(step(g)) == want
for dl in loaders:
    dl.stop()
print("SIM_OK")
"""


def test_global_assembly_simulated_four_hosts(rid_root):
    """Four simulated mesh hosts in one process (forced host-platform device
    count): per-host mesh loaders feed device shards that assemble into one
    global jax.Array consumed by a jitted step."""
    pytest.importorskip("jax")
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["DS_ROOT"] = os.path.dirname(rid_root)  # sim builds its own dataset here
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [src, env.get("PYTHONPATH", "")])
    )
    out = subprocess.run(
        [sys.executable, "-c", _SIM],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0 and "SIM_OK" in out.stdout, (out.stdout, out.stderr)
