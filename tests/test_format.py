"""Core RawArray format: unit + property tests (hypothesis)."""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as ra
from repro.core.spec import FIXED_HEADER_BYTES, MAGIC_BYTES


# ---------------------------------------------------------------- unit
def test_magic_is_ascii_rawarray(tmp_path):
    p = tmp_path / "x.ra"
    ra.write(p, np.zeros(3, np.float32))
    with open(p, "rb") as f:
        assert f.read(8) == MAGIC_BYTES  # od -c shows 'rawarray'


def test_header_layout_matches_paper_table1(tmp_path):
    p = tmp_path / "x.ra"
    arr = np.zeros((6, 2), np.complex64)
    ra.write(p, arr)
    raw = open(p, "rb").read()
    u64 = np.frombuffer(raw[:48], "<u8")
    assert u64[1] == 0            # flags
    assert u64[2] == 4            # eltype: complex
    assert u64[3] == 8            # elbyte: complex64
    assert u64[4] == 6 * 2 * 8    # data_length
    assert u64[5] == 2            # ndims
    dims = np.frombuffer(raw[48:64], "<u8")
    assert list(dims) == [6, 2]
    assert len(raw) == 64 + 96    # header + data, nothing else


def test_file_size_prediction(tmp_path):
    arr = np.zeros((3, 5, 7), np.int16)
    p = tmp_path / "x.ra"
    ra.write(p, arr)
    assert os.path.getsize(p) == ra.nbytes_on_disk(arr)


def test_identical_contents_identical_files(tmp_path):
    """Paper: two RawArray files are identical iff contents identical (no
    timestamps inside)."""
    arr = np.arange(10, dtype=np.float64)
    p1, p2 = tmp_path / "a.ra", tmp_path / "b.ra"
    ra.write(p1, arr)
    import time

    time.sleep(0.01)
    ra.write(p2, arr)
    assert open(p1, "rb").read() == open(p2, "rb").read()


def test_truncation_detected(tmp_path):
    p = tmp_path / "x.ra"
    ra.write(p, np.arange(100, dtype=np.float32))
    blob = open(p, "rb").read()
    open(p, "wb").write(blob[:-10])
    with pytest.raises(ra.RawArrayError, match="truncated"):
        ra.read(p)


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "x.ra"
    open(p, "wb").write(b"notrawarray" + b"\x00" * 64)
    with pytest.raises(ra.RawArrayError, match="magic"):
        ra.read(p)


def test_unknown_flags_rejected_strict(tmp_path):
    p = tmp_path / "x.ra"
    arr = np.zeros(2, np.float32)
    ra.write(p, arr)
    blob = bytearray(open(p, "rb").read())
    blob[8] |= 0x80  # set an unknown flag bit
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ra.RawArrayError, match="flag"):
        ra.read(p)
    # lenient mode reads anyway (forward compat for readers that opt in)
    out = ra.read(p, strict_flags=False)
    assert np.array_equal(out, arr)


def test_crc_detects_corruption(tmp_path):
    p = tmp_path / "x.ra"
    ra.write(p, np.arange(64, dtype=np.float32), crc32=True)
    blob = bytearray(open(p, "rb").read())
    blob[100] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ra.RawArrayError, match="CRC32"):
        ra.read(p)


def test_metadata_append_and_read(tmp_path):
    p = tmp_path / "x.ra"
    ra.write(p, np.zeros(4, np.uint8))
    ra.append_metadata(p, b'{"k": 1}')
    ra.append_metadata(p, b"more")
    assert ra.read_metadata(p) == b'{"k": 1}more'
    assert np.array_equal(ra.read(p), np.zeros(4, np.uint8))


def test_memmap_is_zero_copy_view(tmp_path):
    p = tmp_path / "x.ra"
    arr = np.arange(1000, dtype=np.float32).reshape(10, 100)
    ra.write(p, arr)
    m = ra.memmap(p)
    assert isinstance(m, np.memmap)
    assert np.array_equal(np.asarray(m[3:5]), arr[3:5])
    s = ra.memmap_slice(p, 4, 8)
    assert np.array_equal(np.asarray(s), arr[4:8])


def test_memmap_refuses_compressed(tmp_path):
    p = tmp_path / "x.ra"
    ra.write(p, np.zeros(100, np.float32), compress=True)
    with pytest.raises(ra.RawArrayError, match="compress"):
        ra.memmap(p)


# ---------------------------------------------------------------- property
_DTYPES = ["int8", "uint8", "int16", "uint16", "int32", "uint32", "int64",
           "float16", "float32", "float64", "complex64", "complex128"]


@settings(max_examples=60, deadline=None)
@given(
    dtype=st.sampled_from(_DTYPES),
    shape=st.lists(st.integers(0, 7), min_size=0, max_size=4),
    big_endian=st.booleans(),
    compress=st.booleans(),
    crc=st.booleans(),
    meta=st.binary(max_size=64),
)
def test_roundtrip_property(tmp_path_factory, dtype, shape, big_endian, compress, crc, meta):
    d = tmp_path_factory.mktemp("prop")
    rng = np.random.default_rng(0)
    n = int(np.prod(shape)) if shape else 1
    arr = (rng.integers(0, 100, size=n) - 50).astype(dtype).reshape(shape)
    p = os.path.join(d, "x.ra")
    ra.write(p, arr, big_endian=big_endian, compress=compress, crc32=crc,
             metadata=meta if not crc else None)
    back = ra.read(p)
    assert back.shape == arr.shape
    assert np.array_equal(np.asarray(back, np.complex128), np.asarray(arr, np.complex128))
    hdr = ra.header_of(p)
    assert hdr.ndims == len(shape)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 50),
    cols=st.integers(1, 8),
    nshards=st.integers(1, 8),
    lo_frac=st.floats(0, 1),
    hi_frac=st.floats(0, 1),
)
def test_sharded_slice_property(tmp_path_factory, rows, cols, nshards, lo_frac, hi_frac):
    d = str(tmp_path_factory.mktemp("shard"))
    arr = np.arange(rows * cols, dtype=np.int32).reshape(rows, cols)
    ra.write_sharded(d, arr, nshards=nshards)
    lo = int(lo_frac * rows)
    hi = lo + int(hi_frac * (rows - lo))
    assert np.array_equal(ra.read_slice(d, lo, hi), arr[lo:hi])
    assert np.array_equal(ra.read_sharded(d), arr)


def test_bfloat16_roundtrip(tmp_path):
    import ml_dtypes

    arr = np.linspace(-3, 3, 24, dtype=np.float32).astype(ml_dtypes.bfloat16).reshape(4, 6)
    p = tmp_path / "b.ra"
    ra.write(p, arr)
    hdr = ra.header_of(p)
    assert (hdr.eltype, hdr.elbyte) == (ra.ELTYPE_BRAIN, 2)
    back = ra.read(p)
    assert np.array_equal(back.astype(np.float32), arr.astype(np.float32))


def test_struct_records_roundtrip(tmp_path):
    """Paper: user-defined struct types (eltype 0) — caller reinterprets."""
    sd = np.dtype([("a", "<f4"), ("b", "<i4"), ("c", "<u2")])
    s = np.zeros(7, dtype=sd)
    s["a"] = np.linspace(0, 1, 7)
    s["b"] = np.arange(7)
    p = tmp_path / "s.ra"
    ra.write(p, s)
    hdr = ra.header_of(p)
    assert (hdr.eltype, hdr.elbyte) == (ra.ELTYPE_STRUCT, sd.itemsize)
    back = ra.read(p).view(sd).reshape(s.shape)
    assert np.array_equal(back, s)


# -------------------------------------------- combined flag interactions
def test_zlib_crc_metadata_combined_roundtrip(tmp_path):
    """All beyond-paper extensions in ONE file: zlib payload + CRC32 trailer
    + trailing user metadata must compose (DESIGN.md §7)."""
    p = str(tmp_path / "all.ra")
    arr = np.tile(np.arange(97, dtype=np.float64), 41).reshape(41, 97)
    meta = b'{"origin": "combined-flags-test"}'
    ra.write(p, arr, compress=True, crc32=True, metadata=meta)
    hdr = ra.header_of(p)
    assert hdr.flags & ra.FLAG_ZLIB and hdr.flags & ra.FLAG_CRC32_TRAILER
    assert hdr.data_length < hdr.logical_nbytes  # actually compressed
    back, got_meta = ra.read(p, with_metadata=True)
    assert np.array_equal(back, arr)
    assert got_meta == meta
    assert ra.read_metadata(p) == meta
    # CRC still catches corruption through the combined trailer layout
    blob = bytearray(open(p, "rb").read())
    blob[hdr.nbytes + 5] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ra.RawArrayError, match="CRC32"):
        ra.read(p)


def test_zlib_decompressed_size_verified(tmp_path):
    """A compressed payload whose *decompressed* size disagrees with
    shape x elbyte must be rejected (stored size alone is not enough)."""
    import zlib as _zlib

    from repro.core.header import Header

    p = str(tmp_path / "lie.ra")
    payload = _zlib.compress(np.arange(10, dtype=np.float32).tobytes())
    # header claims 20 elements but the payload decompresses to 10
    hdr = Header(flags=ra.FLAG_ZLIB, eltype=3, elbyte=4,
                 data_length=len(payload), shape=(20,))
    ra.write_like(p, hdr, payload)
    with pytest.raises(ra.RawArrayError, match="[Dd]ecompressed"):
        ra.read(p)


def test_racat_verify_subcommand(tmp_path, capsys):
    from repro.core.racat import main as racat_main

    p = str(tmp_path / "v.ra")
    ra.write(p, np.arange(256, dtype=np.float32), compress=True, crc32=True)
    assert racat_main(["verify", p]) == 0
    blob = bytearray(open(p, "rb").read())
    blob[-3] ^= 0x01  # flip a CRC byte
    open(p, "wb").write(bytes(blob))
    assert racat_main(["verify", p]) == 1
    assert "CRC32" in capsys.readouterr().err
