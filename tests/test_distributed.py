"""Partition specs, sharding rules, MoE invariants, HLO analysis."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import all_arch_ids, get_config
from repro.distributed import optimizer as optim
from repro.distributed.partition import opt_state_specs, param_specs
from repro.launch.hlo_analysis import collective_stats, computation_multipliers, split_computations
from repro.models import build_model

# jax 0.4.x constructs AbstractMesh from (name, size) pairs; newer jax takes
# (axis_sizes, axis_names). Support both so the suite runs across versions.
def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return dict(mesh.shape)[axes]
    n = 1
    for a in axes:
        n *= dict(mesh.shape)[a]
    return n


@pytest.mark.parametrize("arch", all_arch_ids())
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["pod", "multipod"])
def test_param_specs_divide_evenly(arch, mesh):
    """Every spec produced must evenly divide its dim — the invariant that
    makes the dry-run lower."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_specs(shapes, cfg, mesh)

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            assert dim % _axis_size(mesh, axes) == 0, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def test_big_params_actually_sharded():
    """The big matrices must not silently fall back to replicated."""
    cfg = get_config("qwen2.5-14b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_specs(shapes, cfg, MESH)
    flat = {"/".join(str(getattr(k, "key", k)) for k in p): s
            for p, s in jax.tree_util.tree_flatten_with_path(specs)[0]}
    assert flat["embed"] != P(None, None)
    assert any(a is not None for a in tuple(flat["dense_layers/attn/wq"]))
    assert any(a is not None for a in tuple(flat["dense_layers/ffn/w_up"]))


def test_opt_state_specs_match_param_tree():
    cfg = get_config("deepseek-v3-671b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_specs(shapes, cfg, MESH)
    acfg = optim.AdamWConfig(moment_dtype="int8")
    oshapes = jax.eval_shape(lambda: optim.init_state(shapes, acfg))
    ospecs = opt_state_specs(oshapes, pspecs)
    # every quantized moment leaf got a spec tree with q + scale
    def count(t):
        return len(jax.tree_util.tree_leaves(t, is_leaf=lambda x: isinstance(x, P)))
    assert count(ospecs["m"]) == 2 * len(jax.tree_util.tree_leaves(shapes))


# ------------------------------------------------------------- MoE behaviour
def test_moe_gates_normalized_and_capacity_respected():
    import dataclasses

    from repro.models.moe import moe_ffn, route

    cfg = get_config("deepseek-v3-671b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    layer_p = jax.tree_util.tree_map(lambda a: a[0], params["moe_layers"])["ffn"]
    rng = np.random.default_rng(0)
    x2d = jnp.asarray(rng.normal(size=(64, cfg.d_model)), jnp.float32)
    idx, gates, aux = route(layer_p, x2d, cfg)
    assert idx.shape == (64, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, rtol=1e-5)
    assert float(aux) >= 0
    # monkeypatch capacity to 1: output must still be finite (drops happen)
    tight = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=0.01))
    y, aux2 = moe_ffn(layer_p, x2d[None], tight)
    assert np.all(np.isfinite(np.asarray(y)))


def test_moe_chunked_dispatch_equivalent():
    import dataclasses

    cfg = get_config("deepseek-v3-671b").reduced()
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (2, 32)), jnp.int32)
    l1, _ = jax.jit(model.train_loss)(params, {"tokens": tokens})
    cfg2 = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch_chunks=4))
    model2 = build_model(cfg2)
    l2, _ = jax.jit(model2.train_loss)(params, {"tokens": tokens})
    # chunked capacity differs per chunk; with high capacity_factor no drops
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


# ------------------------------------------------------------- HLO analysis
_FAKE_HLO = """\
HloModule test

%inner_body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
}

%inner_cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(4)
}

%outer_body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %w2 = (s32[], f32[8]) while(%t), condition=%inner_cond, body=%inner_body
  %ag = f32[16]{0} all-gather(%y), dimensions={0}
}

%outer_cond (p: (s32[], f32[8])) -> pred[] {
  %c2 = s32[] constant(3)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while(%t0), condition=%outer_cond, body=%outer_body
  %ar2 = f32[32]{0} all-reduce(%a), to_apply=%sum
}
"""


def test_hlo_trip_count_scaling():
    comps = split_computations(_FAKE_HLO)
    assert set(comps) >= {"__entry__", "outer_body", "inner_body", "outer_cond", "inner_cond"}
    mult = computation_multipliers(comps)
    assert mult["outer_body"] == 3
    assert mult["inner_body"] == 12  # 3 x 4
    st = collective_stats(_FAKE_HLO)
    # all-reduce: 12 x 32B (inner) + 1 x 128B (entry) = 512B
    assert st["per_kind"]["all-reduce"]["bytes"] == 12 * 32 + 128
    # all-gather: 3 x 64B
    assert st["per_kind"]["all-gather"]["bytes"] == 3 * 64
