"""Streaming ingest plane (DESIGN.md §11): incremental RaWriter,
ShardedWriter, DatasetBuilder, the remote upload path, and racat ingest.

The load-bearing invariants:

* streamed output is BYTE-IDENTICAL to monolithic ``write()`` for every
  flag combination (plain, crc32, chunked x {raw, zlib}, metadata);
* crash-safety: a writer killed mid-stream (SIGKILL, no cleanup handlers)
  leaves NO partial file visible under the final name;
* finalize-twice / write-after-finalize / finish-after-abort raise;
* the remote PUT session round-trips byte-identically through the existing
  read plane and enforces its token.
"""

import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import repro.core as ra
from repro import remote
from repro.core.io import RaWriter
from repro.core.sharded import ShardedWriter
from repro.data.dataset import DatasetBuilder, RaDataset

TOKEN = "test-ingest-token"


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def writable(tmp_path):
    """(root, base_url) with a live upload-enabled server."""
    root = tmp_path / "served"
    root.mkdir()
    server = remote.serve(str(root), port=0, upload_token=TOKEN)
    try:
        yield str(root), server.url
    finally:
        server.shutdown()
        server.server_close()
        remote.close_readers()
        remote.reset_shared_cache()


FLAG_COMBOS = [
    dict(),
    dict(crc32=True),
    dict(chunked=True, codec="raw", chunk_bytes=4096),
    dict(chunked=True, codec="zlib", chunk_bytes=4096),
    dict(chunked=True, codec="zlib", chunk_bytes=4096, crc32=True),
]


def _stream(w: RaWriter, arr, batches=(1, 7, 64, 3, 200)):
    i = 0
    bi = 0
    while i < len(arr):
        n = batches[bi % len(batches)]
        w.write_rows(arr[i : i + n])
        i += n
        bi += 1


# ----------------------------------------------------------- byte identity
@pytest.mark.parametrize("kw", FLAG_COMBOS)
@pytest.mark.parametrize("meta", [None, b'{"captured": "live"}'])
def test_streamed_byte_identical_to_monolithic(tmp_path, rng, kw, meta):
    arr = rng.integers(0, 1 << 16, size=(531, 37), dtype=np.int64).astype(np.float32)
    mono = tmp_path / "mono.ra"
    streamed = tmp_path / "streamed.ra"
    ra.write(str(mono), arr, metadata=meta, **kw)
    w = RaWriter(str(streamed), arr.dtype, arr.shape[1:], metadata=meta, **kw)
    _stream(w, arr)
    hdr = w.finalize()
    assert mono.read_bytes() == streamed.read_bytes()
    assert hdr.shape == arr.shape
    back = ra.read(str(streamed), with_metadata=meta is not None)
    got = back[0] if meta is not None else back
    assert np.array_equal(np.asarray(got), arr)
    if meta is not None:
        assert back[1] == meta


@pytest.mark.parametrize("kw", [dict(), dict(crc32=True), dict(chunked=True, crc32=True)])
def test_zero_row_stream_matches_empty_write(tmp_path, kw):
    mono = tmp_path / "mono.ra"
    streamed = tmp_path / "streamed.ra"
    ra.write(str(mono), np.empty((0, 9), np.float32), **kw)
    RaWriter(str(streamed), np.float32, (9,), **kw).finalize()
    assert mono.read_bytes() == streamed.read_bytes()


def test_scalar_rows_and_casting(tmp_path):
    """Row shape () → a 1-D file; inputs are cast like the dataset writer."""
    w = RaWriter(str(tmp_path / "v.ra"), np.float32, ())
    w.write_rows(np.arange(5))  # int64 in, cast to float32
    w.write_rows(np.arange(5.0, 8.0))
    w.finalize()
    back = ra.read(str(tmp_path / "v.ra"))
    assert back.dtype == np.float32 and np.array_equal(back, np.arange(8, dtype=np.float32))


def test_wrong_row_shape_rejected(tmp_path):
    w = RaWriter(str(tmp_path / "x.ra"), np.float32, (4,))
    with pytest.raises(ra.RawArrayError, match="row shape"):
        w.write_rows(np.zeros((2, 5), np.float32))
    w.abort()


# ------------------------------------------------------------- crash safety
def test_unfinalized_writer_leaves_no_visible_file(tmp_path):
    w = RaWriter(str(tmp_path / "x.ra"), np.float32, (8,))
    w.write_rows(np.ones((100, 8), np.float32))
    del w  # never finalized
    assert not (tmp_path / "x.ra").exists()


def test_sigkill_mid_stream_leaves_no_partial_file(tmp_path):
    """A writer process killed with SIGKILL (no atexit, no cleanup) must not
    leave a partial file under the final name — only an invisible temp."""
    script = textwrap.dedent(
        f"""
        import numpy as np, os, sys
        sys.path.insert(0, {repr(os.path.join(os.path.dirname(__file__), "..", "src"))})
        from repro.core.io import RaWriter
        w = RaWriter({repr(str(tmp_path / "x.ra"))}, np.float32, (64,), chunked=True)
        batch = np.ones((1024, 64), np.float32)
        while True:
            w.write_rows(batch)
            print("tick", flush=True)
        """
    )
    proc = subprocess.Popen(
        [sys.executable, "-c", script],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE,
    )
    try:
        assert proc.stdout.readline().strip() == b"tick"  # mid-stream for sure
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert not (tmp_path / "x.ra").exists()
    leftovers = [f for f in os.listdir(tmp_path) if ".tmp-" in f]
    assert all(f.startswith(".x.ra.tmp-") for f in leftovers)  # hidden temps only


def test_finalize_twice_and_abort_paths(tmp_path):
    p = tmp_path / "x.ra"
    w = RaWriter(str(p), np.float32, (4,))
    w.write_rows(np.ones((3, 4), np.float32))
    w.finalize()
    with pytest.raises(ra.RawArrayError, match="finalized"):
        w.finalize()
    with pytest.raises(ra.RawArrayError, match="finalized"):
        w.write_rows(np.ones((1, 4), np.float32))
    w.abort()  # no-op after finalize: must NOT delete the published file
    assert p.exists()

    q = tmp_path / "y.ra"
    w = RaWriter(str(q), np.float32, (4,))
    w.write_rows(np.ones((3, 4), np.float32))
    w.abort()
    w.abort()  # idempotent
    assert not q.exists()
    with pytest.raises(ra.RawArrayError, match="aborted"):
        w.finalize()
    assert not [f for f in os.listdir(tmp_path) if ".tmp-" in f]


def test_context_manager_finalizes_or_aborts(tmp_path, rng):
    arr = rng.normal(size=(10, 4)).astype(np.float32)
    with RaWriter(str(tmp_path / "ok.ra"), np.float32, (4,)) as w:
        w.write_rows(arr)
    assert np.array_equal(ra.read(str(tmp_path / "ok.ra")), arr)

    with pytest.raises(RuntimeError):
        with RaWriter(str(tmp_path / "bad.ra"), np.float32, (4,)) as w:
            w.write_rows(arr)
            raise RuntimeError("boom")
    assert not (tmp_path / "bad.ra").exists()


# ------------------------------------------------------------ ShardedWriter
def test_sharded_writer_rolls_and_reads_back(tmp_path, rng):
    arr = rng.normal(size=(777, 16)).astype(np.float32)
    d = str(tmp_path / "st")
    with ShardedWriter(d, np.float32, (16,), shard_rows=200, chunked=True,
                       chunk_bytes=2048) as w:
        for lo in range(0, 777, 31):
            w.write_rows(arr[lo : lo + 31])
    idx = ra.load_index(d)
    assert idx.offsets == (0, 200, 400, 600, 777)
    assert np.array_equal(ra.read_sharded(d), arr)
    assert np.array_equal(ra.read_slice(d, 150, 650), arr[150:650])
    # each shard byte-identical to a monolithic write of its slab
    slab = tmp_path / "slab.ra"
    # stats=True: ShardedWriter defaults stats ON for numeric dtypes (§16)
    ra.write(str(slab), arr[200:400], chunked=True, chunk_bytes=2048, stats=True)
    assert slab.read_bytes() == (tmp_path / "st" / "shard_00001.ra").read_bytes()


def test_sharded_writer_size_threshold(tmp_path, rng):
    arr = rng.normal(size=(1000, 32)).astype(np.float32)  # 128 B rows
    d = str(tmp_path / "st")
    with ShardedWriter(d, np.float32, (32,), shard_bytes=16 * 1024) as w:  # 128 rows
        w.write_rows(arr)
    idx = ra.load_index(d)
    assert len(idx.files) == 8  # ceil(1000 / 128)
    assert np.array_equal(ra.read_sharded(d), arr)


def test_sharded_writer_abort_leaves_no_index(tmp_path):
    d = str(tmp_path / "st")
    w = ShardedWriter(d, np.float32, (8,), shard_rows=10)
    w.write_rows(np.ones((25, 8), np.float32))  # 2 sealed shards + 1 open
    w.abort()
    assert not os.path.exists(os.path.join(d, "index.json"))
    with pytest.raises(ra.RawArrayError, match="aborted"):
        w.finalize()


def test_sharded_writer_empty_matches_write_sharded(tmp_path):
    a = str(tmp_path / "a")
    b = str(tmp_path / "b")
    ra.write_sharded(a, np.empty((0, 4), np.float32), nshards=3)
    ShardedWriter(b, np.float32, (4,), shard_rows=10).finalize()
    assert ra.load_index(a).offsets == ra.load_index(b).offsets == (0, 0)
    assert ra.read_sharded(b).shape == (0, 4)


# ------------------------------------------------------------ DatasetBuilder
def test_dataset_builder_streams_and_rolls(tmp_path, rng):
    root = str(tmp_path / "ds")
    x = rng.normal(size=(500, 12)).astype(np.float32)
    y = rng.integers(0, 10, size=500)
    with DatasetBuilder(root, {"x": ((12,), "float32"), "y": ((), "int64")},
                        shard_rows=150) as b:
        for i in range(0, 500, 7):
            b.append(x=x[i : i + 7], y=y[i : i + 7])
    ds = RaDataset(root)
    assert len(ds) == 500 and len(ds.shards) == 4
    got = ds.rows(140, 160)
    assert np.array_equal(got["x"], x[140:160])
    assert np.array_equal(got["y"], y[140:160])
    # shard files byte-identical to the pre-streaming (monolithic) writer
    mono = tmp_path / "mono.ra"
    # stats=True: DatasetBuilder defaults stats ON for numeric dtypes (§16)
    ra.write(str(mono), x[150:300], stats=True)
    assert mono.read_bytes() == (tmp_path / "ds" / "x_00001.ra").read_bytes()


def test_dataset_builder_add_and_states(tmp_path):
    root = str(tmp_path / "ds")
    b = DatasetBuilder(root, {"v": ((3,), "float32")}, shard_rows=4)
    for i in range(6):
        b.add(v=np.full(3, i, np.float32))
    assert b.rows == 6
    man = b.finish(metadata={"origin": "unit-test"})
    assert man["total_rows"] == 6
    with pytest.raises(ra.RawArrayError, match="finished"):
        b.finish()
    with pytest.raises(ra.RawArrayError, match="finished"):
        b.append(v=np.zeros((1, 3), np.float32))
    assert RaDataset(root).metadata == {"origin": "unit-test"}


def test_dataset_builder_abort_publishes_nothing(tmp_path):
    root = str(tmp_path / "ds")
    b = DatasetBuilder(root, {"v": ((3,), "float32")}, shard_rows=100)
    b.append(v=np.ones((5, 3), np.float32))
    b.abort()
    assert not os.path.exists(os.path.join(root, "manifest.json"))
    assert not [f for f in os.listdir(root) if f.endswith(".ra")]


# ------------------------------------------------------------- remote plane
@pytest.mark.parametrize("kw", FLAG_COMBOS)
def test_remote_writer_byte_identical_roundtrip(writable, tmp_path, rng, kw):
    root, base = writable
    arr = rng.integers(0, 1 << 16, size=(257, 19), dtype=np.int64).astype(np.float32)
    url = f"{base}/up/stream.ra"
    w = remote.RemoteWriter(url, np.float32, (19,), token=TOKEN,
                            metadata=b"remote!", **kw)
    _stream(w, arr)
    w.finalize()
    local = tmp_path / "local.ra"
    ra.write(str(local), arr, metadata=b"remote!", **kw)
    assert local.read_bytes() == open(os.path.join(root, "up", "stream.ra"), "rb").read()
    assert not os.path.exists(os.path.join(root, "up", "stream.ra.part"))
    # through the existing remote read plane
    back, meta = ra.read(url, with_metadata=True)
    assert np.array_equal(back, arr) and meta == b"remote!"


def test_whole_object_put_via_write(writable, tmp_path, rng, monkeypatch):
    root, base = writable
    monkeypatch.setenv("RA_REMOTE_TOKEN", TOKEN)
    arr = rng.normal(size=(64, 8)).astype(np.float32)
    n = ra.write(f"{base}/whole.ra", arr, crc32=True)
    local = tmp_path / "local.ra"
    assert n == ra.write(str(local), arr, crc32=True)
    assert local.read_bytes() == open(os.path.join(root, "whole.ra"), "rb").read()
    assert np.array_equal(ra.read(f"{base}/whole.ra"), arr)


def test_upload_auth_is_enforced(writable, tmp_path):
    _, base = writable
    with pytest.raises(ra.RawArrayError, match="401"):
        remote.upload_bytes(f"{base}/x.ra", b"data", token="wrong-token")
    with pytest.raises(ra.RawArrayError, match="bearer token"):
        remote.upload_bytes(f"{base}/x.ra", b"data", token=None)
    # read-only server: 403 regardless of token
    ro = remote.serve(str(tmp_path), port=0)
    try:
        with pytest.raises(ra.RawArrayError, match="403"):
            remote.upload_bytes(f"{ro.url}/x.ra", b"data", token=TOKEN)
    finally:
        ro.shutdown()
        ro.server_close()


def test_upload_rejects_path_escape(writable):
    _, base = writable
    with pytest.raises(ra.RawArrayError, match="404"):
        remote.upload_bytes(f"{base}/../evil.ra", b"data", token=TOKEN)


def test_remote_abort_removes_part(writable):
    root, base = writable
    w = remote.RemoteWriter(f"{base}/gone.ra", np.float32, (8,), token=TOKEN)
    w.write_rows(np.ones((4, 8), np.float32))
    assert os.path.exists(os.path.join(root, "gone.ra.part"))
    w.abort()
    assert not os.path.exists(os.path.join(root, "gone.ra.part"))
    assert not os.path.exists(os.path.join(root, "gone.ra"))


def test_append_offset_desync_is_loud(writable):
    root, base = writable
    from repro.remote.client import _UploadSink

    s = _UploadSink(f"{base}/clash.ra", token=TOKEN)
    s.append([b"aaaa"])
    # the server loses the session under the writer (crash, cleanup, a
    # competing writer's reset): the next append must 409, never corrupt
    os.unlink(os.path.join(root, "clash.ra.part"))
    with pytest.raises(ra.RawArrayError, match="409"):
        s.append([b"bbbb"])  # writer thinks offset 4; server part is empty
    s.close()


def test_stale_part_does_not_block_new_session(writable, rng):
    """A SIGKILLed predecessor leaves <path>.part server-side; a fresh
    RemoteWriter must reset the session instead of 409ing forever."""
    root, base = writable
    arr = rng.normal(size=(20, 8)).astype(np.float32)
    dead = remote.RemoteWriter(f"{base}/re.ra", np.float32, (8,), token=TOKEN)
    dead.write_rows(arr)
    dead._sink.close()  # vanish without abort/commit (the SIGKILL shape)
    dead._state = "aborted"  # keep __del__ from politely cleaning up
    assert os.path.exists(os.path.join(root, "re.ra.part"))
    with remote.RemoteWriter(f"{base}/re.ra", np.float32, (8,), token=TOKEN) as w:
        w.write_rows(arr)
    assert np.array_equal(ra.read(f"{base}/re.ra"), arr)


def test_checkpoint_save_to_url_roundtrip(writable):
    root, base = writable
    from repro.checkpoint.store import save_checkpoint, load_checkpoint

    params = {"w": np.arange(24, dtype=np.float32).reshape(4, 6),
              "b": np.ones(6, np.float32)}
    os.environ["RA_REMOTE_TOKEN"] = TOKEN
    try:
        final = save_checkpoint(base, 3, params, chunked=True, chunk_bytes=64,
                                extra={"lr": 0.1})
    finally:
        os.environ.pop("RA_REMOTE_TOKEN", None)
    assert final == f"{base}/step_00000003"
    assert os.path.exists(os.path.join(root, "step_00000003", "manifest.json"))
    back, _, extra = load_checkpoint(final, params)
    assert np.array_equal(np.asarray(back["w"]), params["w"])
    assert extra == {"lr": 0.1}


# ------------------------------------------------------------- racat ingest
def test_racat_ingest_concatenates_sources(tmp_path, rng, capsys):
    from repro.core.racat import main as racat

    a = rng.normal(size=(40, 6)).astype(np.float32)
    b = rng.normal(size=(25, 6)).astype(np.float32)
    np.save(str(tmp_path / "a.npy"), a)
    ra.write(str(tmp_path / "b.ra"), b, chunked=True, chunk_bytes=512)
    out = tmp_path / "cat.ra"
    rc = racat(["ingest", str(out), str(tmp_path / "a.npy"), str(tmp_path / "b.ra"),
                "--codec", "zlib", "--chunk-bytes", "256", "--crc32",
                "--batch-rows", "9"])
    assert rc == 0
    mono = tmp_path / "mono.ra"
    ra.write(str(mono), np.concatenate([a, b]), chunked=True, codec="zlib",
             chunk_bytes=256, crc32=True)
    assert mono.read_bytes() == out.read_bytes()
    assert racat(["verify", str(out)]) == 0


def test_racat_ingest_shape_mismatch_fails(tmp_path, rng, capsys):
    from repro.core.racat import main as racat

    np.save(str(tmp_path / "a.npy"), rng.normal(size=(4, 6)).astype(np.float32))
    np.save(str(tmp_path / "b.npy"), rng.normal(size=(4, 7)).astype(np.float32))
    rc = racat(["ingest", str(tmp_path / "o.ra"),
                str(tmp_path / "a.npy"), str(tmp_path / "b.npy")])
    assert rc == 1
    assert not (tmp_path / "o.ra").exists()  # aborted, nothing published


def test_racat_inspect_reports_metadata_length(tmp_path, capsys):
    from repro.core.racat import main as racat

    p = tmp_path / "m.ra"
    ra.write(str(p), np.arange(8, dtype=np.float32), metadata=b"0123456789ab",
             crc32=True)
    assert racat(["inspect", str(p)]) == 0
    out = capsys.readouterr().out
    assert "metadata     12 bytes" in out

    q = tmp_path / "c.ra"
    ra.write(str(q), np.arange(512, dtype=np.float32), metadata=b"xyz",
             chunked=True, chunk_bytes=256)
    assert racat(["inspect", str(q)]) == 0
    out = capsys.readouterr().out
    assert "metadata     3 bytes" in out


def test_racat_help_epilog_lists_subcommands(capsys):
    from repro.core.racat import main as racat

    with pytest.raises(SystemExit) as e:
        racat(["--help"])
    assert e.value.code == 0
    out = capsys.readouterr().out
    for word in ["header", "verify", "compress", "inspect", "ingest", "exit codes"]:
        assert word in out
