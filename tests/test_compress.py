"""Chunked compression codec (DESIGN.md §10) + trailer/CRC read-path
bugfixes: property round-trips, boundary geometry, corruption rejection,
partial reads touching only overlapping chunks, and remote byte-identity."""

import os
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as ra
from repro.core import codec
from repro.core.racat import main as racat_main, verify_file


def _mkfile(tmp_path, name="x.ra"):
    return str(tmp_path / name)


# ------------------------------------------------------------- wire format
def test_chunked_layout_and_flags(tmp_path):
    p = _mkfile(tmp_path)
    arr = np.arange(5000, dtype=np.float32)
    ra.write(p, arr, chunked=True, chunk_bytes=4096)
    hdr = ra.header_of(p)
    assert hdr.flags & ra.FLAG_CHUNKED
    assert hdr.data_length < hdr.logical_nbytes  # actually compressed
    blob = open(p, "rb").read()
    # chunk table magic sits right after the stored payload
    base = hdr.nbytes + hdr.data_length
    assert blob[base : base + 8] == b"rachunks"
    table = codec.ChunkTable.decode(
        blob[base:], logical_nbytes=hdr.logical_nbytes, stored_nbytes=hdr.data_length
    )
    assert table.nchunks == (hdr.logical_nbytes + 4095) // 4096
    assert table.chunk_bytes == 4096
    # stored chunks are packed back-to-back and sum to data_length
    assert table.stored_nbytes == hdr.data_length
    # file ends exactly after the table (no metadata, no CRC)
    assert len(blob) == base + table.nbytes


def test_chunked_mutually_exclusive_with_zlib(tmp_path):
    with pytest.raises(ra.RawArrayError, match="mutually exclusive"):
        ra.write(_mkfile(tmp_path), np.zeros(4), compress=True, chunked=True)


def test_unknown_codec_rejected(tmp_path):
    with pytest.raises(ra.RawArrayError, match="codec"):
        ra.write(_mkfile(tmp_path), np.zeros(4), codec="nope")


# ------------------------------------------------------------- round trips
@settings(max_examples=50, deadline=None)
@given(
    dtype=st.sampled_from(["uint8", "int16", "float32", "float64", "complex64"]),
    shape=st.lists(st.integers(0, 9), min_size=0, max_size=3),
    chunk_bytes=st.sampled_from([4096, 8192, 65536]),
    codec_name=st.sampled_from(["zlib", "raw"]),
    crc=st.booleans(),
    meta=st.binary(max_size=48),
)
def test_chunked_roundtrip_property(tmp_path_factory, dtype, shape, chunk_bytes,
                                    codec_name, crc, meta):
    d = tmp_path_factory.mktemp("chunkprop")
    rng = np.random.default_rng(1)
    n = int(np.prod(shape)) if shape else 1
    arr = (rng.integers(-40, 40, size=n)).astype(dtype).reshape(shape)
    p = os.path.join(d, "x.ra")
    ra.write(p, arr, chunked=True, chunk_bytes=chunk_bytes, codec=codec_name,
             crc32=crc, metadata=meta or None)
    back, got_meta = ra.read(p, with_metadata=True)
    assert back.shape == arr.shape and back.dtype == arr.dtype
    assert np.array_equal(back, arr)
    assert got_meta == meta
    assert ra.read_metadata(p) == meta
    out = np.empty(arr.shape, arr.dtype)
    assert np.array_equal(ra.read_into(p, out), arr)
    assert verify_file(p) == []


@pytest.mark.parametrize("nelem,chunk_bytes", [
    (0, 4096),          # empty payload -> zero chunks
    (1024, 4096),       # exactly one chunk (boundary == array boundary)
    (2048, 4096),       # exactly two chunks
    (2100, 4096),       # last chunk partial
    (1, 4096),          # single element
])
def test_chunked_boundary_geometry(tmp_path, nelem, chunk_bytes):
    p = _mkfile(tmp_path)
    arr = np.arange(nelem, dtype=np.float32)
    ra.write(p, arr, chunked=True, chunk_bytes=chunk_bytes, codec="raw")
    hdr = ra.header_of(p)
    with open(p, "rb") as f:
        table = codec.read_table(f.fileno(), hdr)
    assert table.nchunks == (arr.nbytes + chunk_bytes - 1) // chunk_bytes
    assert np.array_equal(ra.read(p), arr)


def test_chunked_zero_d_roundtrip(tmp_path):
    p = _mkfile(tmp_path)
    ra.write(p, np.float64(2.75), chunked=True)
    back = ra.read(p)
    assert back.shape == () and back == 2.75


def test_chunked_big_endian_roundtrip(tmp_path):
    p = _mkfile(tmp_path)
    arr = np.arange(3000, dtype=np.uint16)
    ra.write(p, arr, chunked=True, chunk_bytes=4096, big_endian=True)
    back = ra.read(p)
    assert back.dtype.byteorder in ("=", "<", "|")
    assert np.array_equal(back, arr)


def test_chunked_refuses_memmap(tmp_path):
    p = _mkfile(tmp_path)
    ra.write(p, np.zeros(100, np.float32), chunked=True)
    with pytest.raises(ra.RawArrayError, match="compress"):
        ra.memmap(p)
    with pytest.raises(ra.RawArrayError, match="compress"):
        ra.memmap_slice(p, 0, 10)


# -------------------------------------------------------------- corruption
def test_corrupt_chunk_crc_rejected(tmp_path):
    p = _mkfile(tmp_path)
    arr = np.arange(8192, dtype=np.float32)
    ra.write(p, arr, chunked=True, chunk_bytes=4096)
    hdr = ra.header_of(p)
    blob = bytearray(open(p, "rb").read())
    blob[hdr.nbytes + 3] ^= 0xFF  # flip one stored byte of chunk 0
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ra.RawArrayError, match="CRC32"):
        ra.read(p)
    assert any("CRC32" in m for m in verify_file(p))
    assert racat_main(["verify", p]) == 1


def test_truncated_chunk_table_rejected(tmp_path):
    p = _mkfile(tmp_path)
    arr = np.arange(8192, dtype=np.float32)
    ra.write(p, arr, chunked=True, chunk_bytes=4096)
    blob = open(p, "rb").read()
    open(p, "wb").write(blob[:-40])  # chop into the table entries
    with pytest.raises(ra.RawArrayError, match="[Tt]runcated"):
        ra.read(p)
    assert verify_file(p) != []


def test_bad_table_magic_rejected(tmp_path):
    p = _mkfile(tmp_path)
    ra.write(p, np.arange(512, dtype=np.float32), chunked=True)
    hdr = ra.header_of(p)
    blob = bytearray(open(p, "rb").read())
    base = hdr.nbytes + hdr.data_length
    blob[base] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ra.RawArrayError, match="magic"):
        ra.read(p)


def test_chunked_strict_flags_for_old_readers(tmp_path):
    """A reader that doesn't know FLAG_CHUNKED must refuse loudly — the
    paper's backward-compatible extension contract."""
    p = _mkfile(tmp_path)
    ra.write(p, np.zeros(64, np.float32), chunked=True)
    hdr = ra.header_of(p)
    assert hdr.flags & ~(ra.FLAG_BIG_ENDIAN | ra.FLAG_CRC32_TRAILER | ra.FLAG_ZLIB)


# ---------------------------------------------------- partial-read locality
def test_sharded_chunked_slice_reads_only_overlapping_chunks(tmp_path):
    d = str(tmp_path / "sh")
    arr = np.arange(1000 * 64, dtype=np.float32).reshape(1000, 64)  # 256 KiB/shard
    ra.write_sharded(d, arr, nshards=1, chunked=True, chunk_bytes=16384)
    # 16 KiB chunks over 256 KiB rows -> 16 chunks; rows 0..10 live in chunk 0
    codec.reset_stats()
    got = ra.read_slice(d, 0, 10)
    assert np.array_equal(got, arr[:10])
    s = codec.stats()
    assert s["chunk_reads"] == 1, s
    codec.reset_stats()
    assert np.array_equal(ra.read_sharded(d), arr)
    assert codec.stats()["chunk_reads"] == 16


def test_sharded_chunked_multi_shard_equivalence(tmp_path):
    d = str(tmp_path / "sh")
    arr = np.arange(777 * 9, dtype=np.int64).reshape(777, 9)
    ra.write_sharded(d, arr, nshards=5, chunked=True, chunk_bytes=4096)
    for lo, hi in [(0, 777), (100, 101), (0, 0), (333, 666)]:
        assert np.array_equal(ra.read_slice(d, lo, hi), arr[lo:hi])
    assert np.array_equal(ra.read_slice_naive(d, 50, 700), arr[50:700])


def test_dataset_chunked_rows_gather_and_counters(tmp_path):
    from repro.data.dataset import RaDataset, RaDatasetWriter

    root = str(tmp_path / "ds")
    rng = np.random.default_rng(7)
    X = rng.normal(size=(400, 32)).astype(np.float32)
    Y = np.arange(400, dtype=np.int64)
    w = RaDatasetWriter(root, {"x": ((32,), "float32"), "y": ((), "int64")},
                        shard_rows=128, chunked=True, chunk_bytes=4096)
    w.append(x=X, y=Y)
    w.finish()
    ds = RaDataset(root)
    r = ds.rows(33, 301)
    assert np.array_equal(r["x"], X[33:301])
    assert np.array_equal(r["y"], Y[33:301])
    idx = rng.permutation(400)[:96]
    codec.reset_stats()
    g = ds.gather(idx)
    assert np.array_equal(g["x"], X[idx])
    assert np.array_equal(g["y"], Y[idx])
    stats = ds.io_stats()
    assert stats.get("chunk_reads", 0) > 0  # chunk counters observable
    # out= reuse (the loader's buffer-ring path)
    out = {"x": np.empty((96, 32), np.float32), "y": np.empty((96,), np.int64)}
    g2 = ds.gather(idx, out=out)
    assert g2["x"] is out["x"] and np.array_equal(out["x"], X[idx])
    ds.close()


def test_sharded_chunked_big_endian_slice_correct(tmp_path):
    """Regression: a big-endian chunked shard must take the decode-and-copy
    fallback, not stream BE bytes into the native-LE output."""
    d = str(tmp_path / "sh")
    arr = np.arange(64, dtype=np.float32).reshape(16, 4)
    os.makedirs(d)
    ra.write(os.path.join(d, "shard_00000.ra"), arr, chunked=True,
             chunk_bytes=4096, big_endian=True)
    from repro.core.sharded import ShardIndex
    idx = ShardIndex(shape=(16, 4), dtype="float32", axis=0,
                     offsets=(0, 16), files=("shard_00000.ra",))
    open(os.path.join(d, "index.json"), "w").write(idx.to_json())
    assert np.array_equal(ra.read_slice(d, 3, 9), arr[3:9])


def test_gather_decodes_each_chunk_once(tmp_path):
    """Regression: a scattered gather must decode each overlapping chunk
    exactly once per field, not once per requested row."""
    from repro.data.dataset import RaDataset, RaDatasetWriter

    root = str(tmp_path / "ds")
    X = np.arange(1000 * 4, dtype=np.float32).reshape(1000, 4)  # 16 B rows
    w = RaDatasetWriter(root, {"x": ((4,), "float32")}, shard_rows=1000,
                        chunked=True, chunk_bytes=4096)  # 256 rows per chunk
    w.append(x=X)
    w.finish()
    ds = RaDataset(root)
    idx = np.arange(0, 200, 4)  # 50 sparse rows, all inside chunk 0
    codec.reset_stats()
    g = ds.gather(idx)
    assert np.array_equal(g["x"], X[idx])
    assert codec.stats()["chunk_reads"] == 1
    # rows spanning all 4 chunks -> exactly 4 decodes
    idx = np.array([0, 300, 600, 900, 1, 301, 601, 901])
    codec.reset_stats()
    g = ds.gather(idx)
    assert np.array_equal(g["x"], X[idx])
    assert codec.stats()["chunk_reads"] == 4
    ds.close()


def test_gather_mixed_chunked_and_plain_fields(tmp_path):
    """A shard mixing a chunked field file with a plain one plans each
    field its own way: chunk decodes for one, coalesced runs/mmap
    leftovers for the other — both byte-correct."""
    from repro.data.dataset import RaDataset, RaDatasetWriter

    root = str(tmp_path / "ds")
    X = np.arange(500 * 8, dtype=np.float32).reshape(500, 8)
    Y = np.arange(500, dtype=np.int64)
    w = RaDatasetWriter(root, {"x": ((8,), "float32"), "y": ((), "int64")},
                        shard_rows=500, chunked=True, chunk_bytes=4096)
    w.append(x=X, y=Y)
    w.finish()
    # rewrite field y plain, same filename: a hand-mixed shard
    ra.write(os.path.join(root, "y_00000.ra"), Y)
    ds = RaDataset(root)
    rng = np.random.default_rng(2)
    idx = rng.permutation(500)[:80]
    g = ds.gather(idx)
    assert np.array_equal(g["x"], X[idx])
    assert np.array_equal(g["y"], Y[idx])
    r = ds.rows(100, 300)
    assert np.array_equal(r["x"], X[100:300])
    assert np.array_equal(r["y"], Y[100:300])
    ds.close()


def test_chunk_bytes_zero_rejected(tmp_path):
    with pytest.raises(ra.RawArrayError, match="positive"):
        ra.write(_mkfile(tmp_path), np.zeros(8, np.float32), chunk_bytes=0)


def test_gather_rows_straddling_chunk_boundary(tmp_path):
    """Rows whose byte span crosses a chunk boundary must assemble from
    both chunks."""
    from repro.data.dataset import RaDataset, RaDatasetWriter

    root = str(tmp_path / "ds")
    # 48-byte rows over 4096-byte chunks: 4096/48 is not integral, so many
    # rows straddle a boundary
    X = np.arange(600 * 12, dtype=np.float32).reshape(600, 12)
    w = RaDatasetWriter(root, {"x": ((12,), "float32")}, shard_rows=600,
                        chunked=True, chunk_bytes=4096)
    w.append(x=X)
    w.finish()
    ds = RaDataset(root)
    rng = np.random.default_rng(5)
    idx = rng.permutation(600)[:128]
    g = ds.gather(idx)
    assert np.array_equal(g["x"], X[idx])
    ds.close()


def test_loader_over_chunked_dataset(tmp_path):
    from repro.data.dataset import RaDataset, RaDatasetWriter
    from repro.data.loader import DataLoader

    root = str(tmp_path / "ds")
    X = np.arange(300 * 8, dtype=np.float32).reshape(300, 8)
    w = RaDatasetWriter(root, {"x": ((8,), "float32")}, shard_rows=100,
                        chunked=True, chunk_bytes=2048)
    w.append(x=X)
    w.finish()
    dl = DataLoader(RaDataset(root), 50, seed=3, reuse_buffers=True)
    seen = [next(dl)["x"].copy() for _ in range(6)]
    dl.stop()
    got = np.sort(np.concatenate(seen).reshape(-1))
    assert np.array_equal(got, np.sort(X.reshape(-1)))
    assert "chunk_reads" in dl.stats()


def test_checkpoint_chunked_roundtrip(tmp_path):
    from repro.checkpoint.store import load_checkpoint, save_checkpoint

    params = {
        "w": np.arange(300 * 40, dtype=np.float32).reshape(300, 40),
        "b": np.ones(11, np.float32),
    }
    ck = save_checkpoint(str(tmp_path / "ck"), 9, params,
                         chunked=True, chunk_bytes=8192, crc32=True)
    hdr = ra.header_of(os.path.join(ck, "param__w.ra"))
    assert hdr.flags & ra.FLAG_CHUNKED
    p2, _, _ = load_checkpoint(ck, params)
    assert np.array_equal(p2["w"], params["w"])
    assert np.array_equal(p2["b"], params["b"])


def test_checkpoint_chunked_restore_resharded(tmp_path):
    """Elastic restore must row-slice a chunked leaf, decoding only the
    overlapping chunks."""
    from repro.checkpoint.store import restore_resharded, save_checkpoint

    w = np.arange(2048 * 16, dtype=np.float32).reshape(2048, 16)  # 64 B rows
    ck = save_checkpoint(str(tmp_path / "ck"), 1, {"w": w},
                         chunked=True, chunk_bytes=16384)  # 256 rows/chunk
    codec.reset_stats()
    got = restore_resharded(ck, "param__w", row_start=100, row_stop=300)
    assert np.array_equal(got, w[100:300])
    assert codec.stats()["chunk_reads"] == 2  # rows 100-300 span chunks 0-1
    assert np.array_equal(
        restore_resharded(ck, "param__w", row_start=0, row_stop=2048), w
    )


# ------------------------------------------------------------------ remote
def test_remote_chunked_byte_identical(tmp_path):
    from repro import remote

    arr = (np.arange(120_000, dtype=np.int64) % 251).astype(np.float32).reshape(120, 1000)
    p = _mkfile(tmp_path, "c.ra")
    ra.write(p, arr, chunked=True, chunk_bytes=32768, metadata=b"rm", crc32=True)
    ra.write_sharded(str(tmp_path / "sh"), arr, nshards=3, chunked=True,
                     chunk_bytes=16384)
    server = remote.serve(str(tmp_path), port=0)
    try:
        url = server.url + "/c.ra"
        got, meta = ra.read(url, with_metadata=True)
        assert got.tobytes() == arr.tobytes() and meta == b"rm"
        assert ra.read_metadata(url) == b"rm"
        out = np.empty_like(arr)
        assert np.array_equal(ra.read_into(url, out), arr)
        assert np.array_equal(ra.read_slice(server.url + "/sh", 17, 103),
                              arr[17:103])
    finally:
        server.shutdown()
        server.server_close()
        remote.close_readers()
        remote.reset_shared_cache()


def test_remote_verify_single_download(tmp_path, monkeypatch):
    """`racat verify <url>` must fetch the file exactly once — no header
    fast path + second full payload download."""
    from repro import remote
    import repro.core.racat as racat_mod

    p = _mkfile(tmp_path, "v.ra")
    ra.write(p, np.arange(4096, dtype=np.float32), chunked=True,
             chunk_bytes=4096, crc32=True)
    server = remote.serve(str(tmp_path), port=0)
    try:
        url = server.url + "/v.ra"
        calls = []
        real = remote.fetch_bytes

        def counting(u, **kw):
            calls.append(u)
            return real(u, **kw)

        monkeypatch.setattr(remote, "fetch_bytes", counting)
        monkeypatch.setattr(
            remote, "get_reader",
            lambda u: pytest.fail("verify must not open a ranged reader"),
        )
        assert racat_mod.main(["verify", url]) == 0
        assert calls == [url]
    finally:
        server.shutdown()
        server.server_close()
        remote.close_readers()
        remote.reset_shared_cache()


# ------------------------------------------------- satellite bugfixes
def test_append_metadata_on_crc32_file(tmp_path):
    """Regression: appended metadata must land BEFORE the 4-byte CRC
    trailer, or readers treat the metadata tail as the checksum."""
    p = _mkfile(tmp_path)
    arr = np.arange(256, dtype=np.float32)
    ra.write(p, arr, crc32=True)
    ra.append_metadata(p, b"abc")
    ra.append_metadata(p, b"def")
    back, meta = ra.read(p, with_metadata=True)  # CRC verifies
    assert np.array_equal(back, arr)
    assert meta == b"abcdef"
    assert ra.read_metadata(p) == b"abcdef"
    assert verify_file(p) == []
    # corruption is still caught after the splice
    blob = bytearray(open(p, "rb").read())
    blob[80] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises(ra.RawArrayError, match="CRC32"):
        ra.read(p)


@pytest.mark.parametrize("kw", [
    {"compress": True},
    {"chunked": True, "chunk_bytes": 4096},
])
def test_append_metadata_on_crc32_compressed_file(tmp_path, kw):
    p = _mkfile(tmp_path)
    arr = np.tile(np.arange(97, dtype=np.float64), 13)
    ra.write(p, arr, crc32=True, metadata=b"m0", **kw)
    ra.append_metadata(p, b"+m1")
    back, meta = ra.read(p, with_metadata=True)
    assert np.array_equal(back, arr)
    assert meta == b"m0+m1"
    assert verify_file(p) == []


def test_read_into_zlib_honors_out(tmp_path):
    """Regression: read_into on a FLAG_ZLIB file must fill the caller's
    buffer (streamed decompressobj, no silent fallback) byte-identically."""
    p = _mkfile(tmp_path)
    arr = np.arange(300_000, dtype=np.float32).reshape(600, 500)
    for kw in [{}, {"crc32": True}]:
        ra.write(p, arr, compress=True, **kw)
        out = np.full_like(arr, -1)
        got = ra.read_into(p, out)
        assert got is out
        assert out.tobytes() == ra.read(p).tobytes()
    # corrupted compressed payload fails the CRC through read_into too
    blob = bytearray(open(p, "rb").read())
    blob[ra.header_of(p).nbytes + 7] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises((ra.RawArrayError, zlib.error)):
        ra.read_into(p, np.empty_like(arr))


def test_read_into_zlib_shape_mismatch_raises(tmp_path):
    p = _mkfile(tmp_path)
    ra.write(p, np.zeros((4, 4), np.float32), compress=True)
    with pytest.raises(ra.RawArrayError, match="out.shape"):
        ra.read_into(p, np.empty((4, 5), np.float32))


# ----------------------------------------------------------------- racat
def test_racat_compress_and_inspect(tmp_path, capsys):
    p = _mkfile(tmp_path)
    q = _mkfile(tmp_path, "y.ra")
    arr = np.tile(np.arange(500, dtype=np.float32), 40)
    ra.write(p, arr, metadata=b"keepme")
    assert racat_main(["compress", p, q, "--chunk-bytes", "8192", "--crc32"]) == 0
    assert np.array_equal(ra.read(q), arr)
    assert ra.read_metadata(q) == b"keepme"
    assert racat_main(["inspect", q]) == 0
    out = capsys.readouterr().out
    assert "rachunks" not in out and "nchunks" in out and "zlib" in out
    assert racat_main(["verify", q]) == 0


def test_codec_registry_roundtrip_all_available(tmp_path):
    rng = np.random.default_rng(0)
    arr = rng.integers(0, 50, size=5000).astype(np.int32)
    for name in ["raw", "zlib"] + (["lzma"] if 4 in codec._by_id else []):
        p = _mkfile(tmp_path, f"{name}.ra")
        ra.write(p, arr, chunked=True, codec=name, chunk_bytes=4096)
        hdr = ra.header_of(p)
        with open(p, "rb") as f:
            t = codec.read_table(f.fileno(), hdr)
        assert codec.get_codec(t.codec_id).name == name
        assert np.array_equal(ra.read(p), arr)


def test_env_knobs(tmp_path, monkeypatch):
    monkeypatch.setenv("RA_CHUNK_BYTES", "4096")
    monkeypatch.setenv("RA_CODEC", "raw")
    p = _mkfile(tmp_path)
    arr = np.arange(3000, dtype=np.float32)
    ra.write(p, arr, chunked=True)
    hdr = ra.header_of(p)
    with open(p, "rb") as f:
        t = codec.read_table(f.fileno(), hdr)
    assert t.chunk_bytes == 4096
    assert codec.get_codec(t.codec_id).name == "raw"
    assert np.array_equal(ra.read(p), arr)
