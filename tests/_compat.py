"""Minimal stand-in for ``hypothesis`` when it isn't installed.

Four seed test modules property-test with hypothesis; the package isn't a
hard dependency of this repo, so ``tests/conftest.py`` falls back to this
shim: each strategy draws deterministic pseudo-random examples (boundary
values first), and ``@given`` turns the test into a fixed example-based
loop. It covers exactly the API surface the suite uses — ``given``,
``settings``, and ``strategies.{integers,floats,booleans,sampled_from,
lists,binary}``.
"""

from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, List

_EXAMPLE_CAP = 15  # keep the fallback suite fast; hypothesis itself runs more


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any], boundary: List[Any]):
        self._draw = draw
        self._boundary = boundary

    def example(self, rng: random.Random, i: int) -> Any:
        if i < len(self._boundary):
            return self._boundary[i]
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        bound = [min_value, max_value] if min_value != max_value else [min_value]
        return _Strategy(lambda r: r.randint(min_value, max_value), bound)

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        bound = [min_value, max_value]
        return _Strategy(lambda r: r.uniform(min_value, max_value), bound)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda r: bool(r.getrandbits(1)), [False, True])

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements), elements[:2])

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(r: random.Random):
            n = r.randint(min_size, max_size)
            return [elem.example(r, len(elem._boundary)) for _ in range(n)]

        bound: List[Any] = []
        if min_size == 0:
            bound.append([])
        bound.append([elem.example(random.Random(0), 0) for _ in range(max(min_size, 1))])
        return _Strategy(draw, bound)

    @staticmethod
    def binary(min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(r: random.Random):
            n = r.randint(min_size, max_size)
            return bytes(r.getrandbits(8) for _ in range(n))

        bound = [b""] if min_size == 0 else []
        return _Strategy(draw, bound)


strategies = _Strategies()


def settings(max_examples: int = _EXAMPLE_CAP, **_ignored):
    """Accepts and mostly ignores hypothesis settings; caps example count."""

    def deco(fn):
        fn._compat_max_examples = min(max_examples, _EXAMPLE_CAP)
        return fn

    return deco


def given(**strategy_kwargs):
    """Run the test once per deterministic example of each strategy kwarg.

    Pytest fixtures in the remaining parameters pass through untouched: the
    wrapper's reported signature drops the strategy-driven arguments.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **fixture_kwargs):
            n = getattr(wrapper, "_compat_max_examples", _EXAMPLE_CAP)
            for i in range(n):
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}:{i}")
                drawn = {k: s.example(rng, i) for k, s in strategy_kwargs.items()}
                fn(*args, **fixture_kwargs, **drawn)

        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items() if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__  # keep pytest from seeing the original signature
        return wrapper

    return deco
