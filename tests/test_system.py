"""End-to-end behaviour: train -> checkpoint -> kill -> resume -> serve,
all on the RawArray data plane (the paper's contribution as a system)."""

import os
import signal

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.store import latest_step
from repro.configs import get_config
from repro.data import DataLoader, RaDataset, make_token_dataset
from repro.distributed.optimizer import AdamWConfig
from repro.models import build_model
from repro.serving import ServeEngine
from repro.train import TrainLoopConfig, train

TINY = get_config("paper_lm").with_(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=256, max_seq=64
)


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("sys") / "ds")
    make_token_dataset(root, n_docs=256, seq_len=32, vocab=TINY.vocab, shard_rows=64)
    return root


def _loop(tmp, steps, ckpt_every=5):
    return TrainLoopConfig(
        steps=steps, ckpt_every=ckpt_every, ckpt_dir=tmp, log_every=1000,
        adamw=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200),
    )


def test_train_reduces_loss_and_checkpoints(dataset, tmp_path):
    model = build_model(TINY)
    loader = DataLoader(RaDataset(dataset), 8, seed=0)
    out = train(model, loader, _loop(str(tmp_path / "ck"), 30), resume=False)
    assert out["steps"] == 30
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])
    assert latest_step(str(tmp_path / "ck")) == 30


def test_resume_continues_identically(dataset, tmp_path):
    """Train 20 straight vs 10 + resume + 10: identical final params."""
    ck1, ck2 = str(tmp_path / "a"), str(tmp_path / "b")
    model = build_model(TINY)

    out_straight = train(
        model, DataLoader(RaDataset(dataset), 8, seed=1), _loop(ck1, 20, ckpt_every=10),
        resume=False,
    )
    train(
        model, DataLoader(RaDataset(dataset), 8, seed=1), _loop(ck2, 10, ckpt_every=10),
        resume=False,
    )
    out_resumed = train(
        model, DataLoader(RaDataset(dataset), 8, seed=1), _loop(ck2, 20, ckpt_every=10),
        resume=True,
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(out_straight["params"]),
        jax.tree_util.tree_leaves(out_resumed["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_preemption_checkpoint_and_restart(dataset, tmp_path):
    """SIGTERM mid-run -> checkpoint flushed; restart resumes past it."""
    ck = str(tmp_path / "ck")
    model = build_model(TINY)
    sent = {"n": 0}

    def bomb(step, metrics):
        if step == 7 and not sent["n"]:
            sent["n"] = 1
            os.kill(os.getpid(), signal.SIGTERM)

    out = train(
        model, DataLoader(RaDataset(dataset), 8, seed=2), _loop(ck, 50),
        resume=False, hooks=[bomb],
    )
    assert out["preempted"]
    assert out["steps"] < 50
    saved = latest_step(ck)
    assert saved is not None and saved >= 7
    out2 = train(model, DataLoader(RaDataset(dataset), 8, seed=2), _loop(ck, saved + 5))
    assert out2["steps"] == saved + 5 and not out2["preempted"]


def test_serve_from_trained_checkpoint(dataset, tmp_path):
    ck = str(tmp_path / "ck")
    model = build_model(TINY)
    train(model, DataLoader(RaDataset(dataset), 8, seed=0), _loop(ck, 10), resume=False)
    step = latest_step(ck)
    engine = ServeEngine(model, checkpoint=os.path.join(ck, f"step_{step:08d}"))
    prompts = np.random.default_rng(0).integers(1, TINY.vocab, (4, 8)).astype(np.int32)
    out = engine.generate(prompts, max_new=8)
    assert out.shape == (4, 8)
    assert np.all((out >= 0) & (out < TINY.vocab))
    # greedy decode must equal the full-prefill oracle
    seq = prompts.copy()
    params = engine.params
    for _ in range(8):
        logits, _ = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(seq)})
        seq = np.concatenate([seq, np.asarray(jnp.argmax(logits, -1))[:, None].astype(np.int32)], 1)
    assert np.array_equal(out, seq[:, 8:])


def test_loader_prefetch_overlaps(dataset):
    """The loader must not starve the consumer (paper's latency story)."""
    import time

    loader = DataLoader(RaDataset(dataset), 8, seed=0, prefetch=4)
    next(loader)
    time.sleep(0.05)  # let prefetch fill
    t0 = time.perf_counter()
    for _ in range(8):
        next(loader)
        time.sleep(0.01)  # simulate compute
    waited = loader.stats()["loader_wait_s"]
    loader.stop()
    assert waited < 0.05
