"""Remote data plane (DESIGN.md §9): byte-range server, parallel-range
client, block cache, and the URL-aware paths through sharded stores,
datasets, the loader, and checkpoint restore.

Everything runs against a real in-process ``ThreadingHTTPServer`` on an
ephemeral loopback port — no fixtures, no mocks, the actual wire."""

import json
import os
import threading
import urllib.request

import numpy as np
import pytest

import repro.core as ra
from repro import remote
from repro.checkpoint import store
from repro.data.dataset import RaDataset, RaDatasetWriter
from repro.data.loader import DataLoader
from repro.remote.cache import BlockCache


@pytest.fixture()
def served(tmp_path):
    """(root, base_url) with a live server; readers/caches reset after."""
    server = remote.serve(str(tmp_path), port=0)
    try:
        yield str(tmp_path), server.url
    finally:
        server.shutdown()
        server.server_close()
        remote.close_readers()
        remote.reset_shared_cache()


def _write(root, name, arr, **kw):
    p = os.path.join(root, name)
    ra.write(p, arr, **kw)
    return p


# ------------------------------------------------------------------ server
def test_range_request_semantics(served):
    root, base = served
    arr = np.arange(4096, dtype=np.uint8)
    _write(root, "x.ra", arr)
    size = os.path.getsize(os.path.join(root, "x.ra"))

    req = urllib.request.Request(f"{base}/x.ra", headers={"Range": "bytes=64-127"})
    with urllib.request.urlopen(req) as resp:
        assert resp.status == 206
        assert resp.headers["Content-Range"] == f"bytes 64-127/{size}"
        body = resp.read()
    assert body == open(os.path.join(root, "x.ra"), "rb").read()[64:128]

    # suffix range
    req = urllib.request.Request(f"{base}/x.ra", headers={"Range": "bytes=-16"})
    with urllib.request.urlopen(req) as resp:
        assert resp.status == 206
        assert resp.read() == open(os.path.join(root, "x.ra"), "rb").read()[-16:]

    # whole entity advertises range support + ETag
    with urllib.request.urlopen(f"{base}/x.ra") as resp:
        assert resp.status == 200
        assert resp.headers["Accept-Ranges"] == "bytes"
        etag = resp.headers["ETag"]
        assert etag

    # If-None-Match revalidation
    req = urllib.request.Request(f"{base}/x.ra", headers={"If-None-Match": etag})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req)
    assert ei.value.code == 304


def test_unsatisfiable_range_is_416(served):
    root, base = served
    _write(root, "x.ra", np.zeros(8, np.uint8))
    size = os.path.getsize(os.path.join(root, "x.ra"))
    req = urllib.request.Request(f"{base}/x.ra", headers={"Range": f"bytes={size + 10}-"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req)
    assert ei.value.code == 416


def test_path_escape_and_missing_are_404(served):
    root, base = served
    for path in ("/nope.ra", "/../../etc/passwd", "/%2e%2e/%2e%2e/etc/passwd"):
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(base + path)
        assert ei.value.code == 404


def test_header_endpoint_json(served):
    root, base = served
    arr = np.zeros((5, 7, 2), np.int16)
    _write(root, "h.ra", arr)
    with urllib.request.urlopen(f"{base}/header/h.ra") as resp:
        d = json.loads(resp.read())
    assert d["shape"] == [5, 7, 2]
    assert d["eltype"] == ra.ELTYPE_INT
    assert d["elbyte"] == 2
    assert d["header_bytes"] == 48 + 8 * 3


# ------------------------------------------------------------------ client
def test_remote_read_matches_local(served):
    root, base = served
    arr = np.random.default_rng(0).normal(size=(513, 37)).astype(np.float32)
    p = _write(root, "x.ra", arr)
    got = ra.read(f"{base}/x.ra")
    assert got.dtype == arr.dtype and np.array_equal(got, ra.read(p))


def test_remote_header_of_fast_path_and_fallback(served):
    root, base = served
    arr = np.zeros((9, 4), np.complex64)
    p = _write(root, "c.ra", arr)
    assert ra.header_of(f"{base}/c.ra") == ra.header_of(p)
    # fallback path (ranged header read) must agree with the endpoint
    reader = remote.get_reader(f"{base}/c.ra")
    from repro.core.header import decode_header

    assert decode_header(reader.read_range(0, min(reader.size, 4096))) == ra.header_of(p)


def test_remote_flagged_payloads_and_metadata(served):
    root, base = served
    arr = np.tile(np.arange(100, dtype=np.float64), 7)
    _write(root, "z.ra", arr, compress=True, crc32=True, metadata=b"tail")
    got, meta = ra.read(f"{base}/z.ra", with_metadata=True)
    assert np.array_equal(got, arr) and meta == b"tail"
    assert ra.read_metadata(f"{base}/z.ra") == b"tail"


def test_remote_read_into_zero_alloc_path(served):
    root, base = served
    arr = np.random.default_rng(2).normal(size=(64, 33)).astype(np.float32)
    _write(root, "r.ra", arr)
    out = np.empty_like(arr)
    res = ra.read_into(f"{base}/r.ra", out)
    assert res is out and np.array_equal(out, arr)
    with pytest.raises(ra.RawArrayError, match="shape"):
        ra.read_into(f"{base}/r.ra", np.empty((3, 3), np.float32))


def test_mmap_side_refuses_urls_and_write_needs_auth(served, monkeypatch):
    """mmap/append stay local-only; ``write`` to a URL now goes through the
    upload plane (DESIGN.md §11) — against this read-only server it must
    fail loudly (403), and without a token it must not even try."""
    root, base = served
    _write(root, "w.ra", np.zeros(4, np.float32))
    url = f"{base}/w.ra"
    monkeypatch.delenv("RA_REMOTE_TOKEN", raising=False)
    with pytest.raises(ra.RawArrayError, match="bearer token"):
        ra.write(url, np.zeros(4, np.float32))
    monkeypatch.setenv("RA_REMOTE_TOKEN", "some-token")
    with pytest.raises(ra.RawArrayError, match="403"):
        ra.write(url, np.zeros(4, np.float32))  # this server is read-only
    with pytest.raises(ra.RawArrayError, match="local-only"):
        ra.memmap(url)
    with pytest.raises(ra.RawArrayError, match="local-only"):
        ra.append_metadata(url, b"x")


def test_naive_single_stream_baseline_equivalence(served):
    root, base = served
    arr = np.random.default_rng(3).integers(0, 255, size=1 << 16).astype(np.uint8)
    _write(root, "n.ra", arr)
    reader = remote.RemoteReader(f"{base}/n.ra", use_cache=False)
    hdr = ra.header_of(f"{base}/n.ra")
    out = np.empty_like(arr)
    reader.pread_into_naive(hdr.nbytes, memoryview(out))
    assert np.array_equal(out, arr)
    reader.close()


# ------------------------------------------------------------- block cache
def test_block_cache_lru_and_counters():
    c = BlockCache(block_bytes=4, capacity_bytes=12)  # 3 blocks max
    assert c.get("t", 0) is None and c.misses == 1
    for i in range(3):
        c.put("t", i, b"abcd")
    assert c.get("t", 0) == b"abcd" and c.hits == 1
    c.put("t", 3, b"efgh")  # evicts block 1 (LRU; 0 was just touched)
    assert c.evictions == 1
    assert c.get("t", 1) is None
    assert c.get("t", 0) == b"abcd" and c.get("t", 3) == b"efgh"
    assert c.nbytes == 12 and len(c) == 3
    s = c.stats()
    assert s["hits"] == 3 and s["misses"] == 2 and s["evictions"] == 1
    c.clear()
    assert len(c) == 0 and c.nbytes == 0


def test_reader_cache_hits_on_reread(served):
    root, base = served
    arr = np.random.default_rng(4).normal(size=(256, 16)).astype(np.float32)
    _write(root, "c.ra", arr)
    cache = BlockCache(block_bytes=4096, capacity_bytes=1 << 22)
    reader = remote.RemoteReader(f"{base}/c.ra", cache=cache)
    hdr = ra.header_of(f"{base}/c.ra")
    out = np.empty_like(arr)
    reader.pread_into(hdr.nbytes, memoryview(out).cast("B"))
    assert np.array_equal(out, arr)
    misses_cold = cache.misses
    assert misses_cold > 0 and cache.hits == 0
    out2 = np.zeros_like(arr)
    reader.pread_into(hdr.nbytes, memoryview(out2).cast("B"))
    assert np.array_equal(out2, arr)
    assert cache.misses == misses_cold  # warm pass never touched the wire
    assert cache.hits >= misses_cold
    reader.close()


def test_cache_tag_isolation():
    c = BlockCache(block_bytes=4, capacity_bytes=1 << 10)
    c.put("a@1", 0, b"aaaa")
    c.put("b@1", 0, b"bbbb")
    assert c.get("a@1", 0) == b"aaaa"
    assert c.invalidate("a@1") == 1
    assert c.get("a@1", 0) is None
    assert c.get("b@1", 0) == b"bbbb"


# ------------------------------------------------ failure modes (no hangs)
def test_truncated_range_raises(served):
    root, base = served
    _write(root, "t.ra", np.zeros(64, np.float32))
    reader = remote.get_reader(f"{base}/t.ra")
    buf = bytearray(1024)
    with pytest.raises(ra.RawArrayError, match="truncated"):
        reader.pread_into(reader.size - 10, memoryview(buf))


def test_dead_server_raises_not_hangs(tmp_path):
    """Connecting to a killed server fails fast with RawArrayError (bounded
    retries, socket timeout) — it must not hang or leak a bare socket error."""
    arr = np.zeros(1024, np.float32)
    ra.write(os.path.join(str(tmp_path), "d.ra"), arr)
    server = remote.serve(str(tmp_path), port=0)
    url = f"{server.url}/d.ra"
    server.shutdown()
    server.server_close()
    with pytest.raises(ra.RawArrayError, match="cannot reach"):
        remote.RemoteReader(url, timeout=5.0, retries=1, use_cache=False)


def test_mid_transfer_disconnect_raises(tmp_path):
    """A server that dies after half the entity must surface RawArrayError
    after bounded retries — never a hang, never silent short data."""
    import http.server

    payload = bytes(range(256)) * 64  # 16 KiB

    class HalfHandler(http.server.BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.0"  # connection closes with the handler

        def log_message(self, *a):
            pass

        def do_HEAD(self):
            self.send_response(200)
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()

        def do_GET(self):
            self.send_response(200)
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload[: len(payload) // 2])
            self.wfile.flush()
            self.connection.close()  # mid-entity disconnect

    srv = http.server.ThreadingHTTPServer(("127.0.0.1", 0), HalfHandler)
    srv.daemon_threads = True
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        url = f"http://127.0.0.1:{srv.server_address[1]}/x"
        reader = remote.RemoteReader(url, timeout=5.0, retries=1, use_cache=False)
        buf = bytearray(len(payload))
        with pytest.raises(ra.RawArrayError, match="failed"):
            reader.pread_into(0, memoryview(buf))
        reader.close()
    finally:
        srv.shutdown()
        srv.server_close()


def test_etag_change_mid_session_detected(served):
    root, base = served
    p = _write(root, "e.ra", np.arange(4096, dtype=np.float32))
    reader = remote.RemoteReader(f"{base}/e.ra", use_cache=False, retries=0)
    # rewrite the file: same size, different mtime → different ETag
    os.utime(p, ns=(os.stat(p).st_mtime_ns + 10**9,) * 2)
    buf = bytearray(64)
    with pytest.raises(ra.RawArrayError, match="changed on server"):
        reader.pread_into(0, memoryview(buf))
    reader.close()


# ----------------------------------------------------- data plane over HTTP
def test_sharded_read_slice_remote(served):
    root, base = served
    arr = np.random.default_rng(5).normal(size=(300, 9)).astype(np.float32)
    ra.write_sharded(os.path.join(root, "sh"), arr, nshards=4)
    url = f"{base}/sh"
    assert np.array_equal(ra.read_slice(url, 37, 255), arr[37:255])
    assert np.array_equal(ra.read_slice_naive(url, 37, 255), arr[37:255])
    assert np.array_equal(ra.read_sharded(url), arr)
    with pytest.raises(ra.RawArrayError, match="local-only"):
        ra.write_sharded(url, arr, nshards=2)


def _make_dataset(root, rows=200, shard_rows=64, seed=6):
    rng = np.random.default_rng(seed)
    w = RaDatasetWriter(
        os.path.join(root, "ds"),
        {"tok": ((8,), "uint32"), "y": ((), "float32")},
        shard_rows=shard_rows,
    )
    w.append(
        tok=rng.integers(0, 1000, size=(rows, 8)).astype(np.uint32),
        y=rng.normal(size=rows).astype(np.float32),
    )
    w.finish()
    return os.path.join(root, "ds")


def test_dataset_rows_and_gather_remote(served):
    root, base = served
    local = RaDataset(_make_dataset(root))
    rem = RaDataset(f"{base}/ds")
    assert rem.is_remote and rem.total_rows == local.total_rows
    for f in ("tok", "y"):
        assert np.array_equal(rem.rows(30, 170)[f], local.rows(30, 170)[f])
    idx = np.random.default_rng(7).permutation(local.total_rows)[:90]
    gl, gr = local.gather(idx), rem.gather(idx)
    for f in ("tok", "y"):
        assert np.array_equal(gr[f], gl[f])
    stats = rem.io_stats()
    assert stats.get("misses", 0) > 0
    with pytest.raises(ra.RawArrayError, match="remote"):
        rem.gather_naive(idx[:4])
    rem.close()
    local.close()


def test_loader_streams_remote_batches(served):
    root, base = served
    _make_dataset(root, rows=256)
    local = RaDataset(os.path.join(root, "ds"))
    rem = RaDataset(f"{base}/ds")
    dl_r = DataLoader(rem, batch_size=32, seed=11, prefetch=1)
    dl_l = DataLoader(local, batch_size=32, seed=11, prefetch=1)
    try:
        for _ in range(4):
            br, bl = next(dl_r), next(dl_l)
            for f in ("tok", "y"):
                assert np.array_equal(br[f], bl[f])
    finally:
        dl_r.stop()
        dl_l.stop()
    assert "remote_cache_hits" in dl_r.stats()
    with pytest.raises(ValueError, match="naive"):
        DataLoader(rem, batch_size=8, naive=True)
    rem.close()
    local.close()


def test_checkpoint_remote_restore(served):
    root, base = served
    rng = np.random.default_rng(8)
    params = {
        "w": rng.normal(size=(96, 17)).astype(np.float32),
        "b": rng.normal(size=(17,)).astype(np.float32),
    }
    final = store.save_checkpoint(os.path.join(root, "ck"), 42, params)
    url = f"{base}/{os.path.relpath(final, root)}"
    like = {k: np.empty_like(v) for k, v in params.items()}
    got, _, _ = store.load_checkpoint(url, like)
    for k in params:
        assert np.array_equal(got[k], params[k])
    sl = store.restore_resharded(url, "param__w", row_start=20, row_stop=50)
    assert np.array_equal(sl, params["w"][20:50])
    # saves to a URL go through the upload plane (DESIGN.md §11) — against
    # this READ-ONLY server they must fail loudly, not half-publish
    with pytest.raises(ra.RawArrayError, match="bearer token|403"):
        store.save_checkpoint(base, 43, params)


def test_racat_over_http(served, capsys):
    from repro.core.racat import main as racat_main

    root, base = served
    _write(root, "v.ra", np.arange(64, dtype=np.float32), crc32=True)
    url = f"{base}/v.ra"
    assert racat_main(["header", url]) == 0
    assert "float" in capsys.readouterr().out
    assert racat_main(["verify", url]) == 0
    # corrupt on disk; verify over HTTP must fail
    p = os.path.join(root, "v.ra")
    blob = bytearray(open(p, "rb").read())
    blob[60] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    assert racat_main(["verify", url]) == 1


def test_literal_header_directory_not_shadowed(served):
    """A real file under a directory literally named 'header/' must serve
    its bytes — the /header/ JSON fast path only answers when no such file
    exists (the client falls back to a ranged header read on non-JSON)."""
    root, base = served
    os.makedirs(os.path.join(root, "header"), exist_ok=True)
    arr = np.arange(32, dtype=np.float32)
    ra.write(os.path.join(root, "header", "x.ra"), arr)
    assert np.array_equal(ra.read(f"{base}/header/x.ra"), arr)
    assert ra.header_of(f"{base}/header/x.ra").shape == (32,)


# ------------------------------------------------------- auth fail-fast (§11)
import http.server as _http_server


class _DenyingHandler(_http_server.BaseHTTPRequestHandler):
    """Answers EVERY request with a fixed auth-failure status and counts
    them — the shape of a token-auth plane rejecting a credential."""

    def _deny(self):
        self.server.hits += 1  # type: ignore[attr-defined]
        body = b"denied\n"
        self.send_response(self.server.deny_status)  # type: ignore[attr-defined]
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    do_GET = do_HEAD = do_PUT = _deny

    def log_message(self, fmt, *args):
        pass


@pytest.fixture(params=[401, 403])
def denying_server(request):
    srv = _http_server.ThreadingHTTPServer(("127.0.0.1", 0), _DenyingHandler)
    srv.deny_status = request.param
    srv.hits = 0
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        yield srv, f"http://127.0.0.1:{srv.server_address[1]}"
    finally:
        srv.shutdown()
        srv.server_close()
        remote.close_readers()


def test_auth_rejection_fails_fast_on_reads(denying_server):
    """401/403 must raise ``RemoteAuthError`` after exactly ONE request —
    a rejected credential is permanent, so the retry budget (which exists
    for transient transport faults) must not be burned on it."""
    srv, base = denying_server
    with pytest.raises(remote.RemoteAuthError, match=str(srv.deny_status)):
        remote.RemoteReader(f"{base}/x.ra", retries=5)
    assert srv.hits == 1  # HEAD stat: one attempt, not retries+1

    srv.hits = 0
    with pytest.raises(remote.RemoteAuthError, match="token"):
        remote.fetch_bytes(f"{base}/manifest.json", retries=5)
    assert srv.hits == 1


def test_auth_rejection_fails_fast_on_ranged_get(denying_server):
    """A reader whose stat succeeded but whose GETs are rejected (token
    revoked mid-session) also fails fast on the ranged read itself."""
    srv, base = denying_server
    reader = remote.RemoteReader.__new__(remote.RemoteReader)
    # hand-build just enough state to drive _ranged_into directly
    from repro.remote.client import _ConnPool
    from urllib.parse import urlsplit

    parts = urlsplit(f"{base}/x.ra")
    reader.url = f"{base}/x.ra"
    reader._path = parts.path
    reader.retries = 5
    reader._pool = _ConnPool(parts.scheme, parts.hostname, parts.port, 2, 5.0)
    reader.etag = None
    reader.size = 1 << 20
    with pytest.raises(remote.RemoteAuthError, match=str(srv.deny_status)):
        reader._ranged_into(0, memoryview(bytearray(64)))
    assert srv.hits == 1
    reader._pool.close()


def test_auth_rejection_fails_fast_on_uploads(denying_server):
    """Uploads against a rejecting server: one PUT, clear auth error,
    no retry burn (upload_bytes would otherwise blind-retry)."""
    srv, base = denying_server
    with pytest.raises(remote.RemoteAuthError, match="token"):
        remote.upload_bytes(f"{base}/up.ra", b"payload", token="bad", retries=5)
    assert srv.hits == 1


def test_auth_error_is_rawarray_error(denying_server):
    """RemoteAuthError stays catch-compatible with every existing caller
    that handles RawArrayError."""
    srv, base = denying_server
    assert issubclass(remote.RemoteAuthError, ra.RawArrayError)
    with pytest.raises(ra.RawArrayError):
        remote.fetch_bytes(f"{base}/x")
