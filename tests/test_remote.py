"""Remote data plane (DESIGN.md §9): byte-range server, parallel-range
client, block cache, and the URL-aware paths through sharded stores,
datasets, the loader, and checkpoint restore.

Everything runs against a real in-process ``ThreadingHTTPServer`` on an
ephemeral loopback port — no fixtures, no mocks, the actual wire."""

import json
import os
import threading
import urllib.request

import numpy as np
import pytest

import repro.core as ra
from repro import remote
from repro.checkpoint import store
from repro.data.dataset import RaDataset, RaDatasetWriter
from repro.data.loader import DataLoader
from repro.remote.cache import BlockCache


@pytest.fixture()
def served(tmp_path):
    """(root, base_url) with a live server; readers/caches reset after."""
    server = remote.serve(str(tmp_path), port=0)
    try:
        yield str(tmp_path), server.url
    finally:
        server.shutdown()
        server.server_close()
        remote.close_readers()
        remote.reset_shared_cache()


def _write(root, name, arr, **kw):
    p = os.path.join(root, name)
    ra.write(p, arr, **kw)
    return p


# ------------------------------------------------------------------ server
def test_range_request_semantics(served):
    root, base = served
    arr = np.arange(4096, dtype=np.uint8)
    _write(root, "x.ra", arr)
    size = os.path.getsize(os.path.join(root, "x.ra"))

    req = urllib.request.Request(f"{base}/x.ra", headers={"Range": "bytes=64-127"})
    with urllib.request.urlopen(req) as resp:
        assert resp.status == 206
        assert resp.headers["Content-Range"] == f"bytes 64-127/{size}"
        body = resp.read()
    assert body == open(os.path.join(root, "x.ra"), "rb").read()[64:128]

    # suffix range
    req = urllib.request.Request(f"{base}/x.ra", headers={"Range": "bytes=-16"})
    with urllib.request.urlopen(req) as resp:
        assert resp.status == 206
        assert resp.read() == open(os.path.join(root, "x.ra"), "rb").read()[-16:]

    # whole entity advertises range support + ETag
    with urllib.request.urlopen(f"{base}/x.ra") as resp:
        assert resp.status == 200
        assert resp.headers["Accept-Ranges"] == "bytes"
        etag = resp.headers["ETag"]
        assert etag

    # If-None-Match revalidation
    req = urllib.request.Request(f"{base}/x.ra", headers={"If-None-Match": etag})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req)
    assert ei.value.code == 304


def test_unsatisfiable_range_is_416(served):
    root, base = served
    _write(root, "x.ra", np.zeros(8, np.uint8))
    size = os.path.getsize(os.path.join(root, "x.ra"))
    req = urllib.request.Request(f"{base}/x.ra", headers={"Range": f"bytes={size + 10}-"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req)
    assert ei.value.code == 416


def test_path_escape_and_missing_are_404(served):
    root, base = served
    for path in ("/nope.ra", "/../../etc/passwd", "/%2e%2e/%2e%2e/etc/passwd"):
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(base + path)
        assert ei.value.code == 404


def test_header_endpoint_json(served):
    root, base = served
    arr = np.zeros((5, 7, 2), np.int16)
    _write(root, "h.ra", arr)
    with urllib.request.urlopen(f"{base}/header/h.ra") as resp:
        d = json.loads(resp.read())
    assert d["shape"] == [5, 7, 2]
    assert d["eltype"] == ra.ELTYPE_INT
    assert d["elbyte"] == 2
    assert d["header_bytes"] == 48 + 8 * 3


# ------------------------------------------------------------------ client
def test_remote_read_matches_local(served):
    root, base = served
    arr = np.random.default_rng(0).normal(size=(513, 37)).astype(np.float32)
    p = _write(root, "x.ra", arr)
    got = ra.read(f"{base}/x.ra")
    assert got.dtype == arr.dtype and np.array_equal(got, ra.read(p))


def test_remote_header_of_fast_path_and_fallback(served):
    root, base = served
    arr = np.zeros((9, 4), np.complex64)
    p = _write(root, "c.ra", arr)
    assert ra.header_of(f"{base}/c.ra") == ra.header_of(p)
    # fallback path (ranged header read) must agree with the endpoint
    reader = remote.get_reader(f"{base}/c.ra")
    from repro.core.header import decode_header

    assert decode_header(reader.read_range(0, min(reader.size, 4096))) == ra.header_of(p)


def test_remote_flagged_payloads_and_metadata(served):
    root, base = served
    arr = np.tile(np.arange(100, dtype=np.float64), 7)
    _write(root, "z.ra", arr, compress=True, crc32=True, metadata=b"tail")
    got, meta = ra.read(f"{base}/z.ra", with_metadata=True)
    assert np.array_equal(got, arr) and meta == b"tail"
    assert ra.read_metadata(f"{base}/z.ra") == b"tail"


def test_remote_read_into_zero_alloc_path(served):
    root, base = served
    arr = np.random.default_rng(2).normal(size=(64, 33)).astype(np.float32)
    _write(root, "r.ra", arr)
    out = np.empty_like(arr)
    res = ra.read_into(f"{base}/r.ra", out)
    assert res is out and np.array_equal(out, arr)
    with pytest.raises(ra.RawArrayError, match="shape"):
        ra.read_into(f"{base}/r.ra", np.empty((3, 3), np.float32))


def test_mmap_side_refuses_urls_and_write_needs_auth(served, monkeypatch):
    """mmap/append stay local-only; ``write`` to a URL now goes through the
    upload plane (DESIGN.md §11) — against this read-only server it must
    fail loudly (403), and without a token it must not even try."""
    root, base = served
    _write(root, "w.ra", np.zeros(4, np.float32))
    url = f"{base}/w.ra"
    monkeypatch.delenv("RA_REMOTE_TOKEN", raising=False)
    with pytest.raises(ra.RawArrayError, match="bearer token"):
        ra.write(url, np.zeros(4, np.float32))
    monkeypatch.setenv("RA_REMOTE_TOKEN", "some-token")
    with pytest.raises(ra.RawArrayError, match="403"):
        ra.write(url, np.zeros(4, np.float32))  # this server is read-only
    with pytest.raises(ra.RawArrayError, match="local-only"):
        ra.memmap(url)
    with pytest.raises(ra.RawArrayError, match="local-only"):
        ra.append_metadata(url, b"x")


def test_naive_single_stream_baseline_equivalence(served):
    root, base = served
    arr = np.random.default_rng(3).integers(0, 255, size=1 << 16).astype(np.uint8)
    _write(root, "n.ra", arr)
    reader = remote.RemoteReader(f"{base}/n.ra", use_cache=False)
    hdr = ra.header_of(f"{base}/n.ra")
    out = np.empty_like(arr)
    reader.pread_into_naive(hdr.nbytes, memoryview(out))
    assert np.array_equal(out, arr)
    reader.close()


# ------------------------------------------------------------- block cache
def test_block_cache_lru_and_counters():
    c = BlockCache(block_bytes=4, capacity_bytes=12)  # 3 blocks max
    assert c.get("t", 0) is None and c.misses == 1
    for i in range(3):
        c.put("t", i, b"abcd")
    assert c.get("t", 0) == b"abcd" and c.hits == 1
    c.put("t", 3, b"efgh")  # evicts block 1 (LRU; 0 was just touched)
    assert c.evictions == 1
    assert c.get("t", 1) is None
    assert c.get("t", 0) == b"abcd" and c.get("t", 3) == b"efgh"
    assert c.nbytes == 12 and len(c) == 3
    s = c.stats()
    assert s["hits"] == 3 and s["misses"] == 2 and s["evictions"] == 1
    c.clear()
    assert len(c) == 0 and c.nbytes == 0


def test_reader_cache_hits_on_reread(served):
    root, base = served
    arr = np.random.default_rng(4).normal(size=(256, 16)).astype(np.float32)
    _write(root, "c.ra", arr)
    cache = BlockCache(block_bytes=4096, capacity_bytes=1 << 22)
    reader = remote.RemoteReader(f"{base}/c.ra", cache=cache)
    hdr = ra.header_of(f"{base}/c.ra")
    out = np.empty_like(arr)
    reader.pread_into(hdr.nbytes, memoryview(out).cast("B"))
    assert np.array_equal(out, arr)
    misses_cold = cache.misses
    assert misses_cold > 0 and cache.hits == 0
    out2 = np.zeros_like(arr)
    reader.pread_into(hdr.nbytes, memoryview(out2).cast("B"))
    assert np.array_equal(out2, arr)
    assert cache.misses == misses_cold  # warm pass never touched the wire
    assert cache.hits >= misses_cold
    reader.close()


def test_cache_tag_isolation():
    c = BlockCache(block_bytes=4, capacity_bytes=1 << 10)
    c.put("a@1", 0, b"aaaa")
    c.put("b@1", 0, b"bbbb")
    assert c.get("a@1", 0) == b"aaaa"
    assert c.invalidate("a@1") == 1
    assert c.get("a@1", 0) is None
    assert c.get("b@1", 0) == b"bbbb"


# ------------------------------------------------ failure modes (no hangs)
def test_truncated_range_raises(served):
    root, base = served
    _write(root, "t.ra", np.zeros(64, np.float32))
    reader = remote.get_reader(f"{base}/t.ra")
    buf = bytearray(1024)
    with pytest.raises(ra.RawArrayError, match="truncated"):
        reader.pread_into(reader.size - 10, memoryview(buf))


def test_dead_server_raises_not_hangs(tmp_path):
    """Connecting to a killed server fails fast with RawArrayError (bounded
    retries, socket timeout) — it must not hang or leak a bare socket error."""
    arr = np.zeros(1024, np.float32)
    ra.write(os.path.join(str(tmp_path), "d.ra"), arr)
    server = remote.serve(str(tmp_path), port=0)
    url = f"{server.url}/d.ra"
    server.shutdown()
    server.server_close()
    with pytest.raises(ra.RawArrayError, match="cannot reach"):
        remote.RemoteReader(url, timeout=5.0, retries=1, use_cache=False)


def test_mid_transfer_disconnect_raises(tmp_path):
    """A server that dies after half the entity must surface RawArrayError
    after bounded retries — never a hang, never silent short data."""
    import http.server

    payload = bytes(range(256)) * 64  # 16 KiB

    class HalfHandler(http.server.BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.0"  # connection closes with the handler

        def log_message(self, *a):
            pass

        def do_HEAD(self):
            self.send_response(200)
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()

        def do_GET(self):
            self.send_response(200)
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload[: len(payload) // 2])
            self.wfile.flush()
            self.connection.close()  # mid-entity disconnect

    srv = http.server.ThreadingHTTPServer(("127.0.0.1", 0), HalfHandler)
    srv.daemon_threads = True
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        url = f"http://127.0.0.1:{srv.server_address[1]}/x"
        reader = remote.RemoteReader(url, timeout=5.0, retries=1, use_cache=False)
        buf = bytearray(len(payload))
        with pytest.raises(ra.RawArrayError, match="failed"):
            reader.pread_into(0, memoryview(buf))
        reader.close()
    finally:
        srv.shutdown()
        srv.server_close()


def test_etag_change_mid_session_detected(served):
    root, base = served
    p = _write(root, "e.ra", np.arange(4096, dtype=np.float32))
    reader = remote.RemoteReader(f"{base}/e.ra", use_cache=False, retries=0)
    # rewrite the file: same size, different mtime → different ETag
    os.utime(p, ns=(os.stat(p).st_mtime_ns + 10**9,) * 2)
    buf = bytearray(64)
    with pytest.raises(ra.RawArrayError, match="changed on server"):
        reader.pread_into(0, memoryview(buf))
    reader.close()


# ----------------------------------------------------- data plane over HTTP
def test_sharded_read_slice_remote(served):
    root, base = served
    arr = np.random.default_rng(5).normal(size=(300, 9)).astype(np.float32)
    ra.write_sharded(os.path.join(root, "sh"), arr, nshards=4)
    url = f"{base}/sh"
    assert np.array_equal(ra.read_slice(url, 37, 255), arr[37:255])
    assert np.array_equal(ra.read_slice_naive(url, 37, 255), arr[37:255])
    assert np.array_equal(ra.read_sharded(url), arr)
    with pytest.raises(ra.RawArrayError, match="local-only"):
        ra.write_sharded(url, arr, nshards=2)


def _make_dataset(root, rows=200, shard_rows=64, seed=6):
    rng = np.random.default_rng(seed)
    w = RaDatasetWriter(
        os.path.join(root, "ds"),
        {"tok": ((8,), "uint32"), "y": ((), "float32")},
        shard_rows=shard_rows,
    )
    w.append(
        tok=rng.integers(0, 1000, size=(rows, 8)).astype(np.uint32),
        y=rng.normal(size=rows).astype(np.float32),
    )
    w.finish()
    return os.path.join(root, "ds")


def test_dataset_rows_and_gather_remote(served):
    root, base = served
    local = RaDataset(_make_dataset(root))
    rem = RaDataset(f"{base}/ds")
    assert rem.is_remote and rem.total_rows == local.total_rows
    for f in ("tok", "y"):
        assert np.array_equal(rem.rows(30, 170)[f], local.rows(30, 170)[f])
    idx = np.random.default_rng(7).permutation(local.total_rows)[:90]
    gl, gr = local.gather(idx), rem.gather(idx)
    for f in ("tok", "y"):
        assert np.array_equal(gr[f], gl[f])
    stats = rem.io_stats()
    assert stats.get("misses", 0) > 0
    with pytest.raises(ra.RawArrayError, match="remote"):
        rem.gather_naive(idx[:4])
    rem.close()
    local.close()


def test_loader_streams_remote_batches(served):
    root, base = served
    _make_dataset(root, rows=256)
    local = RaDataset(os.path.join(root, "ds"))
    rem = RaDataset(f"{base}/ds")
    dl_r = DataLoader(rem, batch_size=32, seed=11, prefetch=1)
    dl_l = DataLoader(local, batch_size=32, seed=11, prefetch=1)
    try:
        for _ in range(4):
            br, bl = next(dl_r), next(dl_l)
            for f in ("tok", "y"):
                assert np.array_equal(br[f], bl[f])
    finally:
        dl_r.stop()
        dl_l.stop()
    assert "remote_cache_hits" in dl_r.stats()
    with pytest.raises(ValueError, match="naive"):
        DataLoader(rem, batch_size=8, naive=True)
    rem.close()
    local.close()


def test_checkpoint_remote_restore(served):
    root, base = served
    rng = np.random.default_rng(8)
    params = {
        "w": rng.normal(size=(96, 17)).astype(np.float32),
        "b": rng.normal(size=(17,)).astype(np.float32),
    }
    final = store.save_checkpoint(os.path.join(root, "ck"), 42, params)
    url = f"{base}/{os.path.relpath(final, root)}"
    like = {k: np.empty_like(v) for k, v in params.items()}
    got, _, _ = store.load_checkpoint(url, like)
    for k in params:
        assert np.array_equal(got[k], params[k])
    sl = store.restore_resharded(url, "param__w", row_start=20, row_stop=50)
    assert np.array_equal(sl, params["w"][20:50])
    # saves to a URL go through the upload plane (DESIGN.md §11) — against
    # this READ-ONLY server they must fail loudly, not half-publish
    with pytest.raises(ra.RawArrayError, match="bearer token|403"):
        store.save_checkpoint(base, 43, params)


def test_racat_over_http(served, capsys):
    from repro.core.racat import main as racat_main

    root, base = served
    _write(root, "v.ra", np.arange(64, dtype=np.float32), crc32=True)
    url = f"{base}/v.ra"
    assert racat_main(["header", url]) == 0
    assert "float" in capsys.readouterr().out
    assert racat_main(["verify", url]) == 0
    # corrupt on disk; verify over HTTP must fail
    p = os.path.join(root, "v.ra")
    blob = bytearray(open(p, "rb").read())
    blob[60] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    assert racat_main(["verify", url]) == 1


def test_literal_header_directory_not_shadowed(served):
    """A real file under a directory literally named 'header/' must serve
    its bytes — the /header/ JSON fast path only answers when no such file
    exists (the client falls back to a ranged header read on non-JSON)."""
    root, base = served
    os.makedirs(os.path.join(root, "header"), exist_ok=True)
    arr = np.arange(32, dtype=np.float32)
    ra.write(os.path.join(root, "header", "x.ra"), arr)
    assert np.array_equal(ra.read(f"{base}/header/x.ra"), arr)
    assert ra.header_of(f"{base}/header/x.ra").shape == (32,)


# ------------------------------------------------------- auth fail-fast (§11)
import http.server as _http_server


class _DenyingHandler(_http_server.BaseHTTPRequestHandler):
    """Answers EVERY request with a fixed auth-failure status and counts
    them — the shape of a token-auth plane rejecting a credential."""

    def _deny(self):
        self.server.hits += 1  # type: ignore[attr-defined]
        body = b"denied\n"
        self.send_response(self.server.deny_status)  # type: ignore[attr-defined]
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    do_GET = do_HEAD = do_PUT = _deny

    def log_message(self, fmt, *args):
        pass


@pytest.fixture(params=[401, 403])
def denying_server(request):
    srv = _http_server.ThreadingHTTPServer(("127.0.0.1", 0), _DenyingHandler)
    srv.deny_status = request.param
    srv.hits = 0
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        yield srv, f"http://127.0.0.1:{srv.server_address[1]}"
    finally:
        srv.shutdown()
        srv.server_close()
        remote.close_readers()


def test_auth_rejection_fails_fast_on_reads(denying_server):
    """401/403 must raise ``RemoteAuthError`` after exactly ONE request —
    a rejected credential is permanent, so the retry budget (which exists
    for transient transport faults) must not be burned on it."""
    srv, base = denying_server
    with pytest.raises(remote.RemoteAuthError, match=str(srv.deny_status)):
        remote.RemoteReader(f"{base}/x.ra", retries=5)
    assert srv.hits == 1  # HEAD stat: one attempt, not retries+1

    srv.hits = 0
    with pytest.raises(remote.RemoteAuthError, match="token"):
        remote.fetch_bytes(f"{base}/manifest.json", retries=5)
    assert srv.hits == 1


def test_auth_rejection_fails_fast_on_ranged_get(denying_server):
    """A reader whose stat succeeded but whose GETs are rejected (token
    revoked mid-session) also fails fast on the ranged read itself."""
    srv, base = denying_server
    reader = remote.RemoteReader.__new__(remote.RemoteReader)
    # hand-build just enough state to drive _ranged_into directly
    from repro.remote.client import _ConnPool
    from urllib.parse import urlsplit

    parts = urlsplit(f"{base}/x.ra")
    reader.url = f"{base}/x.ra"
    reader._path = parts.path
    reader.retries = 5
    reader._pool = _ConnPool(parts.scheme, parts.hostname, parts.port, 2, 5.0)
    reader._breaker = remote.breaker_for(parts.hostname, parts.port)
    reader.etag = None
    reader.size = 1 << 20
    with pytest.raises(remote.RemoteAuthError, match=str(srv.deny_status)):
        reader._ranged_into(0, memoryview(bytearray(64)))
    assert srv.hits == 1
    reader._pool.close()


def test_auth_rejection_fails_fast_on_uploads(denying_server):
    """Uploads against a rejecting server: one PUT, clear auth error,
    no retry burn (upload_bytes would otherwise blind-retry)."""
    srv, base = denying_server
    with pytest.raises(remote.RemoteAuthError, match="token"):
        remote.upload_bytes(f"{base}/up.ra", b"payload", token="bad", retries=5)
    assert srv.hits == 1


def test_auth_error_is_rawarray_error(denying_server):
    """RemoteAuthError stays catch-compatible with every existing caller
    that handles RawArrayError."""
    srv, base = denying_server
    assert issubclass(remote.RemoteAuthError, ra.RawArrayError)
    with pytest.raises(ra.RawArrayError):
        remote.fetch_bytes(f"{base}/x")


# ------------------------------------------- observability + breaker (§14)
def test_healthz_and_metrics_json(served):
    root, base = served
    arr = np.arange(4096, dtype=np.float32)
    _write(root, "m.ra", arr)
    assert np.array_equal(ra.read(f"{base}/m.ra"), arr)

    with urllib.request.urlopen(f"{base}/healthz") as resp:
        h = json.load(resp)
    assert h["ok"] is True and h["role"] == "origin" and h["uptime_s"] >= 0

    with urllib.request.urlopen(f"{base}/metrics") as resp:
        m = json.load(resp)
    assert m["role"] == "origin"
    assert m["requests"] > 0 and m["bytes_out"] > 0 and m["errors"] >= 0
    assert "/m.ra" in m["paths"]


def test_metrics_survive_a_concurrent_hammer(served):
    """Counter mutations race N reader threads against N metrics scrapers;
    the snapshot must stay internally consistent (no torn counts, no
    exceptions from the handler thread pool)."""
    root, base = served
    arr = np.arange(65536, dtype=np.uint8)
    _write(root, "h.ra", arr)
    url = f"{base}/h.ra"
    errors = []

    def reader():
        try:
            for _ in range(5):
                with remote.RemoteReader(url, use_cache=False) as r:
                    out = bytearray(1024)
                    r.pread_into(0, memoryview(out))
        except Exception as exc:  # pragma: no cover - the assertion payload
            errors.append(exc)

    def scraper():
        try:
            for _ in range(10):
                with urllib.request.urlopen(f"{base}/metrics") as resp:
                    m = json.load(resp)
                assert m["requests"] >= 0 and m["bytes_out"] >= 0
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=t) for t in (reader,) * 4 + (scraper,) * 4]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    assert not errors
    with urllib.request.urlopen(f"{base}/metrics") as resp:
        m = json.load(resp)
    assert m["requests"] >= 20  # 4 readers x 5 GETs at minimum


def test_breaker_opens_after_dead_replica(tmp_path):
    """Regression for per-host circuit breaking: after K consecutive refused
    connects the breaker opens and later calls fail in microseconds instead
    of burning connect+retry budgets against a corpse."""
    import time as _time

    remote.reset_breakers()
    arr = np.zeros(512, np.float32)
    ra.write(os.path.join(str(tmp_path), "b.ra"), arr)
    server = remote.serve(str(tmp_path), port=0)
    url = f"{server.url}/b.ra"
    server.shutdown()
    server.server_close()
    try:
        with pytest.raises(ra.RawArrayError, match="cannot reach"):
            remote.RemoteReader(url, retries=4, use_cache=False)
        t0 = _time.perf_counter()
        with pytest.raises(ra.RawArrayError, match="circuit open"):
            remote.RemoteReader(url, retries=4, use_cache=False)
        assert _time.perf_counter() - t0 < 0.25
        brk = remote.breaker_for(*_host_port(url))
        assert brk.stats()["open"]
    finally:
        remote.reset_breakers()


def _host_port(url):
    from urllib.parse import urlsplit

    parts = urlsplit(url)
    return parts.hostname, parts.port


def test_breaker_half_open_recovers(tmp_path):
    """A healed host closes the breaker on the first successful probe; a
    still-dead host re-opens it after ONE refusal (the streak stays primed
    at the threshold through half-open)."""
    remote.reset_breakers()
    os.environ["RA_REMOTE_BREAKER_COOLDOWN"] = "0.05"
    try:
        arr = np.arange(256, dtype=np.float32)
        ra.write(os.path.join(str(tmp_path), "r.ra"), arr)
        server = remote.serve(str(tmp_path), port=0)
        host, port = _host_port(server.url)
        addr = server.server_address
        url = f"{server.url}/r.ra"
        server.shutdown()
        server.server_close()
        with pytest.raises(ra.RawArrayError, match="cannot reach"):
            remote.RemoteReader(url, retries=4, use_cache=False)
        assert remote.breaker_for(host, port).stats()["open"]
        import time as _time

        _time.sleep(0.06)  # cooldown elapses -> half-open
        server2 = remote.ArrayServer(str(tmp_path), addr)  # same port heals
        t = threading.Thread(target=server2.serve_forever, daemon=True)
        t.start()
        try:
            got = ra.read(url)
            assert np.array_equal(got, arr)
            assert not remote.breaker_for(host, port).stats()["open"]
        finally:
            server2.shutdown()
            server2.server_close()
            remote.close_readers()
            remote.reset_shared_cache()
    finally:
        os.environ.pop("RA_REMOTE_BREAKER_COOLDOWN", None)
        remote.reset_breakers()


def test_cache_counters_consistent_under_threads():
    """hits + misses must equal issued gets even when get/put race from many
    threads, and hit_ratio stays within [0, 1] — the §14 counter audit."""
    cache = BlockCache(block_bytes=64, capacity_bytes=64 * 32)
    gets_per_thread = 400
    nthreads = 8

    def worker(seed):
        rng = np.random.default_rng(seed)
        for _ in range(gets_per_thread):
            b = int(rng.integers(0, 64))
            if cache.get("t", b) is None:
                cache.put("t", b, bytes(64))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(nthreads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    s = cache.stats()
    assert s["hits"] + s["misses"] == nthreads * gets_per_thread
    assert 0.0 <= s["hit_ratio"] <= 1.0
    cache.reset_stats()
    s2 = cache.stats()
    assert s2["hits"] == s2["misses"] == s2["evictions"] == s2["invalidations"] == 0


def test_overwrite_never_serves_stale_blocks(served):
    """ETag-tagged cache keys end-to-end: overwrite the file on the origin
    mid-session; a fresh read must see the new bytes and never mix cached
    blocks of the old version."""
    import time as _time

    root, base = served
    p = _write(root, "s.ra", np.zeros(30_000, np.float32))
    url = f"{base}/s.ra"
    assert float(ra.read(url)[0]) == 0.0
    _time.sleep(0.01)  # mtime tick -> new ETag
    ra.write(p, np.full(30_000, 7.0, np.float32))
    remote.close_readers()  # old pinned readers retire; cache stays hot
    got = ra.read(url)
    assert np.array_equal(got, np.full(30_000, 7.0, np.float32))
