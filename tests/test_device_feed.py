"""Device feed plane (DESIGN.md §12): DeviceLoader↔DataLoader equivalence,
u8 quantize/dequant roundtrips (interpret-mode Pallas on CPU), and the
loader-lifecycle fixes — sticky producer errors and the stop()/restore()
zombie-ring race."""

import os
import threading
import time

import numpy as np
import pytest

import repro.core as ra
from repro.data import (
    DataLoader,
    DatasetBuilder,
    DeviceLoader,
    LoaderState,
    RaDataset,
    make_token_dataset,
)


@pytest.fixture(scope="module")
def token_root(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("dfeed") / "toks")
    make_token_dataset(root, n_docs=256, seq_len=16, vocab=64, shard_rows=100)
    return root


@pytest.fixture(scope="module")
def quant_root(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("dfeed") / "imgs")
    rng = np.random.default_rng(0)
    b = DatasetBuilder(
        root,
        {"image": ((6, 6, 3), "float32"), "label": ((), "int32")},
        shard_rows=96,
        quantize={"image": "u8"},
    )
    b.append(
        image=rng.random((250, 6, 6, 3)).astype(np.float32),
        label=rng.integers(0, 10, 250).astype(np.int32),
    )
    b.finish()
    return root


# ------------------------------------------------ bugfix: sticky producer error
def test_dead_producer_error_is_sticky(token_root):
    """The prefetch thread puts ONE exception and exits; before the fix the
    second next() blocked forever on the empty queue. Now every subsequent
    next() re-raises."""
    dl = DataLoader(RaDataset(token_root), 16, seed=0)
    boom = RuntimeError("disk on fire")

    def bad_produce(epoch, step, out=None):
        raise boom

    dl._produce = bad_produce
    with pytest.raises(RuntimeError, match="disk on fire"):
        next(dl)
    # the regression hung here forever — any completion at all is the fix,
    # and it must be the SAME error, immediately
    t0 = time.perf_counter()
    for _ in range(3):
        with pytest.raises(RuntimeError, match="disk on fire"):
            next(dl)
    assert time.perf_counter() - t0 < 1.0
    dl.stop()


def test_error_cleared_by_stop_then_restart(token_root):
    dl = DataLoader(RaDataset(token_root), 16, seed=0)
    orig = DataLoader._produce.__get__(dl)
    dl._produce = lambda e, s, out=None: (_ for _ in ()).throw(ValueError("x"))
    with pytest.raises(ValueError):
        next(dl)
    dl.stop()
    del dl._produce  # restore the class implementation
    assert "tokens" in next(dl)
    dl.stop()
    assert orig is not None


def test_device_loader_error_is_sticky(token_root):
    dl = DataLoader(RaDataset(token_root), 16, seed=0)
    dl._produce = lambda e, s, out=None: (_ for _ in ()).throw(OSError("gone"))
    dev = DeviceLoader(dl)
    with pytest.raises(OSError, match="gone"):
        next(dev)
    with pytest.raises(OSError, match="gone"):
        next(dev)  # sticky through the device pipeline too
    dev.stop()


# --------------------------------------- bugfix: stop()/restore() zombie ring
def test_stop_verifies_join_and_discards_ring(token_root):
    """A producer wedged past the join timeout must not leave its ring to a
    successor: stop() discards the buffers so the restarted loader can
    never alias batches with the zombie."""
    dl = DataLoader(RaDataset(token_root), 16, seed=1, reuse_buffers=True,
                    prefetch=1)
    gate = threading.Event()
    entered = threading.Event()
    orig = DataLoader._produce.__get__(dl)

    def wedged(epoch, step, out=None):
        entered.set()
        gate.wait()
        return orig(epoch, step, out)

    dl._produce = wedged
    dl._start_prefetch()
    assert entered.wait(5.0)
    old_ring = dl._ring
    zombie = dl._thread
    assert old_ring  # allocated by _start_prefetch
    dl.stop(join_timeout=0.2)  # zombie ignores the stop: join must time out
    assert zombie.is_alive()
    assert dl._ring == []  # the ring went with it

    # restart: fresh ring, and the zombie's eventual write lands in the
    # orphaned buffers, not in anything the new loader emits (compare one
    # batch at a time — emitted batches alias the live ring by contract)
    del dl._produce
    ref = DataLoader(RaDataset(token_root), 16, seed=1)
    for i in range(6):
        if i == 3:
            gate.set()  # let the zombie finish its produce mid-iteration
        b, r = next(dl), next(ref)
        assert np.array_equal(b["tokens"], r["tokens"]), i
        assert b["_state"].__dict__ == r["_state"].__dict__
    assert dl._ring and dl._ring is not old_ring
    zombie.join(timeout=5.0)
    assert not zombie.is_alive()  # its private stop event was left set
    dl.stop()
    ref.stop()


def test_clean_stop_keeps_ring(token_root):
    dl = DataLoader(RaDataset(token_root), 16, seed=2, reuse_buffers=True)
    next(dl)
    ring = dl._ring
    assert ring
    dl.stop()
    assert dl._ring is ring  # healthy join: buffers are reusable


def test_restore_after_wedged_stop_is_exact(token_root):
    """restore() goes through stop(): even with a wedged producer the
    resumed sequence is exactly the reference sequence."""
    ref = DataLoader(RaDataset(token_root), 16, seed=3)
    batches = [next(ref) for _ in range(5)]
    ref.stop()

    dl = DataLoader(RaDataset(token_root), 16, seed=3, reuse_buffers=True)
    [next(dl) for _ in range(3)]
    gate = threading.Event()
    orig = DataLoader._produce.__get__(dl)
    dl._produce = lambda e, s, out=None: (gate.wait(), orig(e, s, out))[1]
    time.sleep(0.05)  # let the producer enter the wedge
    dl.restore(batches[2]["_state"])  # join may time out; ring discarded
    del dl._produce
    gate.set()
    nxt = next(dl)
    assert nxt["_state"].__dict__ == batches[3]["_state"].__dict__
    assert np.array_equal(nxt["tokens"], batches[3]["tokens"])
    dl.stop()


# ------------------------------------------------- quantize/dequant roundtrip
def test_write_quantize_u8_roundtrip(tmp_path):
    x = np.random.default_rng(0).normal(size=(64, 5)).astype(np.float32)
    p = str(tmp_path / "q.ra")
    ra.write(p, x, quantize="u8")
    hdr = ra.header_of(p)
    assert hdr.dtype() == np.uint8 and hdr.shape == (64, 5)
    info = ra.read_quant_metadata(p)
    assert info is not None and info.mode == "u8"
    assert info.orig_dtype == "float32" and info.scale.shape == (5,)
    y = ra.read(p, dequantize=True)
    assert y.dtype == np.float32
    # affine u8: error bounded by half a step per channel
    assert (np.abs(y - x) <= info.scale / 2 + 1e-6).all()
    # without dequantize the codes come back raw
    assert ra.read(p).dtype == np.uint8


def test_write_quantize_exact_on_u8_grid(tmp_path):
    """Values already on the u8 grid of the calibrated range roundtrip
    EXACTLY (the image-pixel case). Pin the per-channel calibration to
    [0, 255] by including both extremes in every channel."""
    codes = np.random.default_rng(1).integers(0, 256, (32, 4), dtype=np.uint8)
    codes[0] = 0
    codes[1] = 255
    x = codes.astype(np.float32)  # range [0, 255], step 1 -> scale exactly 1
    p = str(tmp_path / "g.ra")
    ra.write(p, x, quantize="u8")
    info = ra.read_quant_metadata(p)
    assert np.array_equal(info.scale, np.ones(4, np.float32))
    assert np.array_equal(ra.read(p, dequantize=True), x)


def test_quantize_merges_user_metadata(tmp_path):
    p = str(tmp_path / "m.ra")
    ra.write(p, np.ones((4, 2), np.float32), quantize="u8",
             metadata=b'{"units": "mm"}')
    import json

    meta = json.loads(ra.read_metadata(p))
    assert meta["units"] == "mm" and "ra_quant" in meta
    with pytest.raises(ra.RawArrayError, match="JSON object"):
        ra.write(p, np.ones((4, 2), np.float32), quantize="u8", metadata=b"\xff\x00")


def test_quantize_rejects_bad_inputs(tmp_path):
    with pytest.raises(ra.RawArrayError, match="float"):
        ra.write(str(tmp_path / "i.ra"), np.ones((4,), np.int32), quantize="u8")
    with pytest.raises(ra.RawArrayError, match="0-d"):
        ra.write(str(tmp_path / "z.ra"), np.float32(1.0), quantize="u8")
    with pytest.raises(ra.RawArrayError, match="unknown quantization mode"):
        ra.quant_params(np.ones((4,), np.float32), mode="u4")


def test_builder_quantize_validation(tmp_path):
    fields = {"x": ((4,), "float32"), "lab": ((), "int32")}
    with pytest.raises(ra.RawArrayError, match="unknown field"):
        DatasetBuilder(str(tmp_path / "a"), fields, quantize={"nope": "u8"})
    with pytest.raises(ra.RawArrayError, match="float"):
        DatasetBuilder(str(tmp_path / "b"), fields, quantize={"lab": "u8"})
    with pytest.raises(ra.RawArrayError, match="scalar row shape"):
        DatasetBuilder(str(tmp_path / "c"), {"s": ((), "float32")},
                       quantize={"s": "u8"})
    with pytest.raises(ra.RawArrayError, match="hi > lo"):
        ra.resolve_quant_spec(("u8", 2.0, 1.0))


def test_quantized_dataset_schema_and_shards(quant_root):
    ds = RaDataset(quant_root)
    assert set(ds.quant) == {"image"}
    assert ds.stored_spec("image") == ((6, 6, 3), np.dtype(np.uint8))
    assert ds.logical_spec("image") == ((6, 6, 3), np.dtype(np.float32))
    assert ds.fields["image"]["dtype"] == "float32"  # manifest stays logical
    # raw reads serve stored codes
    assert ds.rows(0, 8)["image"].dtype == np.uint8
    # every shard file is self-describing: header uint8 + typed metadata
    shard = os.path.join(quant_root, ds.shards[0].files["image"])
    assert ra.header_of(shard).dtype() == np.uint8
    sinfo = ra.read_quant_metadata(shard)
    assert sinfo is not None and sinfo.to_dict() == ds.quant["image"].to_dict()


def test_host_loader_dequantizes_by_default(quant_root):
    ds = RaDataset(quant_root)
    dl = DataLoader(ds, 16, seed=4, shuffle=False)
    b = next(dl)
    dl.stop()
    assert b["image"].dtype == np.float32
    manual = ds.quant["image"].dequantize(ds.rows(0, 16)["image"])
    assert np.array_equal(b["image"], manual)
    raw = DataLoader(ds, 16, seed=4, shuffle=False, dequant=False)
    assert next(raw)["image"].dtype == np.uint8
    raw.stop()


# --------------------------------------------- DeviceLoader batch equivalence
def _equiv(root, *, batches=4, batch=16, seed=7, **host_kw):
    host = DataLoader(RaDataset(root), batch, seed=seed)
    dev = DeviceLoader(
        DataLoader(RaDataset(root), batch, seed=seed, reuse_buffers=True,
                   **host_kw)
    )
    try:
        for _ in range(batches):
            hb, db = next(host), next(dev)
            assert hb["_state"].__dict__ == db["_state"].__dict__
            for f in hb:
                if f == "_state":
                    continue
                da = np.asarray(db[f])
                assert da.dtype == hb[f].dtype
                assert np.array_equal(da, hb[f]), f
    finally:
        host.stop()
        dev.stop()


def test_device_loader_matches_host_tokens(token_root):
    _equiv(token_root)


def test_device_loader_matches_host_quantized(quant_root):
    """uint8 over the 'link' + interpret-mode Pallas dequant on CPU is
    bit-identical to the host numpy dequant (same float32 affine)."""
    _equiv(quant_root)


def test_device_loader_moves_quantized_bytes(quant_root):
    dev = DeviceLoader(DataLoader(RaDataset(quant_root), 16, seed=0))
    next(dev)
    s = dev.stats()
    dev.stop()
    per_batch = s["h2d_bytes"] / s["h2d_batches"]
    # image moves as u8 codes (108 B/row) + int32 label: 4x less than f32
    assert per_batch == 16 * (6 * 6 * 3 + 4)
    assert {"h2d_s", "device_wait_s", "device_batches"} <= set(s)


def test_device_loader_restore_exact(token_root):
    ref = DataLoader(RaDataset(token_root), 16, seed=9)
    batches = [next(ref) for _ in range(5)]
    ref.stop()
    dev = DeviceLoader(DataLoader(RaDataset(token_root), 16, seed=9))
    [next(dev) for _ in range(2)]
    dev.restore(batches[2]["_state"])
    nxt = next(dev)
    dev.stop()
    assert nxt["_state"].__dict__ == batches[3]["_state"].__dict__
    assert np.array_equal(np.asarray(nxt["tokens"]), batches[3]["tokens"])


def test_device_loader_refuses_started_loader(token_root):
    dl = DataLoader(RaDataset(token_root), 16, seed=0)
    next(dl)
    with pytest.raises(ra.RawArrayError, match="not started"):
        DeviceLoader(dl)
    dl.stop()
    DeviceLoader(dl).stop()  # after stop() wrapping is fine


def test_device_bufs_knob(token_root, monkeypatch):
    monkeypatch.setenv("RA_DEVICE_BUFS", "5")
    dev = DeviceLoader(DataLoader(RaDataset(token_root), 16, seed=0))
    assert dev.bufs == 5
    dev.stop()
    dev2 = DeviceLoader(DataLoader(RaDataset(token_root), 16, seed=0), bufs=1)
    assert dev2.bufs == 1
    dev2.stop()


# ----------------------------------------------- review-hardening regressions
def test_quantize_1d_uses_scalar_params(tmp_path):
    """A 1-D array is ONE channel: calibration must be a global scalar, not
    one (scale, bias) pair per element (metadata bigger than the payload)."""
    x = np.random.default_rng(3).normal(size=4096).astype(np.float32)
    p = str(tmp_path / "one_d.ra")
    ra.write(p, x, quantize="u8")
    info = ra.read_quant_metadata(p)
    assert info.scale.ndim == 0 and info.bias.ndim == 0
    assert len(ra.read_metadata(p)) < 256
    y = ra.read(p, dequantize=True)
    assert (np.abs(y - x) <= float(info.scale) / 2 + 1e-6).all()


def test_channel_params_mismatch_raises_rawarray_error():
    info = ra.QuantInfo(scale=np.ones(3, np.float32), bias=np.zeros(3, np.float32))
    with pytest.raises(ra.RawArrayError, match="3 entries.*5 channels"):
        info.channel_params(5)
    bad_bias = ra.QuantInfo(scale=np.float32(1.0), bias=np.zeros(2, np.float32))
    with pytest.raises(ra.RawArrayError, match="bias has 2 entries"):
        bad_bias.channel_params(5)
    s, b = info.channel_params(3)
    assert s.shape == b.shape == (3,)


def test_quantize_accepts_dict_metadata(tmp_path):
    import json

    p = str(tmp_path / "dm.ra")
    ra.write(p, np.ones((4, 2), np.float32), quantize="u8",
             metadata={"units": "mm"})
    meta = json.loads(ra.read_metadata(p))
    assert meta["units"] == "mm" and "ra_quant" in meta


def test_device_loader_stop_detaches_wedged_feeder(token_root):
    """A feeder wedged past the join timeout (blocked inside the wrapped
    loader) must not share the wrapped loader with a restarted pipeline:
    stop() swaps in an equivalent fresh DataLoader."""
    inner = DataLoader(RaDataset(token_root), 16, seed=6)
    gate = threading.Event()
    orig = DataLoader._produce.__get__(inner)

    def wedged(epoch, step, out=None):
        gate.wait()
        return orig(epoch, step, out)

    inner._produce = wedged
    dev = DeviceLoader(inner)
    dev._start()  # feeder blocks inside next(inner)
    time.sleep(0.1)
    feeder = dev._thread
    dev.stop()  # join times out (~2s): wrapped loader must be replaced
    assert dev.loader is not inner
    assert dev.loader.seed == 6 and dev.loader.batch_size == 16
    # the restarted pipeline iterates the reference sequence from scratch
    ref = DataLoader(RaDataset(token_root), 16, seed=6)
    b, r = next(dev), next(ref)
    assert np.array_equal(np.asarray(b["tokens"]), r["tokens"])
    assert b["_state"].__dict__ == r["_state"].__dict__
    gate.set()  # release the zombie; it must exit without stealing a batch
    feeder.join(timeout=5.0)
    assert not feeder.is_alive()
    b, r = next(dev), next(ref)
    assert np.array_equal(np.asarray(b["tokens"]), r["tokens"])
    dev.stop()
    ref.stop()
    inner.stop()
