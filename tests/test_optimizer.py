"""AdamW + int8 moments: convergence, schedules, quantization properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.distributed import optimizer as optim


def test_adamw_converges_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, warmup_steps=10, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.ones((4, 8)) * 3.0}
    state = optim.init_state(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(200):
        params, state, _ = optim.apply_updates(params, jax.grad(loss)(params), state, cfg)
    assert float(loss(params)) < 1e-5


def test_int8_moments_converge_close_to_fp32():
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    outs = {}
    for mt in ("float32", "int8"):
        cfg = optim.AdamWConfig(lr=0.05, warmup_steps=5, total_steps=300, weight_decay=0.0, moment_dtype=mt)
        params = {"w": jnp.ones((2, 300)) * 3.0}
        state = optim.init_state(params, cfg)
        step = jax.jit(lambda p, s, g: optim.apply_updates(p, g, s, cfg))
        for _ in range(300):
            params, state, _ = step(params, state, jax.grad(loss)(params))
        outs[mt] = float(loss(params))
    assert outs["int8"] < 1e-2


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 500),
    scale=st.floats(1e-4, 1e3),
)
def test_quantize_roundtrip_error_bound(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(3, n)) * scale, jnp.float32)
    q = optim.quantize_blockwise(x)
    y = optim.dequantize_blockwise(q, n)
    assert y.shape == x.shape
    # absmax int8: error <= blockmax/127 per element
    blocks = np.asarray(jnp.abs(x))
    err = np.abs(np.asarray(x - y))
    bound = blocks.max() / 127.0 * 1.001 + 1e-12
    assert err.max() <= bound


def test_quantized_state_is_small():
    params = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    s8 = optim.init_state(params, optim.AdamWConfig(moment_dtype="int8"))
    s32 = optim.init_state(params, optim.AdamWConfig())
    b8 = sum(x.nbytes for x in jax.tree_util.tree_leaves(s8))
    b32 = sum(x.nbytes for x in jax.tree_util.tree_leaves(s32))
    assert b8 < 0.3 * b32  # ~4x smaller moments


def test_lr_schedule_shape():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=100, total_steps=1000, min_lr_frac=0.1)
    lrs = [float(optim._lr_at(jnp.asarray(s), cfg)) for s in (1, 50, 100, 500, 1000, 2000)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rising
    assert abs(lrs[2] - 1.0) < 0.02          # peak at warmup end
    assert lrs[3] < lrs[2]                   # decaying
    assert abs(lrs[4] - 0.1) < 0.02          # floor
    assert abs(lrs[5] - 0.1) < 0.02          # clamped after end


def test_grad_clip_bounds_update():
    cfg = optim.AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0, warmup_steps=0)
    params = {"w": jnp.zeros((4,))}
    state = optim.init_state(params, cfg)
    huge = {"w": jnp.full((4,), 1e9)}
    p2, _, info = optim.apply_updates(params, huge, state, cfg)
    assert float(info["grad_norm"]) > 1e8
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.0  # clipped step stays sane
