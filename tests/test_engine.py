"""Parallel I/O engine: slab math, chunk-boundary correctness, short-read
retries, coalescing equivalence, and byte-identity of engine paths vs the
seed sequential implementations."""

import os

import numpy as np
import pytest

import repro.core as ra
from repro.core import engine


# ------------------------------------------------------------- slab planner
def test_chunk_spans_cover_and_align():
    chunk = 1 << 12
    for offset, length in [(0, 10_000), (100, 10_000), (4095, 4097), (64, 1), (0, chunk)]:
        spans = engine.chunk_spans(offset, length, chunk)
        # exact cover, in order, no overlap
        pos = offset
        for off, ln in spans:
            assert off == pos and ln > 0
            pos += ln
        assert pos == offset + length
        # every interior boundary is chunk-aligned in absolute file offsets
        for off, _ in spans[1:]:
            assert off % chunk == 0


def test_chunk_spans_empty():
    assert engine.chunk_spans(123, 0, 1 << 12) == []


# -------------------------------------------------- reads across slab edges
@pytest.fixture()
def blob_file(tmp_path):
    data = np.random.default_rng(0).integers(0, 256, size=1 << 20, dtype=np.int64).astype(np.uint8)
    p = tmp_path / "blob.bin"
    p.write_bytes(data.tobytes())
    return str(p), data


def test_parallel_read_into_spanning_slabs(blob_file, monkeypatch):
    path, data = blob_file
    monkeypatch.setenv("RA_IO_CHUNK", str(1 << 14))     # 16 KiB slabs
    monkeypatch.setenv("RA_IO_PARALLEL_MIN", "1")       # force the parallel path
    fd = os.open(path, os.O_RDONLY)
    try:
        for offset, length in [(0, len(data)), (3, 1 << 15), ((1 << 14) - 1, 2), (5, 0)]:
            out = np.zeros(length, np.uint8)
            n = engine.parallel_read_into(fd, offset, memoryview(out))
            assert n == length
            assert np.array_equal(out, data[offset : offset + length])
    finally:
        os.close(fd)


def test_parallel_read_spans_multi_file(tmp_path, monkeypatch):
    monkeypatch.setenv("RA_IO_PARALLEL_MIN", "1")
    monkeypatch.setenv("RA_IO_CHUNK", str(1 << 13))
    rng = np.random.default_rng(1)
    parts = [rng.integers(0, 255, size=n, dtype=np.int64).astype(np.uint8) for n in (100, 1 << 15, 1)]
    fds = []
    try:
        for i, part in enumerate(parts):
            p = tmp_path / f"f{i}.bin"
            p.write_bytes(part.tobytes())
            fds.append(os.open(str(p), os.O_RDONLY))
        out = np.zeros(sum(len(p) for p in parts), np.uint8)
        mv = memoryview(out)
        jobs, pos = [], 0
        for fd, part in zip(fds, parts):
            jobs.append((fd, 0, mv[pos : pos + len(part)]))
            pos += len(part)
        engine.parallel_read_spans(jobs)
        assert np.array_equal(out, np.concatenate(parts))
    finally:
        for fd in fds:
            os.close(fd)


def test_short_reads_are_retried(blob_file, monkeypatch):
    """A pread returning fewer bytes than asked must loop, not truncate."""
    path, data = blob_file
    real = os.preadv

    def stingy(fd, bufs, offset):
        (buf,) = bufs
        return real(fd, [buf[: max(1, len(buf) // 3)]], offset)

    monkeypatch.setattr(engine, "_preadv", stingy)
    fd = os.open(path, os.O_RDONLY)
    try:
        out = np.zeros(10_000, np.uint8)
        engine.pread_into(fd, 77, memoryview(out))
        assert np.array_equal(out, data[77 : 77 + 10_000])
    finally:
        os.close(fd)


def test_read_past_eof_raises(blob_file):
    path, data = blob_file
    fd = os.open(path, os.O_RDONLY)
    try:
        out = np.zeros(100, np.uint8)
        with pytest.raises(ra.RawArrayError, match="truncated"):
            engine.pread_into(fd, len(data) - 50, memoryview(out))
    finally:
        os.close(fd)


def test_short_writes_are_retried(tmp_path, monkeypatch):
    real = os.pwritev

    def stingy(fd, bufs, offset):
        (buf,) = bufs
        return real(fd, [buf[: max(1, len(buf) // 4)]], offset)

    monkeypatch.setattr(engine, "_pwritev", stingy)
    payload = bytes(range(256)) * 40
    p = str(tmp_path / "w.bin")
    fd = os.open(p, os.O_WRONLY | os.O_CREAT, 0o644)
    try:
        engine.pwrite_from(fd, 0, memoryview(payload))
    finally:
        os.close(fd)
    assert open(p, "rb").read() == payload


# ------------------------------------------------------------ zero-length
def test_zero_length_everything(tmp_path):
    p = str(tmp_path / "z.ra")
    arr = np.empty((0, 5), np.float32)
    ra.write(p, arr)
    assert ra.read(p).shape == (0, 5)
    out = np.empty((0, 5), np.float32)
    assert ra.read_into(p, out) is out
    fd = os.open(p, os.O_RDONLY)
    try:
        assert engine.parallel_read_into(fd, 0, memoryview(b"")) == 0
    finally:
        os.close(fd)
    runs, leftover = engine.coalesce(np.empty(0, np.int64))
    assert runs == [] and leftover.size == 0


# ---------------------------------------------------------------- coalesce
@pytest.mark.parametrize("trial", range(12))
def test_coalesce_partitions_exactly(trial):
    rng = np.random.default_rng(trial)
    n = int(rng.integers(1, 400))
    indices = rng.integers(0, 1000, size=n)  # duplicates likely
    gap = int(rng.integers(0, 4))
    min_run = int(rng.integers(2, 6))
    runs, leftover = engine.coalesce(indices, gap=gap, min_run=min_run)
    cover = [leftover] + [r.sel for r in runs]
    allpos = np.sort(np.concatenate(cover))
    assert np.array_equal(allpos, np.arange(n))  # exact partition of positions
    for r in runs:
        vals = indices[r.sel]
        assert r.lo == vals.min() and r.hi == vals.max() + 1
        assert len(r.sel) >= min_run
        assert np.all(np.diff(np.sort(vals)) <= gap + 1)  # merged gaps bounded


def test_coalesce_adjacent_rows_merge():
    runs, leftover = engine.coalesce(np.array([7, 4, 5, 6]), gap=0, min_run=2)
    assert leftover.size == 0
    assert len(runs) == 1 and (runs[0].lo, runs[0].hi) == (4, 8)


# ----------------------------------------- gather / read_slice equivalence
@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    from repro.data import RaDataset, make_token_dataset

    root = str(tmp_path_factory.mktemp("eng") / "ds")
    make_token_dataset(root, n_docs=333, seq_len=24, vocab=97, shard_rows=100)
    return RaDataset(root)


@pytest.mark.parametrize("trial", range(10))
def test_gather_matches_naive_on_random_patterns(dataset, trial):
    rng = np.random.default_rng(100 + trial)
    n = int(rng.integers(1, 120))
    if trial % 3 == 0:       # dense-ish: exercises the coalesced ranged reads
        base = int(rng.integers(0, 200))
        idx = base + rng.integers(0, 40, size=n)
    elif trial % 3 == 1:     # sparse: exercises the mmap fancy fallback
        idx = rng.integers(0, len(dataset), size=n)
    else:                    # sorted contiguous with dups: exercises direct reads
        idx = np.sort(rng.integers(0, len(dataset), size=n))
    got = dataset.gather(idx)
    want = dataset.gather_naive(idx)
    assert set(got) == set(want)
    for f in got:
        assert np.array_equal(got[f], want[f]), f


def test_gather_into_preallocated_out(dataset):
    idx = np.array([3, 4, 5, 6, 250, 11, 12, 13, 14, 3])
    out = {
        f: np.empty((len(idx),) + tuple(i["shape"]), i["dtype"])
        for f, i in dataset.fields.items()
    }
    got = dataset.gather(idx, out=out)
    for f in out:
        assert got[f] is out[f]
        assert np.array_equal(got[f], dataset.gather_naive(idx)[f])


def test_rows_matches_gather_naive(dataset):
    got = dataset.rows(90, 210)  # spans two shard boundaries (100, 200)
    want = dataset.gather_naive(np.arange(90, 210))
    for f in got:
        assert np.array_equal(got[f], want[f])


@pytest.mark.parametrize("nshards", [1, 3, 7])
def test_read_slice_matches_naive_and_sharded(tmp_path, nshards):
    arr = np.random.default_rng(nshards).normal(size=(101, 6)).astype(np.float32)
    d = str(tmp_path / f"s{nshards}")
    ra.write_sharded(d, arr, nshards=nshards)
    assert np.array_equal(ra.read_sharded(d), arr)
    for lo, hi in [(0, 101), (13, 87), (50, 51), (40, 40), (-5, 400)]:
        got = ra.read_slice(d, lo, hi)
        naive = ra.read_slice_naive(d, lo, hi)
        assert np.array_equal(got, naive)
        assert np.array_equal(got, arr[max(lo, 0) : min(hi, 101)])


def test_read_slice_empty_respects_axis(tmp_path):
    arr = np.arange(60, dtype=np.int32).reshape(12, 5)
    d = str(tmp_path / "ax1")
    ra.write_sharded(d, arr, nshards=2, axis=1)
    empty = ra.read_slice(d, 3, 3)
    assert empty.shape == (12, 0)  # axis=1 empty slice keeps the other dims
    assert np.array_equal(ra.read_sharded(d), arr)


def test_read_slice_into_out(tmp_path):
    arr = np.random.default_rng(3).integers(0, 1000, size=(64, 9)).astype(np.int64)
    d = str(tmp_path / "out")
    ra.write_sharded(d, arr, nshards=5)
    out = np.full((30, 9), -1, np.int64)
    got = ra.read_slice(d, 10, 40, out=out)
    assert got is out
    assert np.array_equal(out, arr[10:40])
    with pytest.raises(ra.RawArrayError, match="out"):
        ra.read_slice(d, 10, 40, out=np.empty((3, 9), np.int64))


# ------------------------------------------------------------ byte identity
def test_parallel_write_bytes_identical_to_sequential(tmp_path, monkeypatch):
    arr = np.random.default_rng(5).normal(size=(1 << 18,)).astype(np.float32)  # 1 MiB
    p_seq, p_par = str(tmp_path / "s.ra"), str(tmp_path / "p.ra")
    monkeypatch.setenv("RA_IO_SEQUENTIAL", "1")
    ra.write(p_seq, arr, metadata=b"tail")
    monkeypatch.delenv("RA_IO_SEQUENTIAL")
    monkeypatch.setenv("RA_IO_PARALLEL_MIN", "1")
    monkeypatch.setenv("RA_IO_CHUNK", str(1 << 16))
    ra.write(p_par, arr, metadata=b"tail")
    assert open(p_seq, "rb").read() == open(p_par, "rb").read()


def test_parallel_read_identical_to_sequential(tmp_path, monkeypatch):
    arr = np.random.default_rng(6).normal(size=(300, 1000)).astype(np.float64)
    p = str(tmp_path / "x.ra")
    ra.write(p, arr)
    monkeypatch.setenv("RA_IO_SEQUENTIAL", "1")
    seq = ra.read(p)
    monkeypatch.delenv("RA_IO_SEQUENTIAL")
    monkeypatch.setenv("RA_IO_PARALLEL_MIN", "1")
    monkeypatch.setenv("RA_IO_CHUNK", str(1 << 16))
    par = ra.read(p)
    assert np.array_equal(seq, par) and seq.dtype == par.dtype


# ------------------------------------------------------------- read_into
def test_read_into_validates(tmp_path):
    p = str(tmp_path / "v.ra")
    arr = np.arange(24, dtype=np.float32).reshape(4, 6)
    ra.write(p, arr)
    with pytest.raises(ra.RawArrayError, match="shape"):
        ra.read_into(p, np.empty((6, 4), np.float32))
    with pytest.raises(ra.RawArrayError, match="dtype"):
        ra.read_into(p, np.empty((4, 6), np.float64))
    with pytest.raises(ra.RawArrayError, match="contiguous"):
        ra.read_into(p, np.empty((4, 12), np.float32)[:, ::2])
    out = np.empty((4, 6), np.float32)
    assert np.array_equal(ra.read_into(p, out), arr)


def test_read_into_compressed_fallback(tmp_path):
    p = str(tmp_path / "c.ra")
    arr = np.arange(1000, dtype=np.int32)
    ra.write(p, arr, compress=True)
    out = np.empty(1000, np.int32)
    assert np.array_equal(ra.read_into(p, out), arr)


def test_read_into_big_endian_fallback(tmp_path):
    """A native-endian destination must accept a big-endian payload via the
    read() fallback (the dtype check is byte-order-insensitive)."""
    p = str(tmp_path / "be.ra")
    arr = np.arange(100, dtype=np.float32)
    ra.write(p, arr, big_endian=True)
    out = np.empty(100, np.float32)
    assert np.array_equal(ra.read_into(p, out), arr)
