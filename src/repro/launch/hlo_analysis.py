"""Compiled-HLO analysis: collective byte accounting with while-loop
trip-count scaling.

XLA's ``cost_analysis``/naive text scans count a ``while`` (lax.scan) body
ONCE — a 48-layer scanned stack would be undercounted 48x. This module
parses the module into computations, extracts each while's trip count from
its condition (largest integer constant compared against the induction
variable), propagates execution multipliers through while/call/conditional
edges, and sums collective bytes x multiplier.

Byte convention: each collective instruction contributes its OUTPUT shape
bytes (per-device data crossing the links, up to ring-algorithm factors of
~2x (N-1)/N which we fold into the link-bandwidth derate instead).
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}
_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """name -> list of instruction lines. ENTRY computation named '__entry__'."""
    comps: Dict[str, List[str]] = {}
    cur: List[str] | None = None
    name = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_HEAD.match(stripped)
        if m and not line.startswith(" "):
            name = "__entry__" if m.group(1) else m.group(2)
            cur = []
            comps[name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            cur.append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the condition computation (scan bound)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution count per computation, propagated from ENTRY."""
    mult: Dict[str, float] = {k: 0.0 for k in comps}
    if "__entry__" not in comps:
        return {k: 1.0 for k in comps}
    mult["__entry__"] = 1.0
    # topological-ish fixed point (call graph is a DAG; few iterations suffice)
    for _ in range(64):
        changed = False
        new = dict(mult)
        for k in comps:
            new[k] = 0.0
        new["__entry__"] = 1.0
        for cname, lines in comps.items():
            w = mult[cname]
            if w == 0.0:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    t = _trip_count(comps.get(cond, []))
                    if body in new:
                        new[body] += w * t
                    if cond in new:
                        new[cond] += w * (t + 1)
                    continue
                bm = _BRANCH_RE.search(line)
                if bm:
                    for b in bm.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b in new:
                            new[b] += w  # upper bound: every branch charged
                    continue
                cm = _CALL_RE.search(line)
                if cm and " fusion(" not in line and "reduce(" not in line:
                    callee = cm.group(1)
                    if callee in new:
                        new[callee] += w
        if any(abs(new[k] - mult[k]) > 1e-9 for k in comps):
            changed = True
        mult = new
        if not changed:
            break
    return mult


def collective_stats(hlo: str) -> Dict[str, Any]:
    """Trip-count-scaled per-kind collective counts and bytes."""
    comps = split_computations(hlo)
    mult = computation_multipliers(comps)
    per_kind: Dict[str, Dict[str, float]] = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVES}
    for cname, lines in comps.items():
        w = mult.get(cname, 1.0)
        if w == 0.0:
            continue
        for line in lines:
            m = re.match(r"%?[\w.\-]+ = (.*?) ([\w\-]+)\(", line)
            if not m:
                continue
            type_str, op = m.group(1), m.group(2)
            kind = None
            for c in COLLECTIVES:
                if op == c or op == c + "-start":
                    kind = c
                    break
                if op == c + "-done":
                    kind = "__done__"
                    break
            if kind is None or kind == "__done__":
                continue
            b = shape_bytes(type_str)
            if kind == "reduce-scatter":
                # output is the per-device SHARD; physical bytes moved per
                # device ~ full input = shard x group member count
                gm = _GROUPS_RE.search(line)
                if gm:
                    b *= int(gm.group(2))
            per_kind[kind]["count"] += w
            per_kind[kind]["bytes"] += w * b
    total = sum(v["bytes"] for v in per_kind.values())
    n_while = sum(1 for ls in comps.values() for l in ls if _WHILE_RE.search(l))
    return {
        "per_kind": per_kind,
        "total_bytes": total,
        "n_computations": len(comps),
        "n_while": n_while,
    }


def is_async(hlo: str) -> bool:
    return "-start(" in hlo
