"""Training launcher: `python -m repro.launch.train --arch paper_lm ...`

Thin CLI over repro.train.loop — builds the RawArray dataset if absent,
constructs the model + loader, runs the fault-tolerant loop (auto-resume).
For the multi-chip production meshes, combine with the sharded step
factories in repro.distributed.steps (see launch/dryrun.py for the AOT
path; this driver targets the hardware actually present).
"""

from __future__ import annotations

import argparse
import os


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="paper_lm")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--workdir", default="runs/train")
    p.add_argument("--dataset", default=None, help="existing RaDataset dir")
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--fresh", action="store_true")
    p.add_argument(
        "--device-feed", action="store_true",
        help="wrap the loader in DeviceLoader (DESIGN.md §12): keep "
             "RA_DEVICE_BUFS batches resident on device, overlapping host "
             "read + H2D with the train step; quantized fields decode "
             "on-device via the fused Pallas kernel",
    )
    p.add_argument(
        "--device-bufs", type=int, default=None,
        help="device-resident batch depth (default: RA_DEVICE_BUFS or 2)",
    )
    p.add_argument(
        "--restore", choices=("pipelined", "naive"), default="pipelined",
        help="--resume restore path (DESIGN.md §13): 'pipelined' overlaps "
             "fetch/decode/dequant/H2D under the RA_COLDSTART_INFLIGHT "
             "budget; 'naive' is the phase-by-phase baseline",
    )
    p.add_argument(
        "--mesh-hosts", default=None,
        help="data-mesh membership (DESIGN.md §15): comma-separated host "
             "names, one jax process per host, listed in process-index "
             "order (default: RA_MESH_HOSTS)",
    )
    p.add_argument(
        "--mesh-host", default=None,
        help="this process's mesh host name (default: RA_MESH_HOST)",
    )
    args = p.parse_args(argv)

    from repro.configs import get_config
    from repro.data import DataLoader, RaDataset, make_token_dataset
    from repro.distributed.optimizer import AdamWConfig
    from repro.models import build_model
    from repro.train import TrainLoopConfig, train

    cfg = get_config(args.arch)
    os.makedirs(args.workdir, exist_ok=True)
    ds_root = args.dataset or os.path.join(args.workdir, "dataset")
    if not os.path.exists(os.path.join(ds_root, "manifest.json")):
        # shard_rows small enough that a mesh has shards to deal out
        make_token_dataset(ds_root, n_docs=2048, seq_len=min(256, cfg.max_seq),
                           vocab=cfg.vocab, shard_rows=256)
    # data mesh (DESIGN.md §15): shard-ownership ingest across jax processes
    mesh = None
    if args.mesh_hosts or args.mesh_host:
        from repro.distributed.data_mesh import DataMesh

        names = [h.strip() for h in (args.mesh_hosts or "").split(",") if h.strip()]
        if not names or not args.mesh_host:
            p.error("--mesh-hosts and --mesh-host must be given together")
        mesh = DataMesh(args.mesh_host, names)
    else:
        from repro.distributed.data_mesh import DataMesh

        mesh = DataMesh.from_env()  # RA_MESH_HOSTS / RA_MESH_HOST, else None
    # reuse_buffers is safe here: the train loop copies each batch to device
    # (jnp.asarray) before requesting the next one; with --device-feed the
    # DeviceLoader's feeder confirms each transfer before recycling the ring
    loader = DataLoader(RaDataset(ds_root), args.batch, seed=args.seed,
                        reuse_buffers=True, mesh=mesh)
    if args.device_feed:
        from repro.data import DeviceLoader

        loader = DeviceLoader(loader, bufs=args.device_bufs)
    out = train(
        build_model(cfg),
        loader,
        TrainLoopConfig(
            steps=args.steps,
            ckpt_every=args.ckpt_every,
            ckpt_dir=os.path.join(args.workdir, "ckpt"),
            adamw=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=max(args.steps, 200)),
        ),
        resume=not args.fresh,
        restore_mode=args.restore,
    )
    print(f"done: steps={out['steps']} wall={out['wall_s']:.1f}s preempted={out['preempted']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
