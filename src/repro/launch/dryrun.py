import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (device count locks on
first backend init). 512 virtual host devices back both the 16x16 single-pod
mesh and the 2x16x16 multi-pod mesh.

Per cell this script:
  1. builds the production mesh and the step function with explicit
     in/out shardings (repro.distributed.steps),
  2. ``jax.jit(step).lower(**abstract inputs)`` — ShapeDtypeStructs only,
     no allocation,
  3. ``.compile()`` — proving GSPMD partitioning + collectives are coherent,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     into experiments/dryrun/<arch>__<shape>__<mesh>.json for §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


from repro.launch.hlo_analysis import collective_stats, is_async


# ------------------------------------------------------------- dry run core
def run_cell(arch: str, shape_name: str, multi_pod: bool, *, save: bool = True, strategy: str = "tp", tag: str = "", no_remat: bool = False, grad_dtype: str = None, head_pad: int = 0, moe_ep: bool = False) -> Dict[str, Any]:
    from repro.configs import get_config
    from repro.distributed.steps import make_decode_step, make_prefill_step, make_train_step
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, cell_applicable, input_specs
    from repro.models import build_model

    cfg = get_config(arch)
    if no_remat:
        cfg = cfg.with_(remat=False)
    if head_pad:
        cfg = cfg.with_(head_pad=head_pad)
    shape = SHAPES[shape_name]
    skip = cell_applicable(cfg, shape)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell_id = f"{cfg.name}__{shape.name}__{mesh_name}" + (f"__{tag}" if tag else "")
    if skip:
        return {"cell": cell_id, "status": "skip", "reason": skip}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)

    with mesh:
        if shape.kind == "train":
            step, in_sh, out_sh, (params_shape, opt_shape) = make_train_step(
                model, mesh, shape, multi_pod=multi_pod, strategy=strategy,
                grad_dtype=grad_dtype, moe_ep=moe_ep,
            )
            args = (params_shape, opt_shape, input_specs(cfg, shape))
        elif shape.kind == "prefill":
            step, in_sh, out_sh, params_shape = make_prefill_step(
                model, mesh, shape, multi_pod=multi_pod
            )
            args = (params_shape, input_specs(cfg, shape))
        else:
            step, in_sh, out_sh, (params_shape, cache_shape) = make_decode_step(
                model, mesh, shape, multi_pod=multi_pod
            )
            args = (params_shape, cache_shape, input_specs(cfg, shape)["tokens"])

        donate = (0, 1) if shape.kind == "train" else ((1,) if shape.kind == "decode" else ())
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    mem_d = {}
    if mem is not None:
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            mem_d[k] = int(getattr(mem, k, 0) or 0)
    cost_d = {}
    if cost:
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in cost:
                cost_d[k.replace(" ", "_")] = float(cost[k])

    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    n_params = int(
        sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params_shape))
    )
    result = {
        "cell": cell_id,
        "status": "ok",
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": mesh_name,
        "chips": int(np.prod(mesh.devices.shape)),
        "kind": shape.kind,
        "seq": shape.seq,
        "batch": shape.batch,
        "n_params": n_params,
        "n_params_active": int(cfg.active_param_count()),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": cost_d,
        "collectives": coll,
        "async_collectives": is_async(hlo),
        "hlo_bytes": len(hlo),
    }
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(os.path.join(OUT_DIR, cell_id + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--all", action="store_true", help="run every applicable cell")
    p.add_argument("--print-hlo", action="store_true")
    p.add_argument("--strategy", default="tp", choices=["tp", "dp"])
    p.add_argument("--tag", default="")
    p.add_argument("--no-remat", action="store_true")
    p.add_argument("--grad-bf16", action="store_true")
    p.add_argument("--head-pad", type=int, default=0)
    p.add_argument("--moe-ep", action="store_true")
    args = p.parse_args(argv)

    from repro.configs import all_arch_ids, get_config
    from repro.launch.shapes import SHAPES

    archs = all_arch_ids() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    r = run_cell(arch, shape, mp, strategy=args.strategy, tag=args.tag,
                                 no_remat=args.no_remat, head_pad=args.head_pad,
                                 moe_ep=args.moe_ep,
                                 grad_dtype="bfloat16" if args.grad_bf16 else None)
                except Exception as e:  # noqa: BLE001 — report & continue
                    failures += 1
                    print(f"FAIL {arch} {shape} multi_pod={mp}: {e}")
                    traceback.print_exc()
                    continue
                if r["status"] == "skip":
                    print(f"SKIP {r['cell']}: {r['reason']}")
                    continue
                ca = r["cost_analysis"]
                print(
                    f"OK   {r['cell']}: compile={r['compile_s']}s "
                    f"flops={ca.get('flops', 0):.3e} "
                    f"coll={r['collectives']['total_bytes']:.3e}B "
                    f"temp={r['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB/dev"
                )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
