"""Assigned input shapes and abstract input specs for the dry-run.

Shape skips (DESIGN.md §4): ``long_500k`` runs only for sub-quadratic archs
(gemma3 SWA-dominant, mamba2 SSM, zamba2 hybrid); full-attention archs skip
it. Whisper's decode shapes exercise the decoder cache as a shape exercise
(real Whisper caps targets at 448).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

# archs allowed to run long_500k (sub-quadratic long-context decode)
LONG_OK = {"gemma3-12b", "mamba2-780m", "zamba2-1.2b"}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int
    context_parallel: bool = False  # shard KV length instead of batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, context_parallel=True),
}

# decoder prompt/target length for enc-dec (whisper) train/prefill shapes
ENCDEC_TGT = 448


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if (arch, shape) runs; else a skip reason string."""
    if shape.name == "long_500k" and cfg.name not in LONG_OK:
        return "full-attention arch: 500k-context decode skipped (DESIGN.md §4)"
    return None


def all_cells(cfg: ModelConfig) -> List[ShapeSpec]:
    return [s for s in SHAPES.values() if cell_applicable(cfg, s) is None]


def f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.batch, shape.seq
    cd = cfg.cdtype
    if cfg.family == "encdec":
        if shape.kind == "train":
            return {"frames": f((B, S, cfg.d_model), cd), "tokens": f((B, ENCDEC_TGT), jnp.int32)}
        if shape.kind == "prefill":
            return {"frames": f((B, S, cfg.d_model), cd), "tokens": f((B, ENCDEC_TGT), jnp.int32)}
        return {"tokens": f((B, 1), jnp.int32)}  # decode: plus the cache
    if cfg.family == "vlm":
        P = cfg.n_patches
        if shape.kind in ("train", "prefill"):
            return {
                "tokens": f((B, S - P), jnp.int32),
                "patch_embeds": f((B, P, cfg.d_model), cd),
            }
        return {"tokens": f((B, 1), jnp.int32)}
    if shape.kind in ("train", "prefill"):
        return {"tokens": f((B, S), jnp.int32)}
    return {"tokens": f((B, 1), jnp.int32)}


def cache_specs(model, cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """Abstract KV/state cache for decode shapes (no allocation)."""
    return jax.eval_shape(lambda: model.empty_cache(shape.batch, shape.seq))
