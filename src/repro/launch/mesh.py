"""Production mesh definitions (functions, not constants — importing this
module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model). Multi-pod: 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
