"""Parameter partition specs: param-tree path -> PartitionSpec.

Strategy (DESIGN.md §3):

* dense archs — Megatron TP over ``model`` (attention fused-head dims, MLP
  d_ff, vocab), params replicated over ``data`` (their optimizer state too);
* MoE giants — TP over ``model`` **plus** FSDP over ``data`` on the d_model
  axis (ZeRO-3): XLA all-gathers each scanned layer's weights on entry,
  keeping per-chip bytes ≈ params/(16·16);
* every spec is divisibility-checked against the mesh and falls back to
  less-sharded alternatives, so awkward dims (qwen's 40 heads) degrade
  gracefully instead of failing to lower.

Leading scan (layer-stack) axes are never sharded.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..models.config import ModelConfig


def _axis_size(mesh_shape: Dict[str, int], axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_shape.get(axes, 1)
    n = 1
    for a in axes:
        n *= mesh_shape.get(a, 1)
    return n


def _fits(shape: Tuple[int, ...], spec: Sequence, mesh_shape: Dict[str, int]) -> bool:
    for dim, axes in zip(shape, spec):
        if axes is None:
            continue
        if dim % _axis_size(mesh_shape, axes) != 0:
            return False
    return True


def _choose(shape, candidates, mesh_shape) -> P:
    """First candidate spec that divides evenly; final fallback replicated."""
    for cand in candidates:
        cand = tuple(cand) + (None,) * (len(shape) - len(cand))
        if _fits(shape, cand, mesh_shape):
            return P(*cand)
    return P(*([None] * len(shape)))


# Rules: (path regex, candidate specs for the *trailing* named dims).
# 'F' = fsdp axis placeholder (resolved to 'data' for fsdp trees, else None).
def _rules(fsdp: bool):
    F = "data" if fsdp else None
    return [
        # embeddings / heads
        (r"embed$", [("model", F), ("model", None), (None, None)]),
        (r"lm_head$", [(F, "model"), (None, "model"), (None, None)]),
        (r"(enc_pos|dec_pos)$", [(None, "model"), (None, None)]),
        (r"mm_proj$", [(F, "model"), (None, "model")]),
        # attention (d, H, hd) / (H, hd, d)
        (r"attn/w[qkv]$", [(F, "model", None), (None, "model", None), (None, None, "model"), (F, None, None)]),
        (r"attn/wo$", [("model", None, F), ("model", None, None), (None, "model", None), (None, None, F)]),
        (r"attn/b[qkv]$", [("model", None), (None, None)]),
        (r"attn/bo$", [(None,)]),
        # MLA
        (r"attn/wq_a$", [(F, "model"), (None, "model")]),
        (r"attn/wq_b$", [(None, "model", None), ("model", None, None)]),
        (r"attn/wkv_a$", [(F, "model"), (None, "model"), (F, None)]),
        (r"attn/wkv_b$", [(None, "model", None)]),
        # MLP (d, ff) / (ff, d)
        (r"(mlp|ffn)/w_(up|gate)$", [(F, "model"), (None, "model")]),
        (r"(mlp|ffn)/w_down$", [("model", F), ("model", None)]),
        (r"(mlp|ffn)/w1$", [(F, "model"), (None, "model")]),
        (r"(mlp|ffn)/w2$", [("model", F), ("model", None)]),
        # MoE
        (r"ffn/router$", [(F, "model"), (None, "model"), (None, None)]),
        (r"ffn/w_(gate|up)$", [("model", F, None), ("model", None, None)]),  # (E, d, ffe)
        (r"ffn/shared_(gate|up)$", [(F, "model"), (None, "model")]),
        (r"ffn/shared_down$", [("model", F), ("model", None)]),
        # Mamba2
        (r"ssm/w_(z|x)$", [(F, "model"), (None, "model")]),
        (r"ssm/w_(B|C|dt)$", [(F, "model"), (None, "model"), (F, None), (None, None)]),
        (r"ssm/conv_._w$", [("model", None), (None, None)]),
        (r"ssm/conv_._b$", [("model",), (None,)]),
        (r"ssm/norm$", [("model",), (None,)]),
        (r"ssm/out_proj$", [("model", F), ("model", None)]),
        # zamba shared block
        (r"shared/out_proj$", [("model", F), ("model", None)]),
        (r"shared/mlp/w_(up|gate)$", [(F, "model"), (None, "model")]),
        (r"shared/mlp/w_down$", [("model", F), ("model", None)]),
        # mtp projection
        (r"mtp/proj$", [(F, "model"), (None, "model")]),
        # norms & 1-d leftovers: replicated
        (r".*", [()]),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(
    params_shape: Any,
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    n_scan_dims: int = 1,
    strategy: str = "tp",
) -> Any:
    """Build a PartitionSpec tree matching ``params_shape`` (a ShapeDtypeStruct
    tree from ``jax.eval_shape``).

    strategy='tp' (default): Megatron TP over 'model' (+FSDP for MoE giants).
    strategy='dp': pure data parallelism — params REPLICATED (batch shards
    over both mesh axes); pair with ``zero1_moment_specs`` so optimizer
    state shards ZeRO-1 style. Wins for small models where TP's activation
    all-reduces dominate (see EXPERIMENTS.md §Perf / olmo hillclimb).
    """
    mesh_shape = dict(mesh.shape)
    if strategy == "dp":
        return jax.tree_util.tree_map(
            lambda l: P(*([None] * len(l.shape))), params_shape
        )
    fsdp = bool(cfg.moe and cfg.moe.n_experts) and cfg.param_dtype != "float32"
    rules = _rules(fsdp)

    # layer stacks have a leading scan dim; detect by path prefix
    stack_prefixes = ("dense_layers", "moe_layers", "layers", "enc_layers", "dec_layers")

    def spec_of(path, leaf):
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        lead = 1 if ps.startswith(stack_prefixes) else 0
        trail = shape[lead:]
        for pat, candidates in rules:
            if re.search(pat, ps):
                sp = _choose(trail, candidates, mesh_shape)
                return P(*((None,) * lead + tuple(sp)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def opt_state_specs(opt_state_shape: Any, pspecs: Any) -> Any:
    """Optimizer state shards like its parameter. Quantized moments ({"q",
    "scale"}) inherit the param spec ("q" same rank; "scale" drops the last
    dim's sharding)."""

    def like(param_spec: P, leaf_shape) -> P:
        sp = tuple(param_spec)
        rank = len(leaf_shape.shape)
        if rank == len(sp):
            return P(*sp)
        if rank == len(sp) + 1:  # blockwise scale: (..., nblocks) - keep prefix
            return P(*(sp[:-1] + (None, None))[:rank])
        if rank < len(sp):
            return P(*sp[:rank])
        return P(*(sp + (None,) * (rank - len(sp))))

    def map_state(state, specs):
        if isinstance(state, dict) and set(state.keys()) == {"q", "scale"}:
            sp = tuple(specs)
            # scale has shape param.shape[:-1] + (nblocks,): keep the prefix
            # sharding, never shard the block-count dim
            scale_spec = P(*(sp[:-1] + (None,))) if sp else P(None)
            return {"q": like(specs, state["q"]), "scale": scale_spec}
        if isinstance(state, dict):
            raise TypeError("unexpected dict in moment tree")
        return like(specs, state)

    import jax.tree_util as jtu

    m = jtu.tree_map(
        map_state,
        opt_state_shape["m"],
        pspecs,
        is_leaf=lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "scale"},
    )
    v = jtu.tree_map(
        map_state,
        opt_state_shape["v"],
        pspecs,
        is_leaf=lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "scale"},
    )
    return {"step": P(), "m": m, "v": v}


def zero1_moment_specs(opt_state_shape: Any, mesh: Mesh) -> Any:
    """ZeRO-1: shard each moment leaf on its largest evenly-divisible dim
    (layer-stack dims split over 'data', vocab-sized dims over 'model');
    params stay replicated — XLA inserts reduce-scatter(grads) +
    all-gather(updated params) automatically."""
    mesh_shape = dict(mesh.shape)

    def spec(leaf):
        shape = tuple(leaf.shape)
        for axes in (("data",), ("model",), ("data", "model")):
            n = 1
            for a in axes:
                n *= mesh_shape.get(a, 1)
            for i, dim in enumerate(shape):
                if dim % n == 0 and dim >= n:
                    return P(*(axes if j == i else None for j in range(len(shape))))
        return P(*([None] * len(shape)))

    def map_state(state):
        if isinstance(state, dict) and set(state.keys()) == {"q", "scale"}:
            return {"q": spec(state["q"]), "scale": spec(state["scale"])}
        return spec(state)

    import jax.tree_util as jtu

    is_q = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "scale"}
    return {
        "step": P(),
        "m": jtu.tree_map(map_state, opt_state_shape["m"], is_leaf=is_q),
        "v": jtu.tree_map(map_state, opt_state_shape["v"], is_leaf=is_q),
    }
