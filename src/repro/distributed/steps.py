"""jit-able train / prefill / decode steps with full sharding plumbing.

``make_*_step`` returns (fn, in_shardings, out_shardings) ready for
``jax.jit(fn, in_shardings=..., out_shardings=...).lower(...)`` — used both
by the real training loop (CPU-scale) and the multi-pod dry-run (AOT).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..launch.shapes import ShapeSpec
from ..models.config import ModelConfig
from . import optimizer as optim
from .partition import opt_state_specs, param_specs
from .sharding import decode_rules, train_rules, use_rules

Tree = Any


def _named(mesh: Mesh, tree_of_specs: Tree) -> Tree:
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, multi_pod: bool) -> Dict[str, P]:
    b = None if shape.context_parallel else (("pod", "data") if multi_pod else ("data",))
    specs: Dict[str, P] = {"tokens": P(b, None)}
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        specs["patch_embeds"] = P(b, None, None)
    if cfg.family == "encdec" and shape.kind in ("train", "prefill"):
        specs["frames"] = P(b, None, None)
    return specs


def cache_spec_tree(cache_shape: Tree, shape: ShapeSpec, mesh: Mesh, multi_pod: bool) -> Tree:
    """PartitionSpec tree for a KV/state cache, by leaf name + divisibility."""
    mesh_shape = dict(mesh.shape)
    if shape.context_parallel:
        batch_ax = None
        seq_ax: Any = ("pod", "data", "model") if multi_pod else ("data", "model")
    else:
        batch_ax = ("pod", "data") if multi_pod else ("data",)
        seq_ax = "model"

    def ax_size(a):
        if a is None:
            return 1
        if isinstance(a, str):
            return mesh_shape.get(a, 1)
        n = 1
        for x in a:
            n *= mesh_shape.get(x, 1)
        return n

    def leaf_spec(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        shp = tuple(leaf.shape)
        if name == "pos":
            return P()
        def div(dim_idx, ax):
            return ax is not None and shp[dim_idx] % ax_size(ax) == 0
        if name in ("k", "v", "ck", "cv"):  # (L, B, KV, S, hd)
            kv_ax = "model" if (seq_ax != "model" and div(2, "model")) else None
            s_ax = seq_ax if div(3, seq_ax) else None
            if kv_ax == "model" and s_ax and "model" in (s_ax if isinstance(s_ax, tuple) else (s_ax,)):
                kv_ax = None
            return P(None, batch_ax if div(1, batch_ax) else None, kv_ax, s_ax, None)
        if name in ("latent", "k_rope"):  # (L, B, S, r)
            return P(None, batch_ax if div(1, batch_ax) else None,
                     seq_ax if div(2, seq_ax) else None, None)
        if name == "ssm":  # (L, B, H, P, N)
            return P(None, batch_ax if div(1, batch_ax) else None,
                     "model" if div(2, "model") else None, None, None)
        if name.startswith("conv"):  # (L, B, W-1, C)
            return P(None, batch_ax if div(1, batch_ax) else None, None,
                     "model" if div(3, "model") else None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


# -------------------------------------------------------------------- train
def make_train_step(
    model,
    mesh: Mesh,
    shape: ShapeSpec,
    *,
    multi_pod: bool = False,
    adamw: Optional[optim.AdamWConfig] = None,
    microbatches: int = 1,
    strategy: str = "tp",
    grad_dtype: Optional[str] = None,
    moe_ep: bool = False,
):
    """Returns (step_fn, (param_sh, opt_sh, batch_sh), out_shardings)."""
    cfg = model.cfg
    adamw = adamw or optim.AdamWConfig(moment_dtype=cfg.opt_moment_dtype)
    rules = dict(train_rules(multi_pod, strategy))
    if moe_ep:
        rules["_moe_ep"] = True
    grad_shard_like = None  # set below for dp/zero1

    def train_step(params, opt_state, batch):
        with use_rules(rules, mesh):
            def loss_fn(p, b):
                return model.train_loss(p, b)

            if microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch
                )
            else:
                k = microbatches

                def resh(x):
                    return x.reshape(k, x.shape[0] // k, *x.shape[1:])

                mb = jax.tree_util.tree_map(resh, batch)
                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )

                def body(acc, b):
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
                    gacc, lacc = acc
                    gacc = jax.tree_util.tree_map(
                        lambda a, x: a + x.astype(jnp.float32), gacc, g
                    )
                    return (gacc, lacc + l), m

                (grads, loss_sum), ms = jax.lax.scan(body, (zeros, jnp.zeros(())), mb)
                grads = jax.tree_util.tree_map(lambda g: g / k, grads)
                loss = loss_sum / k
                metrics = jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), ms)

            if grad_dtype:
                # cast before the cross-replica reduction: halves gradient
                # all-reduce bytes (bf16 reduce, f32 master math in AdamW)
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.dtype(grad_dtype)), grads
                )
            if grad_shard_like is not None:
                # ZeRO-1 proper: pin gradients to the optimizer-shard layout
                # so GSPMD lowers the reduction as reduce-scatter (each device
                # receives only its moment shard) instead of all-reduce.
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    grads, grad_shard_like,
                )
            new_params, new_opt, info = optim.apply_updates(params, grads, opt_state, adamw)
            return new_params, new_opt, {**metrics, **info}

    # shardings
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_specs(params_shape, cfg, mesh, strategy=strategy)
    opt_shape = jax.eval_shape(lambda: optim.init_state(params_shape, adamw))
    if strategy == "dp":
        from .partition import zero1_moment_specs

        ospecs = zero1_moment_specs(opt_shape, mesh)
        # gradient shard layout = the fp32 moment layout (m tree minus quant dicts)
        def _first_spec(s):
            return s["q"] if isinstance(s, dict) else s
        grad_shard_like = jax.tree_util.tree_map(
            lambda spec: NamedSharding(mesh, _first_spec(spec)),
            ospecs["m"],
            is_leaf=lambda x: isinstance(x, P) or (isinstance(x, dict) and "q" in x),
        )
    else:
        ospecs = opt_state_specs(opt_shape, pspecs)
    bspecs = batch_specs(cfg, shape, multi_pod)
    if strategy == "dp":
        bspecs = {k: P(("data", "model"), *([None] * (len(v) - 1))) for k, v in bspecs.items()}
    param_sh = _named(mesh, pspecs)
    opt_sh = _named(mesh, ospecs)
    batch_sh = _named(mesh, bspecs)
    metrics_sh = NamedSharding(mesh, P())
    in_sh = (param_sh, opt_sh, batch_sh)
    out_sh = (param_sh, opt_sh, None)  # metrics: let XLA pick (replicated)
    return train_step, in_sh, out_sh, (params_shape, opt_shape)


# -------------------------------------------------------------------- serve
def make_prefill_step(model, mesh: Mesh, shape: ShapeSpec, *, multi_pod: bool = False):
    cfg = model.cfg
    rules = decode_rules(multi_pod, shard_kv_seq=shape.context_parallel)

    def prefill_step(params, batch):
        with use_rules(rules, mesh):
            return model.prefill(params, batch)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_specs(params_shape, cfg, mesh)
    bspecs = batch_specs(cfg, shape, multi_pod)
    in_sh = (_named(mesh, pspecs), _named(mesh, bspecs))
    out_sh = None  # logits + cache: XLA propagates
    return prefill_step, in_sh, out_sh, params_shape


def make_decode_step(model, mesh: Mesh, shape: ShapeSpec, *, multi_pod: bool = False):
    cfg = model.cfg
    rules = decode_rules(multi_pod, shard_kv_seq=shape.context_parallel)

    def decode_step(params, cache, tokens):
        with use_rules(rules, mesh):
            return model.decode_step(params, cache, tokens)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_specs(params_shape, cfg, mesh)
    cache_shape = jax.eval_shape(lambda: model.empty_cache(shape.batch, shape.seq))
    cspecs = cache_spec_tree(cache_shape, shape, mesh, multi_pod)
    b = None if shape.context_parallel else (("pod", "data") if multi_pod else ("data",))
    tok_sh = NamedSharding(mesh, P(b, None))
    param_sh = _named(mesh, pspecs)
    cache_sh = _named(mesh, cspecs)
    in_sh = (param_sh, cache_sh, tok_sh)
    # cache must come back with the same sharding (steady-state decode loop)
    out_sh = (None, cache_sh)
    return decode_step, in_sh, out_sh, (params_shape, cache_shape)
