"""Logical-axis sharding rules (MaxText-style) + constraint helper.

Models annotate activations with *logical* axis names; the active rule set
maps names to mesh axes. Outside a rule context `constrain` is a no-op, so
model code runs unmodified on a single CPU device (smoke tests).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _rules() -> Optional[Dict[str, MeshAxes]]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextmanager
def use_rules(rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
    old_r, old_m = _rules(), _mesh()
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = old_r, old_m


def spec_for(*names: Optional[str]) -> P:
    """Build a PartitionSpec from logical axis names under the active rules."""
    rules = _rules() or {}
    axes = []
    for n in names:
        a = rules.get(n) if n else None
        axes.append(a)
    return P(*axes)


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, str):
        return shape.get(axes, 1)
    n = 1
    for a in axes:
        n *= shape.get(a, 1)
    return n


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without rules.
    Axes that don't divide the dimension are dropped (graceful degradation
    for awkward dims, e.g. capacity=5 over data=16)."""
    rules = _rules()
    if rules is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"constrain: {len(names)} names for rank-{x.ndim} array")
    spec = spec_for(*names)
    mesh = _mesh()
    if mesh is not None:
        axes = [
            a if (a is None or x.shape[i] % _axis_size(mesh, a) == 0) else None
            for i, a in enumerate(spec)
        ]
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------- rule sets
def train_rules(multi_pod: bool, strategy: str = "tp") -> Dict[str, MeshAxes]:
    if strategy == "dp":
        # pure DP: batch over every non-pod axis; no tensor sharding at all
        batch = ("data", "model")
        return {k: None for k in (
            "seq", "act_seq", "embed", "heads", "kv_heads", "head_dim",
            "qkv_fused", "ff", "vocab", "experts", "expert_cap", "moe_rows",
            "moe_routes", "kv_seq", "ssm_heads", "state", "lora", "conv_dim",
        )} | {"batch": batch}
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,
        # Megatron-style sequence parallelism at residual-stream save points:
        # shards the per-layer remat carries 16x over 'model'
        "act_seq": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": None,        # kv heads usually < model size; GSPMD decides
        "head_dim": None,
        "qkv_fused": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "expert_cap": "data",
        "moe_rows": ("data", "model") if not multi_pod else ("pod", "data", "model"),
        "moe_routes": ("data", "model") if not multi_pod else ("pod", "data", "model"),
        "kv_seq": None,
        "ssm_heads": "model",
        "state": None,
        "lora": None,
        "conv_dim": "model",
    }


def decode_rules(multi_pod: bool, *, shard_kv_seq: bool = False) -> Dict[str, MeshAxes]:
    r = train_rules(multi_pod)
    if shard_kv_seq:
        # context parallelism: long_500k (batch=1) shards the KV/state length
        r["kv_seq"] = ("pod", "data") if multi_pod else ("data",)
        r["batch"] = None
    return r
