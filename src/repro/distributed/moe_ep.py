"""Expert-parallel MoE dispatch via shard_map + all_to_all (hillclimb #3).

The pjit gather-dispatch baseline lets GSPMD partition a token->slot gather
whose source rows live across the whole mesh; XLA's fallback is partial
gathers + full-buffer all-reduces (measured: ~460 s of ICI time per
deepseek-v3 train step — EXPERIMENTS.md §Perf). This module implements the
communication pattern DeepSeek actually uses: tokens travel to their
experts' owner shards over an **all_to_all on the model axis** (experts are
model-sharded; every model column holds the same experts for its data rows),
then locally dispatch/compute/combine, then all_to_all back.

Per-device per-layer traffic drops from O(E·C·d) all-reduce to
O(T_local·K·d) all-to-all — the theoretical minimum for dropless-ish MoE.

Correctness contract: same routing (sigmoid top-k, renormalized gates) and
the same capacity-drop semantics as `repro.models.moe`, applied in two
stages (send capacity per destination shard, then per-expert capacity).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.common import activation
from ..models.config import ModelConfig


def _positions_by_key(keys: jax.Array, n_buckets: int) -> jax.Array:
    """Stable position of each element within its bucket (sort trick)."""
    n = keys.shape[0]
    order = jnp.argsort(keys, stable=True)
    sorted_k = keys[order]
    seg_start = jnp.searchsorted(sorted_k, jnp.arange(n_buckets))
    pos_sorted = jnp.arange(n) - seg_start[sorted_k]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def _ep_block(x_loc, router_w, w_gate, w_up, w_down, *, cfg: ModelConfig, n_shards: int, axis: str):
    """Per-device body. x_loc (Tl, d); expert weights are the LOCAL slices
    (E_loc, d, ffe). Returns (y_loc (Tl, d), aux scalar)."""
    m = cfg.moe
    Tl, d = x_loc.shape
    E, K = m.n_experts, m.top_k
    E_loc = E // n_shards
    act = activation(cfg.mlp_act)

    # ---- routing (full router replicated: E scores per local token) --------
    logits = jnp.einsum("td,de->te", x_loc, router_w.astype(x_loc.dtype)).astype(jnp.float32)
    scores = jax.nn.sigmoid(logits) if m.router == "sigmoid" else jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(scores, K)
    gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)).astype(x_loc.dtype)
    probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    assign = jnp.zeros_like(probs).at[jnp.arange(Tl)[:, None], idx].add(1.0)
    aux = jnp.mean(jnp.mean(probs, 0) * jnp.mean(assign, 0)) * (E**2) * m.aux_loss_coef
    aux = jax.lax.pmean(aux, axis)

    # ---- stage 1: send routes to expert-owner shards ------------------------
    flat_e = idx.reshape(-1)                      # (Tl*K,) global expert id
    dest = (flat_e // E_loc).astype(jnp.int32)    # owner shard on `axis`
    Cs = max(1, int(math.ceil(Tl * K / n_shards * m.capacity_factor)))
    pos_in_dest = _positions_by_key(dest, n_shards)
    keep1 = pos_in_dest < Cs
    slot1 = jnp.where(keep1, dest * Cs + pos_in_dest, n_shards * Cs)

    flat_tok = (jnp.arange(Tl * K) // K).astype(jnp.int32)
    tok_for_slot = jnp.full((n_shards * Cs + 1,), Tl, jnp.int32).at[slot1].set(flat_tok)[:-1]
    eloc_for_slot = jnp.full((n_shards * Cs + 1,), 0, jnp.int32).at[slot1].set(
        (flat_e % E_loc).astype(jnp.int32)
    )[:-1]
    occupied = jnp.zeros((n_shards * Cs + 1,), jnp.bool_).at[slot1].set(keep1)[:-1]

    x_pad = jnp.concatenate([x_loc, jnp.zeros((1, d), x_loc.dtype)], 0)
    send = x_pad[tok_for_slot].reshape(n_shards, Cs, d)
    send_meta = jnp.stack(
        [eloc_for_slot, occupied.astype(jnp.int32)], axis=-1
    ).reshape(n_shards, Cs, 2)

    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0, tiled=False)
    recv_meta = jax.lax.all_to_all(send_meta, axis, split_axis=0, concat_axis=0, tiled=False)
    # recv: (n_shards, Cs, d) — row i came from source shard i
    rows = recv.reshape(n_shards * Cs, d)
    r_eloc = recv_meta.reshape(-1, 2)[:, 0]
    r_occ = recv_meta.reshape(-1, 2)[:, 1] > 0

    # ---- stage 2: local per-expert dispatch --------------------------------
    C2 = max(1, int(math.ceil(rows.shape[0] / E_loc * m.capacity_factor)))
    key2 = jnp.where(r_occ, r_eloc, E_loc)  # unoccupied rows -> overflow bucket
    pos2 = _positions_by_key(key2.astype(jnp.int32), E_loc + 1)
    keep2 = (pos2 < C2) & r_occ
    slot2 = jnp.where(keep2, r_eloc * C2 + pos2, E_loc * C2)

    row_for_slot = jnp.full((E_loc * C2 + 1,), rows.shape[0], jnp.int32).at[slot2].set(
        jnp.arange(rows.shape[0], dtype=jnp.int32)
    )[:-1]
    rows_pad = jnp.concatenate([rows, jnp.zeros((1, d), rows.dtype)], 0)
    buf = rows_pad[row_for_slot].reshape(E_loc, C2, d)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", act(g) * u, w_down.astype(buf.dtype))
    y_buf = y_buf.reshape(E_loc * C2, d)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)], 0)

    y_rows = y_buf[slot2] * keep2[:, None].astype(y_buf.dtype)  # (n_shards*Cs, d)

    # ---- return trip + combine ----------------------------------------------
    y_send = y_rows.reshape(n_shards, Cs, d)
    y_recv = jax.lax.all_to_all(y_send, axis, split_axis=0, concat_axis=0, tiled=False)
    y_flat = jnp.concatenate([y_recv.reshape(n_shards * Cs, d), jnp.zeros((1, d), y_recv.dtype)], 0)
    yk = y_flat[slot1] * (gates.reshape(-1, 1) * keep1[:, None].astype(y_recv.dtype))
    y_loc = jnp.sum(yk.reshape(Tl, K, d), axis=1)
    return y_loc, aux


def moe_ffn_ep(
    p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, mesh, *, data_axes=("data",), shared: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert-parallel MoE. x (B, S, d) sharded batch over data
    axes; expert weights model-sharded; shared experts handled outside in
    plain TP (same as the baseline)."""
    from jax.experimental.shard_map import shard_map

    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    n_shards = dict(mesh.shape)["model"]
    all_axes = tuple(data_axes) + ("model",)

    x2d = x.reshape(T, d)

    inner = partial(_ep_block, cfg=cfg, n_shards=n_shards, axis="model")

    def block(x_loc, router_w, wg, wu, wd):
        # chunk-scan INSIDE the shard_map: weights enter once (one FSDP
        # gather per layer), dispatch buffers stay chunk-sized
        Tl = x_loc.shape[0]
        nc = m.dispatch_chunks if (m.dispatch_chunks > 1 and Tl % m.dispatch_chunks == 0) else 1
        if nc == 1:
            return inner(x_loc, router_w, wg, wu, wd)
        xs = x_loc.reshape(nc, Tl // nc, -1)

        def body(carry, xc):
            yc, auxc = inner(xc, router_w, wg, wu, wd)
            return carry, (yc, auxc)

        _, (ys, auxes) = jax.lax.scan(body, None, xs)
        return ys.reshape(Tl, -1), jnp.mean(auxes)

    y2d, aux = shard_map(
        block,
        mesh=mesh,
        in_specs=(
            P(all_axes, None),            # tokens split across every axis
            P(None, None),                # router replicated
            P("model", None, None),       # expert weights: E over model
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(all_axes, None), P()),
        check_rep=False,
    )(x2d, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    y = y2d.reshape(B, S, d)
    if shared and m.n_shared:
        act = activation(cfg.mlp_act)
        sg = jnp.einsum("bsd,df->bsf", x, p["shared_gate"].astype(x.dtype))
        su = jnp.einsum("bsd,df->bsf", x, p["shared_up"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", act(sg) * su, p["shared_down"].astype(x.dtype))
    return y, aux
