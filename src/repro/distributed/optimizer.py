"""Sharded AdamW with memory-plan options for trillion-parameter configs.

Moments are stored per the model config's ``opt_moment_dtype``:

* ``float32`` — standard AdamW (dense archs).
* ``int8``    — blockwise-quantized moments (block 128 along the trailing
  axis, absmax scaling), the 8-bit-Adam trick that brings deepseek-v3 /
  kimi-k2 optimizer state under the 16 GiB/chip HBM budget (DESIGN.md §3).

Optimizer state shards exactly like its parameter (same tree structure),
so partition specs map 1:1.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # 'float32' | 'int8'
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


# ------------------------------------------------------------- quantization
def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    n = x.shape[-1]
    pad = (-n) % BLOCK
    if pad:
        x = jnp.concatenate([x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x, n


def quantize_blockwise(x: jax.Array) -> Dict[str, jax.Array]:
    """int8 absmax quantization over trailing-axis blocks of 128."""
    xp, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(*xp.shape[:-1], -1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(xp.shape), "scale": scale[..., 0]}


def dequantize_blockwise(d: Dict[str, jax.Array], n: int) -> jax.Array:
    q = d["q"].astype(jnp.float32)
    blocks = q.reshape(*q.shape[:-1], -1, BLOCK)
    x = blocks * d["scale"][..., None]
    x = x.reshape(q.shape)
    return x[..., :n]


# ------------------------------------------------------------------- state
def _quantizable(p) -> bool:
    """Blockwise int8 pays off only for real tensors (scalars/tiny vectors
    keep fp32 moments — they're negligible memory anyway)."""
    return p.ndim >= 1 and p.size >= BLOCK


def init_state(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    def zero_moment(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.moment_dtype == "int8" and _quantizable(p):
            return quantize_blockwise(z)
        return z

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zero_moment, params),
        "v": jax.tree_util.tree_map(zero_moment, params),
    }


def _lr_at(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    t = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = _lr_at(step, cfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    quantized = cfg.moment_dtype == "int8"

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        q_leaf = quantized and isinstance(m, dict)
        n = p.shape[-1] if p.ndim else 1
        m_f = dequantize_blockwise(m, n) if q_leaf else m
        v_f = dequantize_blockwise(v, n) if q_leaf else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        u = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        m2 = quantize_blockwise(m_f) if q_leaf else m_f
        v2 = quantize_blockwise(v_f) if q_leaf else v_f
        return p2, m2, v2

    def upd_maybe_scanned(p, g, m, v):
        # layer-stacked leaves (leading scan dim): update one layer at a time
        # so the f32 moment/update temporaries are layer-sized, not
        # stack-sized (a (58, 16, 7168, 2048) f32 temp is 50 GiB/device;
        # scanned it is 0.9 GiB — see EXPERIMENTS.md §Perf deepseek log).
        stacked = p.ndim >= 3 and p.shape[0] <= 128 and (p.size // p.shape[0]) >= (1 << 20)
        if not stacked:
            return upd(p, g, m, v)

        def body(_, slices):
            ps, gs, ms, vs = slices
            return None, upd(ps, gs, ms, vs)

        _, (p2, m2, v2) = jax.lax.scan(body, None, (p, g, m, v))
        return p2, m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    # quantized moments are dicts (deeper trees); flatten_up_to stops at the
    # param treedef so each entry is the whole {"q","scale"} dict.
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd_maybe_scanned(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
