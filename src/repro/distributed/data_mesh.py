"""Multi-host data mesh: shard-aware distributed ingest with elastic
ownership (DESIGN.md §15).

The per-host data plane (engine waves, remote ranged reads, device feed)
never knew about the process mesh: every host took a contiguous
``host_range`` row block and permuted it privately, so (a) the last host
ran a different step count, (b) rows never mixed across hosts, and (c)
every host still had to be *able* to read every shard. This module makes
the shard — the unit the paper's byte layout already hands us — the unit
of distribution:

* **Shard ownership** — a deterministic assignment of manifest shards to
  hosts via consistent hashing (the fleet's ``HashRing``, DESIGN.md §14):
  ``owner(s) = HashRing(members).lookup("shard:<s>#e<epoch>")``. Pure
  function of ``(members, epoch)``, identical on every host, and a
  membership change moves only ~1/N of the shards. The epoch salt
  re-deals shards every epoch so rows DO mix across hosts between epochs
  (knob ``RA_MESH_EPOCH_REOWN``); within an epoch a host opens and
  fetches only the shard bytes it owns.
* **Deterministic global shuffle** — a pure function of ``(seed, epoch)``
  evaluated identically everywhere but materialized only for owned rows:
  a global permutation of *shard order* plus an independent permutation
  *within* each shard. No host reads a byte it does not own, yet the
  composition of every global batch changes each epoch.
* **Elastic epochs** — ``EpochPlan`` is pure over a *segment history*
  ``[(start_step, members), ...]``: a host joining or leaving mid-epoch
  appends a segment, every host re-derives the per-shard consumed counts
  by replaying the closed segments (pure arithmetic — no coordination
  traffic), and the remaining rows re-partition under the new ownership
  with no row duplicated or dropped and no epoch restart. The history
  rides in the extended ``LoaderState``, so elastic epochs are resumable.
* **Lockstep steps** — steps per epoch is the GLOBAL MINIMUM over hosts,
  so a collective never hangs on another host's tail batch; the dropped
  tail is an explicit counter, not a silent divergence.

``DataLoader(mesh=DataMesh(...))`` is the entry point (``repro.data``);
``DeviceLoader`` assembles the per-host local batches into global
``jax.Array``s via ``jax.make_array_from_single_device_arrays`` so the
sharded step factories in ``repro.distributed.steps`` run unchanged.
``aggregate_stats`` folds the per-host loader counters into one
straggler summary; ``racat owners`` prints the ownership table for any
manifest without reading a payload byte.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.spec import RawArrayError, env_int, env_str
from ..fleet.router import HashRing

# rng stream salts: shard-order vs within-shard permutations must never
# collide for the same (seed, epoch)
_SHARD_STREAM = 0x5A
_ROW_STREAM = 0xB0


def default_mesh_vnodes() -> int:
    """Virtual nodes per host on the ownership ring (``RA_MESH_VNODES``,
    default 64 — same default as the fleet router's ring)."""
    return max(1, env_int("RA_MESH_VNODES", 64))


def epoch_reown() -> bool:
    """Whether ownership is re-dealt every epoch (``RA_MESH_EPOCH_REOWN``,
    default 1). With 0 a shard stays pinned to one host across epochs —
    cheaper fd/cache churn, but rows never migrate between hosts."""
    return env_int("RA_MESH_EPOCH_REOWN", 1) != 0


def shard_owners(
    nshards: int,
    members: Sequence[str],
    epoch: int = 0,
    *,
    vnodes: Optional[int] = None,
) -> List[str]:
    """Deterministic shard → host assignment: one consistent-hash ring
    over ``members`` (BLAKE2b — identical in every process), looked up
    per shard. A membership change moves only ~1/len(members) of the
    shards; the epoch salt re-deals the assignment each epoch (see
    ``epoch_reown``)."""
    if not members:
        raise RawArrayError("shard ownership needs at least one host")
    ring = HashRing(members, vnodes=default_mesh_vnodes() if vnodes is None else vnodes)
    salt = f"#e{int(epoch)}" if epoch_reown() else ""
    return [ring.lookup(f"shard:{i}{salt}") for i in range(nshards)]


def shard_perm(seed: int, epoch: int, nshards: int, shuffle: bool = True) -> np.ndarray:
    """Global permutation of shard order — the coarse half of the global
    shuffle. Pure function of ``(seed, epoch)``."""
    if not shuffle:
        return np.arange(nshards, dtype=np.int64)
    rng = np.random.default_rng((seed, epoch, _SHARD_STREAM))
    return rng.permutation(nshards).astype(np.int64)


def within_perm(seed: int, epoch: int, shard: int, rows: int, shuffle: bool = True) -> np.ndarray:
    """Permutation of one shard's local rows — the fine half of the global
    shuffle. Pure function of ``(seed, epoch, shard)``, so any host (owner
    or not) derives the same order without reading the shard."""
    if not shuffle:
        return np.arange(rows, dtype=np.int64)
    rng = np.random.default_rng((seed, epoch, _ROW_STREAM, shard))
    return rng.permutation(rows).astype(np.int64)


Segment = Tuple[int, Tuple[str, ...]]


def _normalize_segments(segments) -> List[Segment]:
    out: List[Segment] = []
    for step, members in segments:
        members = tuple(str(m) for m in members)
        if not members:
            raise RawArrayError("mesh segment with empty membership")
        if out and int(step) < out[-1][0]:
            raise RawArrayError(
                f"mesh segments must be step-monotone: {int(step)} after {out[-1][0]}"
            )
        if out and int(step) == out[-1][0]:
            out[-1] = (int(step), members)  # same-boundary replace
        else:
            out.append((int(step), members))
    if not out:
        raise RawArrayError("mesh needs at least one segment")
    if out[0][0] != 0:
        raise RawArrayError(f"first mesh segment must start at step 0, got {out[0][0]}")
    return out


class EpochPlan:
    """The global schedule of one epoch — a pure function of
    ``(shard_rows, seed, epoch, segments, batch_size)``; every host
    evaluates the identical plan and materializes only its own rows.

    Within a segment, host ``h``'s stream is the concatenation, in global
    shard-permutation order, of the *not yet consumed* slice of
    ``within_perm`` for every shard it owns; it consumes ``batch_size``
    rows per step. Steps per segment is the minimum over members (lockstep
    collectives never outrun the smallest owner). Closed segments replay
    into per-shard consumed counts — which is pure length arithmetic, so
    a joining host reconstructs the epoch's exact position from
    ``(seed, epoch, segment history)`` alone.
    """

    def __init__(
        self,
        shard_rows: Sequence[int],
        *,
        seed: int,
        epoch: int,
        segments: Sequence[Tuple[int, Sequence[str]]],
        batch_size: int,
        shuffle: bool = True,
        vnodes: Optional[int] = None,
    ):
        if batch_size < 1:
            raise RawArrayError(f"batch_size must be >= 1, got {batch_size}")
        self.shard_rows = tuple(int(r) for r in shard_rows)
        self.seed, self.epoch = int(seed), int(epoch)
        self.batch_size = int(batch_size)
        self.shuffle = shuffle
        self.vnodes = vnodes
        self.segments = _normalize_segments(segments)
        self.total_rows = int(sum(self.shard_rows))
        self._row_offset = np.concatenate(
            [[0], np.cumsum(self.shard_rows)]
        ).astype(np.int64)
        self._perm = shard_perm(self.seed, self.epoch, len(self.shard_rows), shuffle)
        self._wperm: Dict[int, np.ndarray] = {}  # shard -> within_perm memo
        self._build()

    # -- schedule construction -------------------------------------------

    def _build(self) -> None:
        B = self.batch_size
        consumed = np.zeros(len(self.shard_rows), dtype=np.int64)
        # per segment: (t0, steps, members, runs_by_host); a run is
        # (shard, lo, hi) into within_perm(shard) in shard-perm order
        self._seg: List[Tuple[int, int, Tuple[str, ...], Dict[str, List[Tuple[int, int, int]]]]] = []
        for k, (t0, members) in enumerate(self.segments):
            owners = shard_owners(
                len(self.shard_rows), members, self.epoch, vnodes=self.vnodes
            )
            runs: Dict[str, List[Tuple[int, int, int]]] = {m: [] for m in members}
            for s in self._perm:
                s = int(s)
                lo, hi = int(consumed[s]), self.shard_rows[s]
                if lo < hi:
                    runs[owners[s]].append((s, lo, hi))
            avail = {
                m: sum(hi - lo for _, lo, hi in rs) for m, rs in runs.items()
            }
            if k + 1 < len(self.segments):
                steps = self.segments[k + 1][0] - t0
                short = [m for m in members if avail[m] < steps * B]
                if short:
                    raise RawArrayError(
                        f"mesh segment at step {t0} runs {steps} steps but "
                        f"host(s) {short} own fewer than {steps * B} rows"
                    )
            else:
                steps = min(avail[m] // B for m in members) if members else 0
            # replay this segment's consumption into the per-shard counts
            for m in members:
                need = steps * B
                for s, lo, hi in runs[m]:
                    if need <= 0:
                        break
                    take = min(hi - lo, need)
                    consumed[s] += take
                    need -= take
            self._seg.append((t0, steps, tuple(members), runs))
        self._consumed_end = consumed

    # -- queries ----------------------------------------------------------

    def steps(self) -> int:
        """Total steps this epoch delivers (identical on every host)."""
        t0, steps, _, _ = self._seg[-1]
        return t0 + steps

    def members_at(self, step: int) -> Tuple[str, ...]:
        members = self._seg[0][2]
        for t0, _, m, _ in self._seg:
            if step >= t0:
                members = m
        return members

    def dropped_rows(self) -> int:
        """Rows this epoch never delivers (the lockstep tail): global, and
        by construction the same number on every host."""
        return self.total_rows - int(self._consumed_end.sum())

    def owned_shards(self, host: str) -> List[int]:
        """Every shard ``host`` owns in ANY segment of this epoch — the
        superset of shards it may legitimately open or fetch."""
        owned = set()
        for _, _, members, runs in self._seg:
            for s, _, _ in runs.get(host, ()):
                owned.add(s)
        return sorted(owned)

    def _within(self, s: int) -> np.ndarray:
        w = self._wperm.get(s)
        if w is None:
            w = within_perm(self.seed, self.epoch, s, self.shard_rows[s], self.shuffle)
            self._wperm[s] = w
        return w

    def host_stream(self, host: str, segment: int = -1) -> np.ndarray:
        """Every global row id ``host`` could deliver in one segment
        (default: the final one), unbounded by the step count — union over
        hosts of a segment's streams is exactly the epoch's undelivered
        rows at that segment's start."""
        _, _, _, runs = self._seg[segment]
        parts = [
            self._row_offset[s] + self._within(s)[lo:hi]
            for s, lo, hi in runs.get(host, ())
        ]
        if not parts:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(parts)

    def host_order(self, host: str) -> np.ndarray:
        """Global row ids ``host`` delivers this epoch, as one array of
        length ``steps() * batch_size`` indexed by step — position
        ``[t*B:(t+1)*B]`` is batch ``t``. Steps where ``host`` was not a
        member are filled with -1 (a loader positioned there raises)."""
        B = self.batch_size
        order = np.full(self.steps() * B, -1, dtype=np.int64)
        for t0, steps, members, _ in self._seg:
            if host not in members or steps == 0:
                continue
            need = steps * B
            seg_rows = self.host_stream(host, self._seg_index(t0))[:need]
            order[t0 * B : t0 * B + len(seg_rows)] = seg_rows
        return order

    def _seg_index(self, t0: int) -> int:
        for i, (t, _, _, _) in enumerate(self._seg):
            if t == t0:
                return i
        raise RawArrayError(f"no mesh segment starts at step {t0}")


class DataMesh:
    """One host's view of the data mesh: its identity, the ordered member
    list, and the per-epoch segment history that records membership
    changes. Construction is cheap; all scheduling is in ``EpochPlan``.

    ``DataMesh.from_env()`` builds one from ``RA_MESH_HOSTS`` (comma-
    separated member names) + ``RA_MESH_HOST`` (this host) — the CLI /
    multi-process entry point.
    """

    def __init__(self, host: str, hosts: Sequence[str], *, vnodes: Optional[int] = None):
        members = tuple(str(h) for h in hosts)
        if len(set(members)) != len(members):
            raise RawArrayError(f"duplicate mesh host names: {members}")
        if str(host) not in members:
            raise RawArrayError(f"host {host!r} not in mesh members {members}")
        self.host = str(host)
        self.vnodes = vnodes
        self._members = members
        self._segments: Dict[int, List[Segment]] = {}

    @classmethod
    def from_env(cls) -> Optional["DataMesh"]:
        hosts = env_str("RA_MESH_HOSTS")
        host = env_str("RA_MESH_HOST")
        names = [h.strip() for h in hosts.split(",") if h.strip()]
        if not names or not host:
            return None
        return cls(host, names)

    # -- membership --------------------------------------------------------

    @property
    def hosts(self) -> Tuple[str, ...]:
        """Current membership (the last recorded segment's)."""
        return self._members

    @property
    def host_count(self) -> int:
        return len(self._members)

    @property
    def host_index(self) -> int:
        """Position of this host in the current membership — the data-axis
        block it feeds when batches assemble into global arrays. -1 once the
        host has left the membership (its loader then only drains stats)."""
        try:
            return self._members.index(self.host)
        except ValueError:
            return -1

    def segments_for(self, epoch: int) -> List[Segment]:
        """Segment history of ``epoch``; an epoch with no recorded change
        is one segment of the current membership from step 0."""
        segs = self._segments.get(int(epoch))
        return list(segs) if segs else [(0, self._members)]

    def repartition(self, hosts: Sequence[str], *, epoch: int, step: int) -> None:
        """Record a membership change effective at ``(epoch, step)``. Every
        surviving host must record the identical change at the identical
        step (it is part of the deterministic schedule); a joining host
        records the history it was handed and seeks to ``step``."""
        members = tuple(str(h) for h in hosts)
        if len(set(members)) != len(members):
            raise RawArrayError(f"duplicate mesh host names: {members}")
        segs = self.segments_for(int(epoch))
        segs = _normalize_segments(segs + [(int(step), members)])
        self._segments = {int(epoch): segs}  # older epochs are closed history
        self._members = members

    def load_segments(self, epoch: int, segments) -> None:
        """Restore the segment history of ``epoch`` (from an extended
        ``LoaderState``); membership becomes the last segment's."""
        segs = _normalize_segments(segments)
        self._segments = {int(epoch): segs}
        self._members = segs[-1][1]

    # -- scheduling --------------------------------------------------------

    def plan(
        self,
        shard_rows: Sequence[int],
        *,
        seed: int,
        epoch: int,
        batch_size: int,
        shuffle: bool = True,
    ) -> EpochPlan:
        return EpochPlan(
            shard_rows,
            seed=seed,
            epoch=epoch,
            segments=self.segments_for(epoch),
            batch_size=batch_size,
            shuffle=shuffle,
            vnodes=self.vnodes,
        )


# -------------------------------------------------------------------------
# global-array assembly (jax deferred: the mesh schedule itself is numpy)
# -------------------------------------------------------------------------


def data_sharding(axis_name: str = "data"):
    """``NamedSharding`` splitting axis 0 over EVERY device of the process
    mesh (1-D ``(data,)`` device mesh over ``jax.devices()``). With one
    process per mesh host, host ``h``'s addressable devices hold global
    rows ``[h*local_B, (h+1)*local_B)`` — exactly the block its loader
    materializes."""
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    devs = np.array(jax.devices())
    return NamedSharding(Mesh(devs, (axis_name,)), PartitionSpec(axis_name))


def make_global_batch(
    local_fields: Dict[str, Any],
    host_count: int,
    *,
    sharding=None,
    local_devices=None,
    detach: bool = False,
):
    """Assemble this host's local batch into global ``jax.Array``s via
    ``jax.make_array_from_single_device_arrays``: each field's local rows
    split across this host's ``local_devices`` along axis 0, declared as
    the addressable shards of a ``(host_count * local_B, ...)`` global
    array. The result feeds ``distributed.steps`` factories unchanged —
    a train step sees one logical batch sharded over the ``data`` axis.

    Requires one process per mesh host (``jax.process_count() ==
    host_count``); ``detach=True`` copies rows out of a reused staging
    ring before the transfer."""
    import jax

    if local_devices is None:
        local_devices = jax.local_devices()
    if sharding is None:
        sharding = data_sharding()
    nd = len(local_devices)
    out: Dict[str, Any] = {}
    for name, v in local_fields.items():
        n = int(v.shape[0])
        if n % nd:
            raise RawArrayError(
                f"{name}: local batch of {n} rows does not split over "
                f"{nd} local devices"
            )
        per = n // nd
        shards = [
            jax.device_put(
                np.array(v[i * per : (i + 1) * per], copy=True)
                if detach
                else v[i * per : (i + 1) * per],
                d,
            )
            for i, d in enumerate(local_devices)
        ]
        gshape = (n * host_count,) + tuple(v.shape[1:])
        out[name] = jax.make_array_from_single_device_arrays(
            gshape, sharding, shards
        )
    return out


# -------------------------------------------------------------------------
# observability: ownership table + cross-host stats aggregation
# -------------------------------------------------------------------------


def _manifest_shards(root: str) -> Tuple[List[int], List[int]]:
    """``(rows, bytes)`` per shard of a dataset root / ``manifest.json`` /
    sharded-store dir — manifest only, ZERO payload (or header) reads.
    Bytes are stored row bytes (uint8 for quantized fields)."""
    path = root
    if os.path.isdir(root):
        for name in ("manifest.json", "index.json"):
            cand = os.path.join(root, name)
            if os.path.exists(cand):
                path = cand
                break
        else:
            raise RawArrayError(f"{root}: no manifest.json or index.json")
    with open(path) as f:
        man = json.load(f)
    if man.get("format") == "rawarray-dataset-v1":
        row_nbytes = 0
        for info in man["fields"].values():
            dt = np.dtype("uint8") if info.get("quant") else np.dtype(info["dtype"])
            row_nbytes += dt.itemsize * int(np.prod(info["shape"], dtype=np.int64))
        rows = [int(s["rows"]) for s in man["shards"]]
        return rows, [r * row_nbytes for r in rows]
    if man.get("format") == "rawarray-sharded-v1":
        offs = man["offsets"]
        rows = [int(b) - int(a) for a, b in zip(offs, offs[1:])]
        # index stores the logical shape; rows run along man["axis"]
        shape = [int(d) for d in man["shape"]]
        per_row = int(np.prod(shape, dtype=np.int64)) // max(1, shape[int(man.get("axis", 0))])
        row_nbytes = np.dtype(man["dtype"]).itemsize * per_row
        return rows, [r * row_nbytes for r in rows]
    raise RawArrayError(f"{path}: not a dataset manifest or sharded index")


def owners_table(
    root: str,
    hosts: Sequence[str],
    *,
    epoch: int = 0,
    vnodes: Optional[int] = None,
) -> Dict[str, Any]:
    """Shard → host assignment for a manifest: per-shard
    ``(shard, rows, bytes, owner)`` rows plus per-host totals and the
    byte imbalance ratio (max host bytes / mean host bytes). Reads only
    the manifest — never a payload byte."""
    rows, nbytes = _manifest_shards(root)
    owners = shard_owners(len(rows), hosts, epoch, vnodes=vnodes)
    shards = [
        {"shard": i, "rows": rows[i], "bytes": nbytes[i], "owner": owners[i]}
        for i in range(len(rows))
    ]
    per_host = {
        h: {"shards": 0, "rows": 0, "bytes": 0} for h in (str(h) for h in hosts)
    }
    for s in shards:
        t = per_host[s["owner"]]
        t["shards"] += 1
        t["rows"] += s["rows"]
        t["bytes"] += s["bytes"]
    byte_totals = [t["bytes"] for t in per_host.values()]
    mean = sum(byte_totals) / max(1, len(byte_totals))
    imbalance = (max(byte_totals) / mean) if mean else 1.0
    return {
        "epoch": int(epoch),
        "hosts": [str(h) for h in hosts],
        "shards": shards,
        "per_host": per_host,
        "total_rows": sum(rows),
        "total_bytes": sum(nbytes),
        "imbalance": imbalance,
    }


def aggregate_stats(per_host: Sequence[Dict[str, float]]) -> Dict[str, float]:
    """Fold per-host ``DataLoader`` / ``DeviceLoader`` ``stats()`` dicts
    (each tagged with ``host_id``) into one fleet view: counters sum,
    every ``*_s`` timing also reports ``_max`` / ``_mean`` plus the
    straggler summary — ``straggler_host`` is the host with the largest
    produce time and ``produce_skew`` its ratio over the mean (the same
    slow-host signal the fleet's ``/metrics`` counters expose per
    replica)."""
    per_host = [dict(d) for d in per_host]
    if not per_host:
        return {"hosts": 0.0}
    out: Dict[str, float] = {"hosts": float(len(per_host))}
    keys = sorted({k for d in per_host for k in d if k != "host_id"})
    for k in keys:
        vals = [float(d[k]) for d in per_host if k in d]
        out[k] = float(sum(vals))
        if k.endswith("_s"):
            out[f"{k}_max"] = float(max(vals))
            out[f"{k}_mean"] = float(sum(vals) / len(vals))
    produce = [float(d.get("loader_produce_s", 0.0)) for d in per_host]
    worst = int(np.argmax(produce))
    out["straggler_host"] = float(per_host[worst].get("host_id", worst))
    mean = sum(produce) / len(produce)
    out["produce_skew"] = float(produce[worst] / mean) if mean else 1.0
    # lockstep sanity: dropped tails are global, so they must agree
    tails = {float(d["dropped_tail_rows"]) for d in per_host if "dropped_tail_rows" in d}
    if len(tails) == 1:
        out["dropped_tail_rows"] = tails.pop()
    return out
