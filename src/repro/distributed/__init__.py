"""Distributed runtime: meshes, sharding rules, train/serve step factories."""
