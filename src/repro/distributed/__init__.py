"""Distributed runtime: meshes, sharding rules, train/serve step factories.

``data_mesh`` (DESIGN.md §15) is the data plane's view of the process
mesh: shard-ownership partitioning, the deterministic global shuffle, and
elastic membership. It stays numpy-only at import time; the jax-dependent
assembly helpers defer their import.
"""

from typing import Any

__all__ = ["DataMesh"]


def __getattr__(name: str) -> Any:
    # lazy: most distributed users (partition/steps) never need the data
    # mesh, and data_mesh pulls in the fleet's hash ring
    if name == "DataMesh":
        from .data_mesh import DataMesh

        return DataMesh
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
