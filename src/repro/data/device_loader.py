"""Device feed plane: prefetch-to-device loader with on-device dequant
(DESIGN.md §12).

The host ``DataLoader`` ends at numpy batches in host RAM; a train step
then pays host→device transfer *inside* its critical path, and the
paper's read-bandwidth win evaporates at the device boundary.
``DeviceLoader`` closes that gap:

* a feeder thread pulls host batches (the loader's prefetch ring is the
  staging buffer) and ``jax.device_put``s every field, keeping up to
  ``RA_DEVICE_BUFS`` (default 2) batches RESIDENT ON DEVICE — so host
  gather, staging-buffer fill, and the H2D copy all overlap the running
  train step;
* quantized fields (DESIGN.md §12) cross the PCIe/ICI link as uint8 —
  4× fewer bytes than float32 — and are decoded ON DEVICE by the fused
  Pallas kernel ``repro.kernels.ops.dequant_u8`` (one HBM read of the u8
  codes, fused ``q*scale + bias``); the wrapped loader's host-side
  dequantization is turned off automatically;
* ``stats()`` folds ``h2d_s`` (time inside device transfers), ``h2d_bytes``
  (bytes actually moved) and ``device_wait_s`` (consumer starved on the
  device queue) into the wrapped loader's counters, so the train loop's
  straggler monitor sees the whole feed path;
* wrapping a mesh loader (``DataLoader(mesh=...)``, DESIGN.md §15) turns on
  **global assembly**: each host's local batch is split over its addressable
  devices and declared as the local shards of one global ``jax.Array`` via
  ``jax.make_array_from_single_device_arrays`` (data axis = mesh hosts ×
  local devices), so the sharded train-step factories in
  ``repro.distributed.steps`` consume mesh batches unchanged.

Safety: the feeder blocks until each transfer completes before pulling the
next host batch, so the wrapped loader's ``reuse_buffers`` ring is never
overwritten mid-copy; device batches are immutable ``jax.Array``s. The
dequant kernel is dispatched on the feeder thread too — decode belongs to
the feed pipeline, leaving the consumer's critical path as nothing but a
queue pop and its train step (jax compiled-function execution is
thread-safe). Producer errors are sticky exactly like the host loader's:
every ``next()`` after a failure re-raises instead of hanging.

Usage (flag-gated in ``repro.launch.train`` via ``--device-feed``)::

    loader = DeviceLoader(DataLoader(RaDataset(root), batch, ...))
    batch = next(loader)        # fields are jax.Arrays, already on device
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from ..core.spec import RawArrayError, env_int
from .loader import DataLoader, LoaderState


def default_device_bufs() -> int:
    """Device-resident batch depth (knob ``RA_DEVICE_BUFS``, default 2)."""
    return max(1, env_int("RA_DEVICE_BUFS", 2))


class DeviceLoader:
    """Wrap a ``DataLoader`` so consumers receive device-resident batches.

    ``bufs`` device batches (knob ``RA_DEVICE_BUFS``) are kept in flight;
    quantized fields are moved as uint8 and dequantized on device with the
    fused Pallas kernel (DESIGN.md §12). The wrapped loader must not have
    started iterating yet (its prefetch pipeline is reconfigured here).
    """

    def __init__(
        self,
        loader: DataLoader,
        *,
        bufs: Optional[int] = None,
        device: Any = None,
        interpret: Optional[bool] = None,
        block_rows: Optional[int] = None,
        global_arrays: Optional[bool] = None,
    ):
        import jax  # deferred: keep `repro.data` importable without jax

        if loader._q is not None or loader._thread is not None:
            raise RawArrayError(
                "DeviceLoader must wrap a DataLoader that has not started "
                "iterating (stop() it first)"
            )
        self._jax = jax
        self.loader = loader
        mesh = getattr(loader, "mesh", None)
        # a mesh loader assembles global jax.Arrays by default; override only
        # to keep plain per-host arrays (e.g. non-collective eval loops)
        self.global_arrays = (mesh is not None) if global_arrays is None else bool(global_arrays)
        if self.global_arrays:
            if mesh is None:
                raise RawArrayError(
                    "global_arrays=True requires DataLoader(mesh=...)"
                )
            if mesh.host_count > 1 and jax.process_count() != mesh.host_count:
                raise RawArrayError(
                    f"global assembly needs one jax process per mesh host: "
                    f"mesh has {mesh.host_count} hosts but "
                    f"jax.process_count()={jax.process_count()} (use "
                    f"data_mesh.make_global_batch directly to simulate)"
                )
        self._gsharding: Any = None  # lazy data_mesh.data_sharding()
        self._gdevices: Any = None
        # device decode replaces host decode: raw uint8 over the wire
        loader.dequant = False
        self.bufs = max(1, bufs if bufs is not None else default_device_bufs())
        self.device = device
        self._interpret = interpret
        self._block_rows = block_rows
        self._quant_dev: Dict[str, Tuple[Any, Any, np.dtype]] = {}
        # Regression note (ralint guarded-by): the feeder thread writes the
        # h2d_* counters while the consumer writes _wait_s/_n_batches and
        # stats() reads both — previously with no lock anywhere.
        self._stats_lock = threading.Lock()
        self._h2d_s = 0.0      # guarded-by: _stats_lock
        self._h2d_bytes = 0    # guarded-by: _stats_lock
        self._h2d_n = 0        # guarded-by: _stats_lock
        self._wait_s = 0.0     # guarded-by: _stats_lock
        self._n_batches = 0    # guarded-by: _stats_lock
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None

    # ---- quantized-field kernel parameters ---------------------------------
    def _quant_params(self) -> Dict[str, Tuple[Any, Any, np.dtype]]:
        """Per-field ``(scale, bias, out_dtype)`` with scale/bias already on
        device, built once: the dequant kernel wants ``(C,)`` float32 for
        the last axis of each quantized field."""
        if not self._quant_dev:
            for f, info in getattr(self.loader.ds, "quant", {}).items():
                shape, _ = self.loader.ds.logical_spec(f)
                if not shape:
                    raise RawArrayError(
                        f"quantized field {f!r} has a scalar row shape"
                    )
                scale, bias = info.channel_params(int(shape[-1]))
                self._quant_dev[f] = (
                    self._jax.device_put(scale, self.device),
                    self._jax.device_put(bias, self.device),
                    np.dtype(info.orig_dtype),
                )
        return self._quant_dev

    # ---- feeder thread ------------------------------------------------------
    def _start(self) -> None:
        jax = self._jax
        q = self._q = queue.Queue(maxsize=self.bufs)
        stop = self._stop = threading.Event()
        self._exc = None
        dev = self.device
        # captured by value: a zombie feeder that outlives its join timeout
        # keeps THIS loader object even after stop() swaps in a fresh one,
        # so it can never steal batches from (or poison the sticky-error
        # state of) a restarted pipeline
        loader = self.loader

        # device_put MAY alias host memory zero-copy (the CPU backend does
        # for aligned arrays): with a reused staging ring the bytes must be
        # detached first or the "device" batch changes under the consumer
        # when the ring recycles
        detach = bool(getattr(self.loader, "reuse_buffers", False))

        def run():
            while not stop.is_set():
                try:
                    batch = next(loader)
                    state = batch.pop("_state", None)
                    t0 = time.perf_counter()
                    if self.global_arrays:
                        moved = self._globalize(batch, detach)
                    else:
                        moved = {
                            k: jax.device_put(
                                np.array(v, copy=True) if detach else v, dev
                            )
                            for k, v in batch.items()
                        }
                        # the transfer must COMPLETE before the next host
                        # batch may recycle the staging ring buffer under it
                        jax.block_until_ready(list(moved.values()))
                    nbytes = sum(int(v.nbytes) for v in batch.values())
                    with self._stats_lock:
                        self._h2d_s += time.perf_counter() - t0
                        self._h2d_bytes += nbytes
                        self._h2d_n += 1
                    if not self.global_arrays:
                        # on-device decode is part of the FEED pipeline:
                        # dispatch the fused dequant here so the consumer's
                        # critical path is nothing but q.get() + train step
                        self._dequant_on_device(moved)
                    item: Any = (moved, state)
                except Exception as e:  # surface in consumer (sticky there)
                    item = e
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.2)
                        break
                    except queue.Full:
                        continue
                if isinstance(item, Exception):
                    return

        self._thread = threading.Thread(target=run, daemon=True, name="ra-h2d")
        self._thread.start()

    # ---- global assembly (DESIGN.md §15) ------------------------------------
    def _quant_params_on(self, device) -> Dict[str, Tuple[Any, Any, np.dtype]]:
        """Per-field dequant parameters COMMITTED to ``device`` — committed
        operands keep the fused kernel's dispatch on each shard's own device
        in the global-assembly path."""
        cache = getattr(self, "_quant_dev_on", None)
        if cache is None:
            cache = self._quant_dev_on = {}
        per = cache.get(device)
        if per is None:
            per = cache[device] = {}
            for f, info in getattr(self.loader.ds, "quant", {}).items():
                shape, _ = self.loader.ds.logical_spec(f)
                scale, bias = info.channel_params(int(shape[-1]))
                per[f] = (
                    self._jax.device_put(scale, device),
                    self._jax.device_put(bias, device),
                    np.dtype(info.orig_dtype),
                )
        return per

    def _globalize(self, batch: Dict[str, np.ndarray], detach: bool) -> Dict[str, Any]:
        """Local host batch → global ``jax.Array``s: split rows over this
        host's addressable devices, device_put each block (uint8 for
        quantized fields), dequant each block on ITS device, then declare
        the blocks as the addressable shards of the
        ``(host_count * local_B, ...)``-shaped global array. The assembly
        itself is metadata-only — no gather, no cross-host traffic."""
        jax = self._jax
        if self._gsharding is None:
            from ..distributed import data_mesh

            self._gsharding = data_mesh.data_sharding()
            self._gdevices = jax.local_devices()
        devs = self._gdevices
        nd = len(devs)
        host_count = self.loader.mesh.host_count
        out: Dict[str, Any] = {}
        for k, v in batch.items():
            n = int(v.shape[0])
            if n % nd:
                raise RawArrayError(
                    f"{k}: local batch of {n} rows does not split over "
                    f"{nd} local devices"
                )
            per = n // nd
            shards = [
                jax.device_put(
                    np.array(v[i * per : (i + 1) * per], copy=True)
                    if detach
                    else v[i * per : (i + 1) * per],
                    d,
                )
                for i, d in enumerate(devs)
            ]
            # transfers must COMPLETE before the staging ring may recycle
            jax.block_until_ready(shards)
            if k in getattr(self.loader.ds, "quant", {}):
                from ..kernels import ops  # deferred: pallas import is heavy

                shards = [
                    ops.dequant_rows(
                        s, *self._quant_params_on(d)[k][:2],
                        out_dtype=self._quant_params_on(d)[k][2],
                        block_rows=self._block_rows, interpret=self._interpret,
                    )
                    for s, d in zip(shards, devs)
                ]
            gshape = (n * host_count,) + tuple(shards[0].shape[1:])
            out[k] = jax.make_array_from_single_device_arrays(
                gshape, self._gsharding, shards
            )
        return out

    # ---- iteration ----------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def _dequant_on_device(self, moved: Dict[str, Any]) -> None:
        """Decode quantized fields in place with the fused Pallas kernel
        (uint8 in HBM → float out; DESIGN.md §12). Runs on the feeder
        thread — dispatch and execution overlap the consumer's train step;
        jax compiled-function execution is thread-safe."""
        quant = self._quant_params()
        if not quant:
            return
        from ..kernels import ops  # deferred: pallas import is heavy

        for f, (scale, bias, out_dtype) in quant.items():
            if f in moved:
                moved[f] = ops.dequant_rows(
                    moved[f], scale, bias, out_dtype=out_dtype,
                    block_rows=self._block_rows, interpret=self._interpret,
                )

    def __next__(self) -> Dict[str, Any]:
        if self._exc is not None:
            raise self._exc  # sticky, same contract as DataLoader.__next__
        if self._q is None:
            self._start()
        t0 = time.perf_counter()
        item = self._q.get()
        with self._stats_lock:
            self._wait_s += time.perf_counter() - t0
        if isinstance(item, Exception):
            self._exc = item
            raise item
        moved, state = item
        moved["_state"] = state
        with self._stats_lock:
            self._n_batches += 1
        return moved

    # ---- lifecycle ----------------------------------------------------------
    def stop(self) -> None:
        """Stop the feeder and VERIFY it exited, then stop the wrapped
        loader. A feeder wedged past the join timeout (blocked inside the
        wrapped loader) keeps only its captured references: the wrapped
        loader is REPLACED with an equivalent fresh one, so the zombie can
        never steal a batch from — or stick a stale error onto — a
        restarted pipeline."""
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            if self._thread.is_alive():
                self.loader = self._detached_clone(self.loader)
        self._q = None
        self._thread = None
        self._exc = None
        self.loader.stop()

    @staticmethod
    def _detached_clone(old: DataLoader) -> DataLoader:
        """A fresh DataLoader equivalent to ``old`` (same dataset, order,
        position) sharing none of its queues, events, or buffers; ``old``
        stays with the zombie feeder that still references it."""
        old_q = old._q
        old.stop()  # best-effort: signals old's own producer too
        if old_q is not None:
            # wake a feeder blocked in old.__next__'s q.get(): the sentinel
            # error makes next() raise, and the feeder's (set) stop event
            # then ends the thread instead of leaking it on an orphaned get
            try:
                old_q.put_nowait(
                    RawArrayError("loader detached from a wedged device feeder")
                )
            except queue.Full:
                pass
        new = DataLoader(
            old.ds, old.batch_size, seed=old.seed, shuffle=old.shuffle,
            host_id=old.host_id, host_count=old.host_count,
            prefetch=old.prefetch, reuse_buffers=old.reuse_buffers,
            naive=old.naive, dequant=old.dequant, mesh=old.mesh,
        )
        new.state = LoaderState(old.state.epoch, old.state.step)
        return new

    def restore(self, state: LoaderState) -> None:
        """Resume exactly after the batch ``state`` describes (drains the
        device pipeline, then delegates to the wrapped loader)."""
        self.stop()
        self.loader.restore(state)

    def steps_per_epoch(self) -> int:
        return self.loader.steps_per_epoch()

    @property
    def ds(self):
        return self.loader.ds

    @property
    def state(self) -> LoaderState:
        return self.loader.state

    def stats(self) -> Dict[str, float]:
        """Wrapped loader counters plus the device feed's: ``h2d_s`` (time
        inside host→device transfers), ``h2d_bytes`` (bytes moved — 4×
        smaller for quantized fields), ``device_wait_s`` (consumer starved
        on the device queue: the straggler signal), ``device_batches``."""
        out = dict(self.loader.stats())
        with self._stats_lock:
            out.update(
                h2d_s=self._h2d_s,
                h2d_bytes=float(self._h2d_bytes),
                h2d_batches=float(self._h2d_n),  # feeder runs ahead of consumer
                device_wait_s=self._wait_s,
                device_batches=float(self._n_batches),
            )
        return out
