"""RawArray-backed data pipeline (the paper's contribution as the loader)."""

from .dataset import DatasetBuilder, RaDataset, RaDatasetWriter, dataset_manifest
from .loader import DataLoader, LoaderState
from .synth import make_image_dataset, make_token_dataset

__all__ = [
    "DatasetBuilder",
    "RaDataset",
    "RaDatasetWriter",
    "dataset_manifest",
    "DataLoader",
    "DeviceLoader",
    "LoaderState",
    "make_token_dataset",
    "make_image_dataset",
]


def __getattr__(name):
    # DeviceLoader pulls in jax; load it lazily so the numpy-only data plane
    # (datasets, host loader) stays importable and fast without it
    if name == "DeviceLoader":
        from .device_loader import DeviceLoader

        return DeviceLoader
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
