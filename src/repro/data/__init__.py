"""RawArray-backed data pipeline (the paper's contribution as the loader)."""

from .dataset import DatasetBuilder, RaDataset, RaDatasetWriter, dataset_manifest
from .loader import DataLoader, LoaderState
from .synth import make_image_dataset, make_token_dataset

__all__ = [
    "DatasetBuilder",
    "RaDataset",
    "RaDatasetWriter",
    "dataset_manifest",
    "DataLoader",
    "LoaderState",
    "make_token_dataset",
    "make_image_dataset",
]
