"""RawArray shard-directory datasets.

Layout — exactly the paper's archival vision (§1: "metadata as human-
readable markup, raw data in RawArray files, organized by a file system
directory structure")::

    <root>/
      manifest.json             {"fields": {"tokens": {"dtype": "uint32",
                                 "shape": [1024]}, ...},
                                 "shards": [{"files": {"tokens":
                                 "tokens_00000.ra"}, "rows": 8192}, ...]}
      tokens_00000.ra           (rows, *field_shape) RawArray
      tokens_00001.ra           ...

Every shard file is an independent, memory-mappable RawArray; a reader
needs only offset arithmetic to fetch any row range of any field — this is
what makes multi-host sharded reads and exact-resume trivial.

``root`` may also be an ``http(s)://`` URL of a served dataset directory
(DESIGN.md §9): the manifest is fetched over HTTP, every positioned read
becomes a pooled byte-range request through ``repro.remote``, and the
block cache turns repeated epoch traversals into RAM hits. The engine's
``rows``/``gather`` wave plans are identical in both modes; only the
sparse-leftover path differs (ranged reads instead of mmap fancy
indexing, since there is nothing to map).

Datasets are built by streaming (DESIGN.md §11): ``DatasetBuilder`` feeds
samples or row batches through per-field incremental writers in bounded
memory and publishes the manifest atomically at ``finish``.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .. import core as ra
from ..core import codec as chunked_codec
from ..core import engine

MANIFEST = "manifest.json"

_join = ra.join_path


def dataset_manifest(root: str) -> Dict[str, Any]:
    if ra.is_url(root):
        from .. import remote

        return json.loads(remote.fetch_bytes(_join(root, MANIFEST)))
    with open(os.path.join(root, MANIFEST)) as f:
        return json.load(f)


class DatasetBuilder:
    """Streaming dataset ingest (DESIGN.md §11): feed samples or row
    batches; every field streams through an incremental ``RaWriter`` into
    the current shard file, shards roll at ``shard_rows``, and the manifest
    is written LAST (temp + atomic rename) — so peak memory is one write
    buffer per field (not a shard), a crash mid-ingest leaves only whole
    shard files plus invisible temps, and the directory is not a dataset
    until ``finish`` succeeds.

    This is the MNIST/CIFAR-style converter entry point the paper sketches
    (``repro.formats`` converters call it; see ``examples/streaming_ingest.py``).
    ``chunked=True`` (or ``codec=``/``chunk_bytes=``) writes every shard
    file chunk-compressed (DESIGN.md §10) — compression runs chunk-parallel
    WHILE samples arrive; readers then decode only the chunks overlapping
    each row request. Output is byte-identical to the pre-streaming writer
    (one monolithic ``ra.write`` per shard) for the same sample stream.

    ``quantize={"field": spec}`` (DESIGN.md §12) stores a float field as
    uint8 codes — 4× fewer disk/wire bytes — with the ``(scale, bias,
    orig_dtype)`` schema in each shard file's RawArray metadata AND the
    manifest, so readers dequantize on host (``DataLoader``) or on device
    (``DeviceLoader`` via the fused Pallas kernel). ``spec`` is ``"u8"``
    (calibration range [0, 1]), ``("u8", lo, hi)``, or a ``QuantInfo``;
    streaming ingest needs the range declared up front, so out-of-range
    samples saturate rather than rescaling.
    """

    def __init__(
        self,
        root: str,
        fields: Dict[str, Tuple[Tuple[int, ...], str]],
        shard_rows: int = 8192,
        *,
        crc32: bool = False,
        chunked: bool = False,
        codec: Optional[str] = None,
        chunk_bytes: Optional[int] = None,
        quantize: Optional[Dict[str, Any]] = None,
        stats: Optional[bool] = None,
    ):
        self.root = root
        self.fields = fields  # name -> (row_shape, dtype)
        self.shard_rows = shard_rows
        self.chunked = chunked or codec is not None or chunk_bytes is not None
        self.codec = codec
        self.chunk_bytes = chunk_bytes
        self.crc32 = crc32
        self.stats = stats  # None = auto: on for numeric stored dtypes (§16)
        self.quant: Dict[str, ra.QuantInfo] = {}
        for name, spec in (quantize or {}).items():
            if name not in fields:
                raise ra.RawArrayError(f"quantize names unknown field {name!r}")
            shape, dtype = fields[name]
            if not np.issubdtype(np.dtype(dtype), np.floating):
                raise ra.RawArrayError(
                    f"quantize: field {name!r} is {dtype}, only float fields "
                    f"can be stored quantized"
                )
            if len(shape) < 1:
                raise ra.RawArrayError(
                    f"quantize: field {name!r} has a scalar row shape; the "
                    f"dequant kernel needs a channel (last) axis"
                )
            self.quant[name] = ra.resolve_quant_spec(spec, dtype=dtype)
        self._writers: Optional[Dict[str, ra.io.RaWriter]] = None
        self._shard_fill = 0  # rows in the open shard
        self._shards: List[Dict[str, Any]] = []
        self._state = "open"
        os.makedirs(root, exist_ok=True)

    @property
    def rows(self) -> int:
        """Total rows ingested so far."""
        return sum(s["rows"] for s in self._shards) + self._shard_fill

    def _open_shard(self) -> Dict[str, ra.io.RaWriter]:
        if self._writers is None:
            idx = len(self._shards)
            self._writers = {
                # quantized fields store uint8 shard files carrying their
                # dequant schema as RawArray metadata (self-describing even
                # without the manifest)
                name: ra.io.RaWriter(
                    os.path.join(self.root, f"{name}_{idx:05d}.ra"),
                    np.uint8 if name in self.quant else np.dtype(dtype),
                    tuple(shape),
                    crc32=self.crc32, chunked=self.chunked,
                    codec=self.codec, chunk_bytes=self.chunk_bytes,
                    metadata=(self.quant[name].encode()
                              if name in self.quant else None),
                    # per-chunk stats default on for numeric stored dtypes
                    # (uint8 codes for quantized fields), DESIGN.md §16
                    stats=(ra.stats_supported(
                        np.uint8 if name in self.quant else np.dtype(dtype))
                        if self.stats is None else self.stats),
                )
                for name, (shape, dtype) in self.fields.items()
            }
            self._shard_fill = 0
        return self._writers

    def _roll(self) -> None:
        idx = len(self._shards)
        files = {}
        for name, w in self._writers.items():
            w.finalize()
            files[name] = f"{name}_{idx:05d}.ra"
        self._shards.append({"files": files, "rows": self._shard_fill})
        self._writers = None
        self._shard_fill = 0

    def append(self, **arrays: np.ndarray) -> None:
        """Append one row batch: every field, same leading dimension. The
        batch is split across shard boundaries as needed."""
        if self._state != "open":
            raise ra.RawArrayError(f"append on a {self._state} DatasetBuilder")
        batch: Dict[str, np.ndarray] = {}
        n = None
        for name, (shape, dtype) in self.fields.items():
            a = np.asarray(arrays[name])
            assert a.shape[1:] == tuple(shape), f"{name}: {a.shape} vs {shape}"
            n = a.shape[0] if n is None else n
            assert a.shape[0] == n
            if name in self.quant:
                a = self.quant[name].quantize(a)
            batch[name] = a
        pos = 0
        while pos < n:
            writers = self._open_shard()
            take = min(n - pos, self.shard_rows - self._shard_fill)
            for name, a in batch.items():
                writers[name].write_rows(a[pos : pos + take])
            self._shard_fill += take
            pos += take
            if self._shard_fill >= self.shard_rows:
                self._roll()

    def add(self, **sample: np.ndarray) -> None:
        """Append ONE sample (each field without the leading batch dim) —
        the live-capture convenience over ``append``."""
        self.append(**{k: np.asarray(v)[None] for k, v in sample.items()})

    def finish(self, metadata: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Seal the open shard and atomically publish ``manifest.json``;
        returns the manifest. Calling it twice — or after ``abort`` — raises."""
        if self._state != "open":
            raise ra.RawArrayError(f"finish on a {self._state} DatasetBuilder")
        if self._writers is not None and self._shard_fill:
            self._roll()
        elif self._writers is not None:  # opened but empty: drop, don't publish
            for w in self._writers.values():
                w.abort()
            self._writers = None
        man = {
            "format": "rawarray-dataset-v1",
            # "dtype" stays the LOGICAL dtype; a "quant" sub-object marks the
            # shard files as uint8 codes plus the dequant schema (§12)
            "fields": {
                k: {
                    "shape": list(s),
                    "dtype": str(np.dtype(d)),
                    **({"quant": self.quant[k].to_dict()} if k in self.quant else {}),
                }
                for k, (s, d) in self.fields.items()
            },
            "shards": self._shards,
            "total_rows": int(sum(s["rows"] for s in self._shards)),
            "metadata": metadata or {},
        }
        tmp = os.path.join(self.root, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(man, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.root, MANIFEST))
        self._state = "finished"
        return man

    def abort(self) -> None:
        """Drop the open shard's temp files; no manifest is written."""
        if self._state == "open":
            self._state = "aborted"
            if self._writers is not None:
                for w in self._writers.values():
                    w.abort()
                self._writers = None

    def __enter__(self) -> "DatasetBuilder":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        elif self._state == "open":
            self.finish()


# Pre-streaming name, kept for compatibility: the old RaDatasetWriter
# buffered a whole shard in RAM and wrote it monolithically; DatasetBuilder
# produces byte-identical output incrementally.
RaDatasetWriter = DatasetBuilder


@dataclass
class _Shard:
    rows: int
    files: Dict[str, str]
    row_offset: int


class RaDataset:
    """Random-access reader over a shard directory.

    Contiguous reads (``rows``) go through the parallel I/O engine in one
    wave of positioned preads straight into the output batch buffer; random
    gathers (``gather``) are planned by ``engine.coalesce`` — dense index
    runs become ranged reads, sparse leftovers fall back to fancy indexing
    on the cached per-shard mmaps (DESIGN.md §8). Both accept ``out=`` so a
    loader can stream into reused, pre-faulted batch arrays.
    """

    def __init__(self, root: str):
        self.root = root
        self.is_remote = ra.is_url(root)
        man = dataset_manifest(root)
        if man.get("format") != "rawarray-dataset-v1":
            raise ra.RawArrayError(f"not a RawArray dataset: {root}")
        self.fields: Dict[str, Any] = man["fields"]
        self.metadata = man.get("metadata", {})
        # typed quant schemas (DESIGN.md §12): shard files of these fields
        # hold uint8 codes; consumers dequantize on host or on device
        self.quant: Dict[str, ra.QuantInfo] = {
            f: ra.QuantInfo.from_dict(info["quant"])
            for f, info in self.fields.items()
            if info.get("quant")
        }
        self.shards: List[_Shard] = []
        off = 0
        for s in man["shards"]:
            self.shards.append(_Shard(rows=s["rows"], files=s["files"], row_offset=off))
            off += s["rows"]
        self.total_rows = off
        self._bounds = np.array([s.row_offset for s in self.shards] + [off])
        self._mmaps: Dict[Tuple[int, str], np.ndarray] = {}
        # (shard, field) -> (src, data_offset, row_nbytes, header, chunk
        # table or None) for positioned reads; src is an int fd locally, a
        # pooled RemoteReader for URLs
        self._fds: Dict[Tuple[int, str], Tuple[Any, int, int, Any, Any]] = {}
        # (shard, field) -> ChunkStats | None, decoded once from the tail
        # of each shard file (header/table/tail reads only — never payload)
        self._stats: Dict[Tuple[int, str], Any] = {}
        # shard -> access count, bumped on EVERY fd/mmap lookup: the witness
        # that a mesh host never touches a shard it doesn't own (§15)
        self._shard_touch: Dict[int, int] = {}

    def __len__(self) -> int:
        return self.total_rows

    # ---- shard-touch accounting (DESIGN.md §15) ---------------------------
    def shard_touches(self) -> Dict[int, int]:
        """Per-shard access counts (every fd/mmap lookup, local or remote):
        the observable a mesh test asserts to prove this host fetched bytes
        only from shards it owns."""
        return dict(self._shard_touch)

    def shards_touched(self) -> List[int]:
        return sorted(self._shard_touch)

    def reset_shard_touches(self) -> None:
        self._shard_touch.clear()

    def close(self) -> None:
        for fd, *_ in self._fds.values():
            if not isinstance(fd, int):
                continue  # remote readers live in the shared registry
            try:
                os.close(fd)
            except OSError:
                pass
        self._fds.clear()
        self._mmaps.clear()

    def __del__(self):  # best-effort fd cleanup
        try:
            self.close()
        except Exception:
            pass

    def _mmap(self, shard_idx: int, field: str) -> np.ndarray:
        if self.is_remote:
            raise ra.RawArrayError(
                "memory-mapping is unavailable for a remote dataset "
                "(gather serves every row via ranged reads instead)"
            )
        key = (shard_idx, field)
        self._shard_touch[shard_idx] = self._shard_touch.get(shard_idx, 0) + 1
        if key not in self._mmaps:
            path = os.path.join(self.root, self.shards[shard_idx].files[field])
            self._mmaps[key] = ra.memmap(path)
        return self._mmaps[key]

    def _fmeta(self, shard_idx: int, field: str) -> Tuple[Any, int, int, Any, Any]:
        """(src, payload offset, row bytes, header, chunk table | None) for
        one shard file, cached. ``src`` is whatever ``engine.pread_into``
        accepts: an int fd for a local file, a pooled ``RemoteReader`` for a
        URL. A chunked shard carries its decoded chunk table so row spans
        map to chunk runs without re-reading the trailer."""
        key = (shard_idx, field)
        self._shard_touch[shard_idx] = self._shard_touch.get(shard_idx, 0) + 1
        if key not in self._fds:
            path = _join(self.root, self.shards[shard_idx].files[field])
            hdr = ra.header_of(path)
            if hdr.compressed and not (hdr.flags & ra.FLAG_CHUNKED):
                raise ra.RawArrayError(
                    f"{path}: whole-file zlib shards are not range-addressable; "
                    f"rewrite the dataset with chunked compression "
                    f"(RaDatasetWriter(chunked=True) or `racat compress`)"
                )
            if hdr.big_endian:
                raise ra.RawArrayError(
                    f"{path}: big-endian shards are not supported in datasets"
                )
            row_nbytes = hdr.elbyte
            for d in hdr.shape[1:]:
                row_nbytes *= d
            if self.is_remote:
                from .. import remote

                src: Any = remote.get_reader(path)
            else:
                src = os.open(path, os.O_RDONLY)
            table = (
                chunked_codec.read_table(src, hdr)
                if hdr.flags & ra.FLAG_CHUNKED
                else None
            )
            self._fds[key] = (src, hdr.nbytes, row_nbytes, hdr, table)
        return self._fds[key]

    def _raw_reader(self, shard_idx: int, field: str):
        """``read_raw(raw_off, view)`` closure over one plain shard file:
        one positioned read at the payload offset (chunked fields never
        come through here — gather plans them per chunk)."""
        src, doff, *_ = self._fmeta(shard_idx, field)
        return lambda off, view: engine.pread_into(src, doff + off, view)

    def _resolve_fmeta(self, shard_idx_list, fields) -> None:
        """Resolve the (shard, field) sources a read will touch in one
        concurrent wave. Remotely each resolution costs 1-2 HTTP round
        trips (header + HEAD); a serial first-batch loop over S x F shard
        files would pay them back-to-back (same pre-resolve pattern as
        checkpoint restore and sharded.read_slice)."""
        pending = [
            (si, f)
            for si in shard_idx_list
            for f in fields
            if (si, f) not in self._fds
        ]
        if len(pending) > 1:
            engine.run_tasks([(lambda s=si, g=f: self._fmeta(s, g)) for si, f in pending])

    def io_stats(self) -> Dict[str, int]:
        """I/O observability counters: block-cache hit/miss/eviction (plus a
        combined ``hit_ratio`` recomputed from the summed counters) over
        this dataset's remote readers (empty for a local dataset), plus the
        codec's chunk decode counters (``chunk_reads`` /
        ``chunk_stored_bytes`` / ``chunk_raw_bytes``) when any chunked
        decoding has happened — the observable that proves partial reads of
        compressed shards touch only overlapping chunks. NB: readers
        default to the process-wide ``remote.shared_cache()`` and the chunk
        counters are process-wide too, so with other traffic in the same
        process these counters are process-global, not per-dataset; pass
        each reader its own ``BlockCache`` (and ``codec.reset_stats()``)
        for isolated accounting."""
        out: Dict[str, int] = {}
        if self.is_remote:
            caches = []
            for src, *_ in self._fds.values():
                cache = getattr(src, "cache", None)
                if cache is not None and all(c is not cache for c in caches):
                    caches.append(cache)
            for c in caches:
                for k, v in c.stats().items():
                    if k == "hit_ratio":
                        continue  # a ratio does not sum; recomputed below
                    out[k] = out.get(k, 0) + v
            total = out.get("hits", 0) + out.get("misses", 0)
            if total:
                out["hit_ratio"] = out["hits"] / total
        cstats = chunked_codec.stats()
        if any(cstats.values()):
            out.update(cstats)
        return out

    def _field_spec(self, field: str) -> Tuple[Tuple[int, ...], np.dtype]:
        return self.stored_spec(field)

    def stored_spec(self, field: str) -> Tuple[Tuple[int, ...], np.dtype]:
        """``(row_shape, dtype)`` of the bytes actually ON DISK for one
        field — uint8 for quantized fields (DESIGN.md §12), the declared
        dtype otherwise. All read planning (and loader staging buffers)
        works in stored terms; dequantization happens at the consumer."""
        info = self.fields[field]
        dtype = np.dtype(np.uint8) if field in self.quant else np.dtype(info["dtype"])
        return tuple(info["shape"]), dtype

    def logical_spec(self, field: str) -> Tuple[Tuple[int, ...], np.dtype]:
        """``(row_shape, dtype)`` a consumer sees AFTER dequantization —
        the manifest's declared dtype."""
        info = self.fields[field]
        return tuple(info["shape"]), np.dtype(info["dtype"])

    def _dest(
        self,
        out: Optional[Dict[str, np.ndarray]],
        field: str,
        n: int,
    ) -> np.ndarray:
        rshape, dtype = self._field_spec(field)
        want = (n,) + rshape
        if out is not None and field in out:
            dst = out[field]
            if tuple(dst.shape) != want or dst.dtype != dtype or not dst.flags.c_contiguous:
                raise ra.RawArrayError(
                    f"{field}: out must be C-contiguous {want} {dtype}, "
                    f"got {dst.shape} {dst.dtype}"
                )
            return dst
        return np.empty(want, dtype)

    def rows(
        self,
        start: int,
        stop: int,
        fields: Optional[Sequence[str]] = None,
        *,
        out: Optional[Dict[str, np.ndarray]] = None,
    ) -> Dict[str, np.ndarray]:
        """Read global rows [start, stop) across shard boundaries — one
        engine wave of positioned reads into a single buffer per field."""
        fields = list(fields or self.fields)
        start, stop = max(0, start), min(stop, self.total_rows)
        n = max(0, stop - start)
        result = {f: self._dest(out, f, n) for f in fields}
        if n == 0:
            return result
        touched = [
            i
            for i, sh in enumerate(self.shards)
            if sh.row_offset < stop and sh.row_offset + sh.rows > start
        ]
        self._resolve_fmeta(touched, fields)
        jobs = []
        tasks = []  # per-chunk decode tasks for chunked shards
        for i in touched:
            sh = self.shards[i]
            lo, hi = sh.row_offset, sh.row_offset + sh.rows
            a, b = max(start, lo) - lo, min(stop, hi) - lo
            for f in fields:
                fd, doff, rnb, hdr, table = self._fmeta(i, f)
                if rnb == 0:
                    continue
                dst = result[f]
                mv = memoryview(dst.reshape(-1).view(np.uint8)).cast("B")
                o = lo + a - start
                dview = mv[o * rnb : (o + b - a) * rnb]
                if table is None:
                    jobs.append((fd, doff + a * rnb, dview))
                else:
                    tasks += chunked_codec.chunk_read_tasks(
                        fd, hdr, table, a * rnb, b * rnb, dview
                    )
        if tasks:  # one wave: slab preads + chunk decodes share the pool
            engine.run_tasks(engine.span_read_tasks(jobs) + tasks)
        else:
            engine.parallel_read_spans(jobs)
        return result

    def gather(
        self,
        indices: np.ndarray,
        fields: Optional[Sequence[str]] = None,
        *,
        out: Optional[Dict[str, np.ndarray]] = None,
    ) -> Dict[str, np.ndarray]:
        """Gather arbitrary global rows (shuffled access).

        Per shard, ``engine.coalesce`` merges near-adjacent requests into
        ranged positioned reads (served from reusable scratch buffers, or
        read directly into the output when the destination rows line up);
        requests too sparse to coalesce fall back to fancy indexing on the
        cached mmap — the planner never reads more than ``gap+1`` times the
        requested bytes."""
        fields = list(fields or self.fields)
        indices = np.asarray(indices, dtype=np.int64)
        n = len(indices)
        result = {f: self._dest(out, f, n) for f in fields}
        if n == 0:
            return result
        # one global sort; shard membership is then a searchsorted over the
        # sorted values (no per-shard masks), and per-shard slices arrive
        # pre-sorted for the planner and for page-local fancy indexing
        order = np.argsort(indices, kind="stable")
        sidx = indices[order]
        cuts = np.searchsorted(sidx, self._bounds)
        touched = [
            si for si in range(len(self.shards)) if cuts[si] != cuts[si + 1]
        ]
        # sources must be resolved BEFORE planning: a chunked field is
        # planned per CHUNK (each needed chunk decoded exactly once, rows
        # scattered out of it), a plain field per coalesced row run —
        # chunked-ness is a per-field property, so a shard mixing chunked
        # and plain field files gets both plan kinds
        self._resolve_fmeta(touched, fields)
        plans = []  # (si, local rows, destination slots, plain (runs, leftover))
        for si in touched:
            a, b = cuts[si], cuts[si + 1]
            local = sidx[a:b] - self.shards[si].row_offset
            plain_plan = None
            if any(self._fmeta(si, f)[4] is None for f in fields):
                # remote: no mmap to service sparse leftovers, so every
                # request becomes a ranged read (min_run=1); singleton runs
                # are absorbed by the block cache
                min_run = 1 if self.is_remote else None
                plain_plan = engine.coalesce_sorted(local, np.arange(a, b),
                                                    min_run=min_run)
            plans.append((si, local, order[a:b], plain_plan))
        tasks = []
        fancy = []  # deferred sparse leftovers: (si, field, positions, local)
        for f in fields:
            rshape, dtype = self._field_spec(f)
            sample = result[f]
            for si, local, pos, plain_plan in plans:
                src, doff, rnb, hdr, table = self._fmeta(si, f)
                if rnb == 0:
                    continue
                if table is not None:
                    mv = memoryview(sample.reshape(-1).view(np.uint8)).cast("B")
                    tasks += chunked_codec.gather_rows_tasks(
                        src, hdr, table, rnb, local, pos, mv
                    )
                    continue
                runs, leftover = plain_plan
                if runs:
                    read_raw = self._raw_reader(si, f)
                    for run in runs:
                        tasks.append(
                            self._run_task(run, sidx, order, sample, rshape, dtype,
                                           read_raw, rnb, self.shards[si].row_offset)
                        )
                if leftover.size:
                    fancy.append((si, f, order[leftover], sidx[leftover]
                                  - self.shards[si].row_offset))
        engine.run_tasks(tasks)
        for si, f, pos, loc in fancy:
            result[f][pos] = self._mmap(si, f)[loc]
        return result

    @staticmethod
    def _run_task(run, sidx, order, sample, rshape, dtype, read_raw, rnb, row_off):
        """Closure for one coalesced ranged read (executed on the pool).
        ``run.sel`` points into the dataset-wide sorted arrays; ``read_raw``
        serves a logical payload byte range (positioned pread on a plain
        shard, chunk decode on a chunked one)."""

        def task():
            lo, hi, sel = run
            span = hi - lo
            want = span * rnb
            pos_sel = order[sel]
            loc_sel = sidx[sel] - row_off
            p0 = int(pos_sel[0])
            direct = (
                span == len(sel)
                and np.array_equal(loc_sel, np.arange(lo, hi))
                and np.array_equal(pos_sel, np.arange(p0, p0 + span))
            )
            if direct:
                # destination rows are contiguous and in order: zero-copy read
                mv = memoryview(sample.reshape(-1).view(np.uint8)).cast("B")
                read_raw(lo * rnb, mv[p0 * rnb : p0 * rnb + want])
                return
            scratch = engine.acquire_scratch(want)
            try:
                read_raw(lo * rnb, memoryview(scratch)[:want])
                rows_arr = scratch[:want].view(dtype).reshape((span,) + rshape)
                sample[pos_sel] = rows_arr[loc_sel - lo]
            finally:
                engine.release_scratch(scratch)

        return task

    def gather_naive(
        self, indices: np.ndarray, fields: Optional[Sequence[str]] = None
    ) -> Dict[str, np.ndarray]:
        """Reference per-row fancy-indexing gather (the pre-engine path).
        Kept for equivalence tests and as the benchmark baseline.
        Local-only: it indexes shard mmaps."""
        fields = list(fields or self.fields)
        indices = np.asarray(indices)
        bounds = np.array([s.row_offset for s in self.shards] + [self.total_rows])
        shard_of = np.searchsorted(bounds, indices, side="right") - 1
        out: Dict[str, np.ndarray] = {}
        for f in fields:
            rshape, dtype = self.stored_spec(f)
            sample = np.empty((len(indices),) + rshape, dtype=dtype)
            for si in np.unique(shard_of):
                mask = shard_of == si
                local = indices[mask] - self.shards[si].row_offset
                sample[mask] = self._mmap(int(si), f)[local]
            out[f] = sample
        return out

    # ---- predicate pushdown (DESIGN.md §16) -------------------------------
    def field_stats(self, shard_idx: int, field: str):
        """Per-chunk ``rastats`` statistics of one shard file, decoded once
        and cached. Costs the header + chunk table + two small tail reads
        (a few hundred bytes over HTTP) — the payload is never touched.
        ``None`` for shards written without (or with a damaged) stats
        block; those shards are then fully scanned."""
        key = (shard_idx, field)
        if key not in self._stats:
            src, _doff, _rnb, hdr, table = self._fmeta(shard_idx, field)
            size = chunked_codec._src_size(src)
            self._stats[key] = ra.io._read_stats_src(
                src, hdr, size=size,
                table_nbytes=table.nbytes if table is not None else 0,
            )
        return self._stats[key]

    def _row_verdicts(self, where) -> Tuple[np.ndarray, np.ndarray]:
        """Global per-row ``(definitely_true, definitely_false)`` for a
        predicate, from the per-shard stats blocks."""
        pfields = sorted(where.fields())
        for f in pfields:
            if f not in self.fields:
                raise ra.RawArrayError(f"predicate names unknown field {f!r}")
        dt = np.zeros(self.total_rows, dtype=bool)
        df = np.zeros(self.total_rows, dtype=bool)
        self._resolve_fmeta(range(len(self.shards)), pfields)
        for si, sh in enumerate(self.shards):
            info = {}
            for f in pfields:
                rshape, dtype = self.stored_spec(f)
                rnb = dtype.itemsize
                for d in rshape:
                    rnb *= d
                info[f] = (self.field_stats(si, f), rnb)
            d, e = where.row_verdicts(sh.rows, info)
            dt[sh.row_offset:sh.row_offset + sh.rows] = d
            df[sh.row_offset:sh.row_offset + sh.rows] = e
        return dt, df

    def select(
        self,
        where=None,
        fields: Optional[Sequence[str]] = None,
    ) -> Dict[str, np.ndarray]:
        """Read every row matching ``where`` (DESIGN.md §16).

        The predicate (built with ``repro.core.col``) is pushed down to
        the per-chunk statistics: chunks whose ``[min, max]`` intervals
        prove no row can match are pruned without fetching a single
        payload byte, chunks proved all-matching are taken wholesale, and
        only the undecided rows are decoded AND masked — each touched
        chunk is decoded exactly once, with the residual row filter
        applied in the same pass. Identical for local directories,
        ``http(s)://`` URLs and the fleet router. Rows of quantized
        fields are compared (and returned) as their STORED uint8 codes.
        Shards without usable stats degrade to a full scan — results are
        always byte-identical to filtering a full read."""
        fields = list(fields or self.fields)
        if where is None:
            return self.rows(0, self.total_rows, fields)
        pfields = sorted(where.fields())
        dt, df = self._row_verdicts(where)
        cand = np.nonzero(~df)[0]
        if cand.size == 0:
            return {f: self._dest(None, f, 0) for f in fields}
        need_scan = bool((~dt[cand]).any())
        gfields = list(dict.fromkeys(fields + (pfields if need_scan else [])))
        batch = self.gather(cand, gfields)
        if not need_scan:
            return {f: batch[f] for f in fields}
        keep = dt[cand] | where.mask({f: batch[f] for f in pfields})
        return {f: batch[f][keep] for f in fields}

    def select_indices(self, where) -> np.ndarray:
        """Global row indices matching ``where`` (sorted ascending) — the
        planning half of ``select``, used by ``DataLoader(where=...)``.
        Only predicate fields of undecided chunks are decoded."""
        dt, df = self._row_verdicts(where)
        cand = np.nonzero(~df)[0]
        scan = cand[~dt[cand]]
        if scan.size == 0:
            return cand
        pfields = sorted(where.fields())
        batch = self.gather(scan, pfields)
        keep = dt[cand].copy()
        keep[~dt[cand]] = where.mask(batch)
        return cand[keep]

    def host_range(self, host_id: int, host_count: int) -> Tuple[int, int]:
        """Contiguous row range owned by this host (multi-host sharding)."""
        per = self.total_rows // host_count
        start = host_id * per
        stop = start + per if host_id < host_count - 1 else self.total_rows
        return start, stop
