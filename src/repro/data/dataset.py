"""RawArray shard-directory datasets.

Layout — exactly the paper's archival vision (§1: "metadata as human-
readable markup, raw data in RawArray files, organized by a file system
directory structure")::

    <root>/
      manifest.json             {"fields": {"tokens": {"dtype": "uint32",
                                 "shape": [1024]}, ...},
                                 "shards": [{"files": {"tokens":
                                 "tokens_00000.ra"}, "rows": 8192}, ...]}
      tokens_00000.ra           (rows, *field_shape) RawArray
      tokens_00001.ra           ...

Every shard file is an independent, memory-mappable RawArray; a reader
needs only offset arithmetic to fetch any row range of any field — this is
what makes multi-host sharded reads and exact-resume trivial.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .. import core as ra

MANIFEST = "manifest.json"


def dataset_manifest(root: str) -> Dict[str, Any]:
    with open(os.path.join(root, MANIFEST)) as f:
        return json.load(f)


class RaDatasetWriter:
    """Streaming writer: append row batches, shards roll at ``shard_rows``."""

    def __init__(self, root: str, fields: Dict[str, Tuple[Tuple[int, ...], str]], shard_rows: int = 8192):
        self.root = root
        self.fields = fields  # name -> (row_shape, dtype)
        self.shard_rows = shard_rows
        self._buf: Dict[str, List[np.ndarray]] = {k: [] for k in fields}
        self._buffered = 0
        self._shards: List[Dict[str, Any]] = []
        os.makedirs(root, exist_ok=True)

    def append(self, **arrays: np.ndarray) -> None:
        n = None
        for name, (shape, dtype) in self.fields.items():
            a = np.asarray(arrays[name])
            assert a.shape[1:] == tuple(shape), f"{name}: {a.shape} vs {shape}"
            n = a.shape[0] if n is None else n
            assert a.shape[0] == n
            self._buf[name].append(a.astype(dtype, copy=False))
        self._buffered += n
        while self._buffered >= self.shard_rows:
            self._flush(self.shard_rows)

    def _flush(self, rows: int) -> None:
        if rows == 0:
            return
        idx = len(self._shards)
        files = {}
        for name in self.fields:
            buf = np.concatenate(self._buf[name], axis=0)
            take, rest = buf[:rows], buf[rows:]
            self._buf[name] = [rest] if rest.size else []
            fname = f"{name}_{idx:05d}.ra"
            ra.write(os.path.join(self.root, fname), take)
            files[name] = fname
        self._shards.append({"files": files, "rows": rows})
        self._buffered -= rows

    def finish(self, metadata: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        if self._buffered:
            self._flush(self._buffered)
        man = {
            "format": "rawarray-dataset-v1",
            "fields": {
                k: {"shape": list(s), "dtype": str(np.dtype(d))}
                for k, (s, d) in self.fields.items()
            },
            "shards": self._shards,
            "total_rows": int(sum(s["rows"] for s in self._shards)),
            "metadata": metadata or {},
        }
        with open(os.path.join(self.root, MANIFEST), "w") as f:
            json.dump(man, f, indent=1)
        return man


@dataclass
class _Shard:
    rows: int
    files: Dict[str, str]
    row_offset: int


class RaDataset:
    """Random-access reader over a shard directory. All reads are memory-
    mapped row-range slices (zero decode, zero copy until touched)."""

    def __init__(self, root: str):
        self.root = root
        man = dataset_manifest(root)
        if man.get("format") != "rawarray-dataset-v1":
            raise ra.RawArrayError(f"not a RawArray dataset: {root}")
        self.fields: Dict[str, Any] = man["fields"]
        self.metadata = man.get("metadata", {})
        self.shards: List[_Shard] = []
        off = 0
        for s in man["shards"]:
            self.shards.append(_Shard(rows=s["rows"], files=s["files"], row_offset=off))
            off += s["rows"]
        self.total_rows = off
        self._mmaps: Dict[Tuple[int, str], np.ndarray] = {}

    def __len__(self) -> int:
        return self.total_rows

    def _mmap(self, shard_idx: int, field: str) -> np.ndarray:
        key = (shard_idx, field)
        if key not in self._mmaps:
            path = os.path.join(self.root, self.shards[shard_idx].files[field])
            self._mmaps[key] = ra.memmap(path)
        return self._mmaps[key]

    def rows(self, start: int, stop: int, fields: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
        """Read global rows [start, stop) across shard boundaries."""
        fields = list(fields or self.fields)
        out: Dict[str, List[np.ndarray]] = {f: [] for f in fields}
        for i, sh in enumerate(self.shards):
            lo, hi = sh.row_offset, sh.row_offset + sh.rows
            if hi <= start or lo >= stop:
                continue
            a, b = max(start, lo) - lo, min(stop, hi) - lo
            for f in fields:
                out[f].append(np.asarray(self._mmap(i, f)[a:b]))
        return {
            f: (v[0] if len(v) == 1 else np.concatenate(v, axis=0)) for f, v in out.items()
        }

    def gather(self, indices: np.ndarray, fields: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
        """Gather arbitrary global rows (shuffled access)."""
        fields = list(fields or self.fields)
        indices = np.asarray(indices)
        bounds = np.array([s.row_offset for s in self.shards] + [self.total_rows])
        shard_of = np.searchsorted(bounds, indices, side="right") - 1
        out: Dict[str, np.ndarray] = {}
        for f in fields:
            field_info = self.fields[f]
            sample = np.empty(
                (len(indices),) + tuple(field_info["shape"]), dtype=field_info["dtype"]
            )
            for si in np.unique(shard_of):
                mask = shard_of == si
                local = indices[mask] - self.shards[si].row_offset
                sample[mask] = self._mmap(int(si), f)[local]
            out[f] = sample
        return out

    def host_range(self, host_id: int, host_count: int) -> Tuple[int, int]:
        """Contiguous row range owned by this host (multi-host sharding)."""
        per = self.total_rows // host_count
        start = host_id * per
        stop = start + per if host_id < host_count - 1 else self.total_rows
        return start, stop
