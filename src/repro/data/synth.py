"""Synthetic dataset builders (offline container: no real MNIST/CIFAR/corpus;
dataset layout DESIGN.md §4, streamed through the §11 ingest plane).

* ``make_token_dataset`` — Zipfian token documents packed to fixed length,
  written as a RaDataset (uint32 tokens). Used by the e2e LM example.
* ``make_image_dataset`` — MNIST-like (28x28x1) or CIFAR-like (36x36x3)
  uint8 images with enough spatial structure that PNG compresses
  realistically (~2-3x), for the paper's Fig-3 benchmark.
"""

from __future__ import annotations

import os
from typing import Tuple

import numpy as np

from .dataset import RaDatasetWriter


def make_token_dataset(
    root: str,
    *,
    n_docs: int = 4096,
    seq_len: int = 1024,
    vocab: int = 8192,
    seed: int = 0,
    shard_rows: int = 1024,
) -> str:
    """Zipf-distributed tokens with local repetition structure (so the tiny
    LM has something learnable: token t+1 correlates with token t)."""
    rng = np.random.default_rng(seed)
    w = RaDatasetWriter(root, {"tokens": ((seq_len,), "uint32")}, shard_rows=shard_rows)
    # markov-ish: next token = f(current) with noise
    perm = rng.permutation(vocab)
    for lo in range(0, n_docs, 256):
        n = min(256, n_docs - lo)
        toks = np.empty((n, seq_len), dtype=np.uint32)
        cur = rng.zipf(1.3, size=n).clip(1, vocab - 1)
        for t in range(seq_len):
            toks[:, t] = cur
            follow = perm[cur]  # deterministic successor
            noise = rng.zipf(1.3, size=n).clip(1, vocab - 1)
            take_follow = rng.random(n) < 0.7
            cur = np.where(take_follow, follow, noise) % vocab
        w.append(tokens=toks)
    w.finish({"vocab": vocab, "seq_len": seq_len, "seed": seed})
    return root


def _structured_images(rng, n: int, h: int, w: int, c: int) -> np.ndarray:
    """Images with smooth gradients + shapes: PNG-compressible like real data."""
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    imgs = np.empty((n, h, w, c), dtype=np.uint8)
    for i in range(n):
        cx, cy = rng.uniform(0, w), rng.uniform(0, h)
        r = rng.uniform(h / 8, h / 2)
        base = 127 + 120 * np.sin(xx / w * rng.uniform(1, 6) + rng.uniform(0, 6)) * np.cos(
            yy / h * rng.uniform(1, 6)
        )
        blob = (((xx - cx) ** 2 + (yy - cy) ** 2) < r * r) * rng.uniform(40, 120)
        img = np.clip(base + blob, 0, 255)
        for ch in range(c):
            imgs[i, :, :, ch] = np.clip(img * rng.uniform(0.7, 1.0), 0, 255).astype(np.uint8)
    return imgs


def make_image_dataset(
    root: str,
    *,
    kind: str = "mnist",  # 'mnist' (28x28x1) | 'cifar' (36x36x3)
    n: int = 4096,
    seed: int = 0,
    shard_rows: int = 4096,
) -> str:
    h, w, c = (28, 28, 1) if kind == "mnist" else (36, 36, 3)
    rng = np.random.default_rng(seed)
    wri = RaDatasetWriter(
        root,
        {"image": ((h, w, c), "uint8"), "label": ((), "int32")},
        shard_rows=shard_rows,
    )
    for lo in range(0, n, 1024):
        k = min(1024, n - lo)
        wri.append(
            image=_structured_images(rng, k, h, w, c),
            label=rng.integers(0, 10, size=k).astype(np.int32),
        )
    wri.finish({"kind": kind, "n": n})
    return root
