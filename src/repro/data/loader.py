"""Deterministic, resumable, prefetching loader over RaDataset.

* **Determinism**: per-epoch permutation from (seed, epoch) — every host
  derives the same global order and takes its own slice.
* **Resumability**: `LoaderState` (epoch, step) checkpoints with the model;
  `DataLoader.restore(state)` resumes mid-epoch exactly.
* **Data mesh** (``mesh=DataMesh(...)``, DESIGN.md §15): shard-ownership
  partitioning replaces the contiguous ``host_range`` split — this host
  materializes only the rows of shards it owns under the mesh's
  deterministic global shuffle, steps per epoch is the global minimum over
  hosts (lockstep-safe), and ``repartition()`` applies a membership change
  mid-epoch with no row duplicated or dropped. ``LoaderState`` then also
  carries the epoch's segment history, so elastic epochs are resumable.
* **Prefetch**: a background thread keeps ``prefetch`` batches ready, so
  host-side reads overlap device compute (the paper's I/O latency win,
  applied where it matters in training).
* **Buffer reuse** (``reuse_buffers=True``): the prefetch thread cycles
  through ``prefetch + 2`` preallocated batch buffers and streams each batch
  into them via ``RaDataset.gather/rows(out=...)`` — no per-batch allocation,
  no page-fault storm (DESIGN.md §8). The emitted arrays alias the ring, so
  a consumer must finish with (or copy) a batch before advancing more than
  ``prefetch + 1`` steps; that is exactly the train-loop pattern. Defaults to
  off to preserve the seed's value semantics.
* **Straggler visibility**: the loader tracks wait-time (device starved) vs
  ready-time; exported in ``stats()`` for the train-loop straggler monitor.
* **Remote datasets** (DESIGN.md §9): a loader over an ``http(s)://``
  ``RaDataset`` streams batches via parallel byte-range reads; with the
  block cache sized to the working set, epoch 2+ is served from RAM.
  ``stats()`` then also reports the cache hit/miss/eviction counters.
  The ``naive=True`` baseline indexes local mmaps and is refused remotely.
* **Predicate filtering** (``where=col("label") == 3``, DESIGN.md §16):
  the loader trains on only the matching rows. The match set is planned
  once with chunk-statistics pushdown (pruned chunks never fetch payload
  bytes), then shuffled/split per epoch exactly like the full dataset.
  Mutually exclusive with ``mesh=`` and ``naive=``.
* **Quantized fields** (DESIGN.md §12): fields stored as uint8 codes are
  dequantized on host by default (``dequant=True``) so consumers see the
  logical float batches; ``DeviceLoader`` wraps a ``dequant=False`` loader
  and moves the 4×-smaller uint8 bytes to the device instead, decoding
  there with the fused Pallas kernel.
* **Failure semantics**: a producer error is STICKY — every subsequent
  ``next()`` re-raises it (never a hang on a dead prefetch thread), and
  ``stop()`` verifies the producer actually exited before the buffer ring
  may be handed to a successor (a zombie thread can never alias batches a
  restarted loader emits).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..core.spec import RawArrayError
from .dataset import RaDataset


@dataclass
class LoaderState:
    epoch: int = 0
    step: int = 0  # batches already emitted within this epoch
    # mesh loaders only: the epoch's segment history [(start_step, [hosts])]
    # — everything a (re)joining host needs to rebuild the exact schedule
    mesh_segments: Optional[list] = None

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"epoch": self.epoch, "step": self.step}
        if self.mesh_segments is not None:
            d["mesh_segments"] = [[int(t), list(m)] for t, m in self.mesh_segments]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "LoaderState":
        segs = d.get("mesh_segments")
        return cls(
            epoch=int(d["epoch"]),
            step=int(d["step"]),
            mesh_segments=[(int(t), tuple(m)) for t, m in segs] if segs else None,
        )


class DataLoader:
    def __init__(
        self,
        dataset: RaDataset,
        batch_size: int,
        *,
        seed: int = 0,
        shuffle: bool = True,
        host_id: int = 0,
        host_count: int = 1,
        prefetch: int = 2,
        drop_last: bool = True,
        reuse_buffers: bool = False,
        naive: bool = False,
        dequant: bool = True,
        mesh: Optional[Any] = None,
        where: Optional[Any] = None,
    ):
        if not drop_last:
            raise NotImplementedError("fixed-shape training wants drop_last")
        if where is not None and mesh is not None:
            raise ValueError(
                "where= filters rows with predicate pushdown; the mesh "
                "partitions by shard ownership — combine them by filtering "
                "at ingest instead"
            )
        if where is not None and naive:
            raise ValueError("naive=True is the seed baseline; it has no where mode")
        if naive and getattr(dataset, "is_remote", False):
            raise ValueError(
                "naive=True gathers via local mmaps and cannot stream a "
                "remote dataset; use the default engine path"
            )
        if mesh is not None and naive:
            raise ValueError("naive=True is the seed baseline; it has no mesh mode")
        self.mesh = mesh  # repro.distributed.data_mesh.DataMesh (duck-typed)
        if mesh is not None:
            host_id, host_count = mesh.host_index, mesh.host_count
        self.ds = dataset
        self.batch_size = batch_size
        self.seed = seed
        self.shuffle = shuffle
        self.host_id = host_id
        self.host_count = host_count
        self.prefetch = prefetch
        # queue capacity must be finite or the producer laps the buffer ring
        # (prefetch=0 would mean queue.Queue(maxsize=0) = unbounded)
        self._qcap = max(1, prefetch)
        self.reuse_buffers = reuse_buffers and not naive
        self.naive = naive  # seed-era produce path (benchmark baseline)
        # predicate-filtered loading (DESIGN.md §16): the matching global
        # row set is planned ONCE via chunk-stats pushdown; epochs then
        # shuffle/split only the matching rows
        self.where = where
        self._where_rows: Optional[np.ndarray] = None
        # host-side dequantization of quantized fields (DESIGN.md §12);
        # DeviceLoader turns this off and decodes on device instead
        self.dequant = dequant
        self._ring: list = []  # preallocated batch dicts when reuse_buffers
        # Regression note (ralint guarded-by): the epoch-plan memos are
        # written by the prefetch thread (epoch rollover) AND the consumer
        # (steps_per_epoch / _invalidate_plans) — the dict clear+insert used
        # to run with no lock at all. Same for the stats counters: producer
        # writes _produce_s while the consumer writes _wait_s/_n_batches.
        self._plans_lock = threading.Lock()
        self._plans: Dict[int, Any] = {}  # guarded-by: _plans_lock
        self._order_memo = None           # guarded-by: _plans_lock
        self._last_state: Optional[LoaderState] = None  # last DELIVERED batch
        self.state = LoaderState()
        self._stats_lock = threading.Lock()
        self._wait_s = 0.0    # guarded-by: _stats_lock
        self._produce_s = 0.0  # guarded-by: _stats_lock
        self._n_batches = 0   # guarded-by: _stats_lock
        self._thread: Optional[threading.Thread] = None
        self._q: Optional[queue.Queue] = None
        # fresh Event per prefetch thread (see _start_prefetch): stop() of a
        # wedged producer must not be undone by the next start's clear()
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None  # sticky producer error

    # ---- order ------------------------------------------------------------
    def _matched_rows(self) -> np.ndarray:
        """Global rows matching ``where`` (sorted), computed once per loader
        via ``RaDataset.select_indices`` — chunk pruning means the plan
        decodes only predicate columns of undecided chunks."""
        if self._where_rows is None:
            self._where_rows = self.ds.select_indices(self.where)
        return self._where_rows

    def _host_rows(self) -> np.ndarray:
        if self.where is not None:
            rows = self._matched_rows()
            per = len(rows) // self.host_count
            start = self.host_id * per
            stop = start + per if self.host_id < self.host_count - 1 else len(rows)
            return rows[start:stop]
        start, stop = self.ds.host_range(self.host_id, self.host_count)
        return np.arange(start, stop)

    def _mesh_plan(self, epoch: int):
        """The mesh's pure epoch schedule (DESIGN.md §15), memoized — plans
        are invalidated whenever the segment history can change (restore /
        repartition / seek)."""
        with self._plans_lock:
            plan = self._plans.get(epoch)
        if plan is None:
            # plan() is pure in (seed, epoch, ...): two threads racing here
            # compute identical plans, so only the memo writes need the lock
            plan = self.mesh.plan(
                [s.rows for s in self.ds.shards],
                seed=self.seed,
                epoch=epoch,
                batch_size=self.batch_size,
                shuffle=self.shuffle,
            )
            with self._plans_lock:
                if len(self._plans) > 4:
                    self._plans.clear()
                self._plans[epoch] = plan
        return plan

    def _epoch_order(self, epoch: int) -> np.ndarray:
        if self.mesh is not None:
            return self._mesh_plan(epoch).host_order(self.mesh.host)
        rows = self._host_rows()
        if not self.shuffle:
            return rows
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(rows)

    def _cached_order(self, epoch: int) -> np.ndarray:
        """The permutation is a pure function of (seed, epoch): compute it
        once per epoch, not once per batch (the seed path recomputed it every
        ``_produce`` — measurable at high batch rates). Returns the LOCAL
        tuple's order, so a concurrent caller on another epoch (a zombie
        producer racing its successor) can't swap the memo underneath us."""
        cached = self._order_memo
        if cached is None or cached[0] != epoch:
            cached = (epoch, self._epoch_order(epoch))
            with self._plans_lock:
                self._order_memo = cached
        return cached[1]

    def steps_per_epoch(self) -> int:
        """Steps the CURRENT epoch runs — the GLOBAL MINIMUM over hosts, so
        lockstep collectives never hang on one host's remainder tail (the
        ``host_range`` split hands the last host the extra rows; the floor
        division used to give it a different step count). The dropped tail
        is exposed in ``stats()['dropped_tail_rows']``."""
        return self._spe(self.state.epoch)

    def _spe(self, epoch: int) -> int:
        if self.mesh is not None:
            # mesh epochs re-deal ownership, so the minimum-owner step count
            # is genuinely per-epoch (and per segment history)
            return self._mesh_plan(epoch).steps()
        if self.where is not None:
            return (len(self._matched_rows()) // self.host_count) // self.batch_size
        return (self.ds.total_rows // self.host_count) // self.batch_size

    def _dropped_tail(self, epoch: int) -> int:
        """Rows the epoch never delivers GLOBALLY (identical on every host)."""
        if self.mesh is not None:
            return self._mesh_plan(epoch).dropped_rows()
        total = (len(self._matched_rows()) if self.where is not None
                 else self.ds.total_rows)
        return total - self._spe(epoch) * self.batch_size * self.host_count

    # ---- synchronous iteration ---------------------------------------------
    def _make_ring(self) -> list:
        """qcap+2 preallocated batch dicts (stored dtypes): one held by the
        consumer, up to ``qcap`` queued, one being filled. Built on the
        consumer thread BEFORE the producer starts, and handed to it by
        reference — a zombie producer that outlived its join keeps its own
        (discarded) ring object and can never touch a successor's."""
        nbufs = self._qcap + 2
        specs = {f: self._stored_spec(f) for f in self.ds.fields}
        return [
            {
                f: np.empty((self.batch_size,) + shape, dtype)
                for f, (shape, dtype) in specs.items()
            }
            for _ in range(nbufs)
        ]

    def _stored_spec(self, field: str):
        """Stored (on-disk) row spec — uint8 for quantized fields; staging
        buffers and reads are planned in stored terms (DESIGN.md §12)."""
        spec = getattr(self.ds, "stored_spec", None)
        if spec is not None:
            return spec(field)
        info = self.ds.fields[field]
        return tuple(info["shape"]), np.dtype(info["dtype"])

    def _produce(
        self, epoch: int, step: int, out: Optional[Dict[str, np.ndarray]] = None
    ) -> Dict[str, np.ndarray]:
        if self.naive:
            order = self._epoch_order(epoch)  # seed behavior: fresh every batch
        else:
            order = self._cached_order(epoch)
        lo = step * self.batch_size
        idx = order[lo : lo + self.batch_size]
        if self.mesh is not None:
            if idx.size < self.batch_size or int(idx.min()) < 0:
                raise RawArrayError(
                    f"host {self.mesh.host!r} is not a mesh member at epoch "
                    f"{epoch} step {step} (left the membership?)"
                )
            # owned rows are non-contiguous even with shuffle=False — always
            # gather; the planner opens only this host's owned shards
            batch = self.ds.gather(idx, out=out)
        elif self.naive and self.shuffle:
            batch = self.ds.gather_naive(idx)
        elif self.shuffle or self.where is not None:
            # predicate-filtered rows are non-contiguous even unshuffled
            batch = self.ds.gather(idx, out=out)
        else:
            batch = self.ds.rows(int(idx[0]), int(idx[-1]) + 1, out=out)
        if self.dequant:
            for f, info in getattr(self.ds, "quant", {}).items():
                if f in batch:
                    # float32 affine decode — allocates a fresh logical array,
                    # so the emitted field never aliases the uint8 ring
                    batch[f] = info.dequantize(batch[f])
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._exc is not None:
            # sticky: the prefetch thread put ONE exception and exited — a
            # second get() would block forever on the empty queue, so every
            # subsequent next() re-raises instead (restart via stop()/restore)
            raise self._exc
        if self._q is None:
            self._start_prefetch()
        t0 = time.perf_counter()
        batch = self._q.get()
        with self._stats_lock:
            self._wait_s += time.perf_counter() - t0
            self._n_batches += 1
        if isinstance(batch, Exception):
            self._exc = batch
            raise batch
        # the last DELIVERED position anchors repartition(): queued-but-
        # undelivered prefetch batches are discarded and their rows re-dealt
        self._last_state = batch["_state"]
        return batch

    # ---- prefetch thread ---------------------------------------------------
    def _start_prefetch(self) -> None:
        # the queue AND the stop event are private to this thread (captured
        # by closure, not read back off self): a zombie predecessor that
        # outlived its join timeout can neither be revived by this clear-less
        # start nor push a stale batch into the new queue
        q = self._q = queue.Queue(maxsize=self._qcap)
        stop = self._stop = threading.Event()
        self._exc = None
        ring: Optional[list] = None
        if self.reuse_buffers:
            if not self._ring:
                self._ring = self._make_ring()
            ring = self._ring

        def run():
            epoch, step = self.state.epoch, self.state.step
            spe = self._spe(epoch)
            pos = 0
            while not stop.is_set():
                if spe <= 0:
                    # surface instead of spinning: with a mesh this means the
                    # smallest owner holds fewer than batch_size rows
                    e: Exception = RawArrayError(
                        f"epoch {epoch} has zero steps (batch_size="
                        f"{self.batch_size} exceeds the smallest host's rows)"
                    )
                    while not stop.is_set():
                        try:
                            q.put(e, timeout=0.2)
                            break
                        except queue.Full:
                            continue
                    return
                if step >= spe:
                    epoch, step = epoch + 1, 0
                    # a mesh re-deals ownership per epoch: the minimum-owner
                    # step count must be re-derived at every rollover
                    spe = self._spe(epoch)
                    continue
                try:
                    t0 = time.perf_counter()
                    buf = None
                    if ring is not None:
                        buf = ring[pos % len(ring)]
                        pos += 1
                    b = self._produce(epoch, step, buf)
                    with self._stats_lock:
                        self._produce_s += time.perf_counter() - t0
                except Exception as e:  # surface in consumer (sticky there)
                    while not stop.is_set():
                        try:
                            q.put(e, timeout=0.2)
                            break
                        except queue.Full:
                            continue
                    return
                b["_state"] = (
                    LoaderState(epoch, step)
                    if self.mesh is None
                    else LoaderState(epoch, step, self.mesh.segments_for(epoch))
                )
                step += 1
                while not stop.is_set():
                    try:
                        q.put(b, timeout=0.2)
                        break
                    except queue.Full:
                        continue

        self._thread = threading.Thread(target=run, daemon=True, name="ra-prefetch")
        self._thread.start()

    def restore(self, state: LoaderState) -> None:
        """Resume exactly after the batch `state` describes. A mesh state
        carries the epoch's segment history, so restoring mid-elastic-epoch
        rebuilds the identical schedule the original fleet was running."""
        self.stop()
        if self.mesh is not None and state.mesh_segments:
            self.mesh.load_segments(state.epoch, state.mesh_segments)
        self._invalidate_plans()
        self.state = LoaderState(state.epoch, state.step + 1)
        if self.state.step >= self._spe(state.epoch):
            self.state = LoaderState(state.epoch + 1, 0)

    def seek(self, epoch: int, step: int) -> None:
        """Position so the NEXT batch emitted is ``(epoch, step)`` — the
        joining-host entry point: build a ``DataMesh``, load the handed-over
        segment history (or call ``mesh.repartition``), then seek to the
        boundary step."""
        self.stop()
        self._invalidate_plans()
        self.state = LoaderState(int(epoch), int(step))

    def repartition(self, hosts) -> LoaderState:
        """Apply a mesh membership change effective at the next UNDELIVERED
        batch: the prefetch thread is stopped and its queued batches are
        discarded (their rows stay unconsumed in the segment replay, so they
        re-deal under the new ownership — exactly-once is preserved w.r.t.
        batches actually delivered), the mesh records the segment boundary,
        and prefetch restarts lazily under the new plan. No epoch restart.
        Returns the boundary position every surviving host must agree on."""
        if self.mesh is None:
            raise RawArrayError("repartition() requires a mesh loader")
        last = self._last_state
        if last is None:
            nxt = LoaderState(self.state.epoch, self.state.step)
        else:
            nxt = LoaderState(last.epoch, last.step + 1)
            if nxt.step >= self._spe(last.epoch):
                nxt = LoaderState(last.epoch + 1, 0)
        self.stop()
        self.mesh.repartition(hosts, epoch=nxt.epoch, step=nxt.step)
        self._invalidate_plans()
        self.state = LoaderState(nxt.epoch, nxt.step)
        return self.state

    def _invalidate_plans(self) -> None:
        with self._plans_lock:
            self._plans.clear()
            self._order_memo = None
        self._last_state = None

    def stop(self, join_timeout: float = 2.0) -> None:
        """Stop the prefetch thread and VERIFY it exited. If the join times
        out (a producer wedged in a slow read), the buffer ring is discarded
        so the zombie can never write into buffers a restarted loader hands
        out — the successor allocates a fresh ring; the zombie's private
        stop event stays set and its queue is orphaned, so the worst it can
        do is finish one produce into memory nobody reads."""
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
        if self._thread is not None:
            self._thread.join(timeout=join_timeout)
            if self._thread.is_alive():
                # zombie still running: it may be mid-_produce into the ring,
                # so orphan it — the next start allocates fresh buffers
                self._ring = []
        self._q = None
        self._thread = None
        self._exc = None

    def stats(self) -> Dict[str, float]:
        with self._stats_lock:
            wait_s, produce_s, n = self._wait_s, self._produce_s, self._n_batches
        out = {
            "loader_wait_s": wait_s,
            "loader_produce_s": produce_s,
            "batches": float(n),
            # host identity + the lockstep tail (global, identical on every
            # host) — inputs to data_mesh.aggregate_stats
            "host_id": float(
                self.mesh.host_index if self.mesh is not None else self.host_id
            ),
            "host_count": float(
                self.mesh.host_count if self.mesh is not None else self.host_count
            ),
            "dropped_tail_rows": float(self._dropped_tail(self.state.epoch)),
        }
        io_stats = getattr(self.ds, "io_stats", None)
        if io_stats is not None:
            for k, v in io_stats().items():
                # chunk decode counters (DESIGN.md §10) are not cache stats
                key = k if k.startswith("chunk_") else f"remote_cache_{k}"
                out[key] = float(v)
        return out
