"""Batched decode serving on RawArray-mmapped weights."""

from .engine import ServeEngine

__all__ = ["ServeEngine"]
