"""Minimal batched serving engine.

Weights load by memory-mapping the RawArray checkpoint (zero-copy until
pages are touched — the paper's mmap story applied to model serving, where
cold-start latency is checkpoint-read latency). Requests are batched,
prefilled together (right-aligned padding-free: equal-length prompts per
batch for simplicity), then decoded step by step with a shared KV cache.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import restore_naive, restore_pipelined
from ..models.config import ModelConfig


class ServeEngine:
    def __init__(
        self,
        model,
        params: Any = None,
        *,
        checkpoint: Optional[str] = None,
        restore: str = "pipelined",
    ):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        if params is None:
            if checkpoint is None:
                raise ValueError("need params or checkpoint")
            like = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            # cold start is checkpoint-read latency: the overlapped restore
            # engine (DESIGN.md §13) is the default; "naive" keeps the
            # phase-by-phase baseline reachable for comparison
            if restore == "pipelined":
                params, _, _ = restore_pipelined(checkpoint, like)
            elif restore == "naive":
                params, _, _ = restore_naive(checkpoint, like)
            else:
                raise ValueError(f"restore must be 'pipelined' or 'naive', got {restore!r}")
        self.params = params
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self.stats: Dict[str, float] = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0.0}

    def generate(
        self,
        prompts: np.ndarray,  # (B, S_prompt) int32 — equal lengths
        max_new: int = 32,
        *,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> np.ndarray:
        B, S = prompts.shape
        t0 = time.perf_counter()
        logits, cache = self._prefill_with_capacity(prompts, S + max_new)
        jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.perf_counter() - t0

        out = np.zeros((B, max_new), dtype=np.int32)
        rng = jax.random.PRNGKey(seed)
        t0 = time.perf_counter()
        tok = self._sample(logits, temperature, rng)
        out[:, 0] = np.asarray(tok)[:, 0]
        for i in range(1, max_new):
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits, temperature, sub)
            out[:, i] = np.asarray(tok)[:, 0]
        jax.block_until_ready(tok)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["tokens"] += B * max_new
        return out

    def _prefill_with_capacity(self, prompts: np.ndarray, capacity: int):
        """Prefill such that the returned cache can absorb ``capacity - S``
        further decode steps. Family-dependent:

        * attention families: prompts are right-padded to ``capacity`` so the
          KV cache has room; ``pos`` is reset to the true prompt length
          (causal masking keeps the padding region dead until overwritten);
        * pure SSM: the cache is O(1) — plain prefill;
        * hybrid: the shared-attn cache is length-bound, so we allocate an
          empty capacity cache and replay the prompt token-by-token.
        """
        B, S = prompts.shape
        fam = self.cfg.family
        if fam == "ssm":
            return self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        if fam == "hybrid":
            cache = self.model.empty_cache(B, capacity)
            logits = None
            tok_arr = jnp.asarray(prompts)
            for t in range(S):
                logits, cache = self._decode(self.params, cache, tok_arr[:, t : t + 1])
            return logits, cache
        # prefill the first S-1 tokens (padded to capacity so the cache has
        # room), rewind pos, then feed the last prompt token as a decode step
        # — its logits are exactly the first-new-token distribution.
        padded = np.zeros((B, capacity), dtype=prompts.dtype)
        padded[:, : S - 1] = prompts[:, : S - 1]
        _, cache = self._prefill(self.params, {"tokens": jnp.asarray(padded)})
        cache["pos"] = jnp.asarray(S - 1, jnp.int32)
        logits, cache = self._decode(self.params, cache, jnp.asarray(prompts[:, S - 1 : S]))
        return logits, cache

    def _sample(self, logits: jax.Array, temperature: float, rng) -> jax.Array:
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(rng, logits / temperature, axis=-1).astype(jnp.int32)[:, None]

    def throughput(self) -> Dict[str, float]:
        d = dict(self.stats)
        if d["decode_s"] > 0:
            d["decode_tok_per_s"] = d["tokens"] / d["decode_s"]
        return d
