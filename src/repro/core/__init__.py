"""RawArray (.ra) — the paper's archival format, as the framework's data plane.

Public API mirrors the paper's reference implementations::

    import repro.core as ra
    ra.write("x.ra", arr)
    arr = ra.read("x.ra")
    m = ra.memmap("x.ra")          # zero-copy
"""

from . import codec
from . import engine
from . import quant
from . import stats
from .header import Header, decode_header, read_header
from .io import (
    RaWriter,
    append_metadata,
    header_of,
    is_url,
    join_path,
    memmap,
    memmap_slice,
    nbytes_on_disk,
    read,
    read_into,
    read_metadata,
    read_quant_metadata,
    read_stats,
    write,
    write_like,
)
from .stats import (
    ChunkStats,
    Expr,
    StatsAccumulator,
    col,
    compute_stats,
    split_stats,
    stats_supported,
)
from .quant import QuantInfo, decode_quant_metadata, quant_params, resolve_quant_spec
from .sharded import (
    ShardedWriter,
    ShardIndex,
    load_index,
    read_sharded,
    read_slice,
    read_slice_naive,
    write_sharded,
)
from .spec import (
    ELTYPE_BRAIN,
    ELTYPE_COMPLEX,
    ELTYPE_FLOAT,
    ELTYPE_INT,
    ELTYPE_STRUCT,
    ELTYPE_UINT,
    FLAG_BIG_ENDIAN,
    FLAG_CHUNKED,
    FLAG_CRC32_TRAILER,
    FLAG_ZLIB,
    MAGIC,
    MAGIC_BYTES,
    RawArrayError,
)

__all__ = [
    "ChunkStats",
    "Expr",
    "Header",
    "QuantInfo",
    "StatsAccumulator",
    "codec",
    "col",
    "compute_stats",
    "read_stats",
    "split_stats",
    "stats",
    "stats_supported",
    "decode_quant_metadata",
    "engine",
    "quant",
    "quant_params",
    "read_quant_metadata",
    "resolve_quant_spec",
    "read_header",
    "decode_header",
    "read",
    "read_into",
    "write",
    "RaWriter",
    "ShardedWriter",
    "memmap",
    "memmap_slice",
    "read_metadata",
    "append_metadata",
    "header_of",
    "is_url",
    "join_path",
    "write_like",
    "nbytes_on_disk",
    "write_sharded",
    "read_sharded",
    "read_slice",
    "read_slice_naive",
    "load_index",
    "ShardIndex",
    "MAGIC",
    "MAGIC_BYTES",
    "RawArrayError",
    "ELTYPE_STRUCT",
    "ELTYPE_INT",
    "ELTYPE_UINT",
    "ELTYPE_FLOAT",
    "ELTYPE_COMPLEX",
    "ELTYPE_BRAIN",
    "FLAG_BIG_ENDIAN",
    "FLAG_CHUNKED",
    "FLAG_CRC32_TRAILER",
    "FLAG_ZLIB",
]
