"""RawArray header encode/decode (paper §2, Table 1; DESIGN.md §1)."""

from __future__ import annotations

import io
from dataclasses import dataclass
from typing import BinaryIO, Tuple

import numpy as np

from .spec import (
    FIXED_HEADER,
    FIXED_HEADER_BYTES,
    FLAG_BIG_ENDIAN,
    FLAG_CHUNKED,
    FLAG_CRC32_TRAILER,
    FLAG_ZLIB,
    KNOWN_FLAGS,
    MAGIC,
    MAX_NDIMS,
    RawArrayError,
    header_nbytes,
)
from .dtypes import dtype_of, eltype_of


@dataclass(frozen=True)
class Header:
    """Decoded RawArray header."""

    flags: int
    eltype: int
    elbyte: int
    data_length: int
    shape: Tuple[int, ...]

    @property
    def ndims(self) -> int:
        return len(self.shape)

    @property
    def nbytes(self) -> int:
        """Header size on disk."""
        return header_nbytes(self.ndims)

    @property
    def big_endian(self) -> bool:
        return bool(self.flags & FLAG_BIG_ENDIAN)

    @property
    def count(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    @property
    def logical_nbytes(self) -> int:
        """Uncompressed payload size implied by shape × elbyte (equals
        ``data_length`` except for compressed payloads — zlib or chunked —
        where ``data_length`` is the stored size)."""
        return self.count * self.elbyte

    @property
    def compressed(self) -> bool:
        """Payload bytes on disk are not the raw array bytes."""
        return bool(self.flags & (FLAG_ZLIB | FLAG_CHUNKED))

    @property
    def plain(self) -> bool:
        """True when the data segment can be streamed byte-for-byte into a
        native little-endian destination — the zero-copy fast path every
        layer (local, remote, sharded, checkpoint) keys off."""
        return not (
            self.flags & (FLAG_ZLIB | FLAG_CHUNKED | FLAG_CRC32_TRAILER)
        ) and not self.big_endian

    def dtype(self) -> np.dtype:
        return dtype_of(self.eltype, self.elbyte, big_endian=self.big_endian)

    def validate(self, *, strict_flags: bool = True) -> None:
        if self.ndims > MAX_NDIMS:
            raise RawArrayError(f"ndims={self.ndims} exceeds sanity bound {MAX_NDIMS}")
        if strict_flags and (self.flags & ~KNOWN_FLAGS):
            raise RawArrayError(f"unknown flag bits set: {self.flags:#x}")
        expected = self.logical_nbytes
        # The paper keeps data_length as a redundant sanity check; honor it —
        # except for compressed payloads where data_length is the stored size.
        if not self.compressed and expected != self.data_length:
            raise RawArrayError(
                f"data_length={self.data_length} inconsistent with "
                f"shape={self.shape} x elbyte={self.elbyte} (= {expected})"
            )

    def encode(self) -> bytes:
        buf = io.BytesIO()
        buf.write(
            FIXED_HEADER.pack(
                MAGIC, self.flags, self.eltype, self.elbyte, self.data_length, self.ndims
            )
        )
        if self.ndims:
            buf.write(np.asarray(self.shape, dtype="<u8").tobytes())
        return buf.getvalue()

    @classmethod
    def for_array(cls, arr: np.ndarray, flags: int = 0, data_length: int | None = None) -> "Header":
        eltype, elbyte = eltype_of(arr.dtype)
        dlen = arr.size * elbyte if data_length is None else data_length
        return cls(
            flags=flags,
            eltype=eltype,
            elbyte=elbyte,
            data_length=dlen,
            shape=tuple(int(d) for d in arr.shape),
        )


def read_header(f: BinaryIO, *, strict_flags: bool = True) -> Header:
    """Parse a header from a binary stream positioned at byte 0 of the file."""
    fixed = f.read(FIXED_HEADER_BYTES)
    if len(fixed) < FIXED_HEADER_BYTES:
        raise RawArrayError("file too short for RawArray header")
    magic, flags, eltype, elbyte, dlen, ndims = FIXED_HEADER.unpack(fixed)
    if magic != MAGIC:
        raise RawArrayError(
            f"bad magic {magic:#018x} (expected {MAGIC:#018x} = 'rawarray')"
        )
    if ndims > MAX_NDIMS:
        raise RawArrayError(f"ndims={ndims} exceeds sanity bound {MAX_NDIMS}")
    raw_dims = f.read(8 * ndims)
    if len(raw_dims) < 8 * ndims:
        raise RawArrayError("file truncated inside dimension vector")
    shape = tuple(int(d) for d in np.frombuffer(raw_dims, dtype="<u8"))
    hdr = Header(flags=flags, eltype=eltype, elbyte=elbyte, data_length=dlen, shape=shape)
    hdr.validate(strict_flags=strict_flags)
    return hdr


def decode_header(buf: bytes, *, strict_flags: bool = True) -> Header:
    return read_header(io.BytesIO(buf), strict_flags=strict_flags)
