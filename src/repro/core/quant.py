"""Typed quantized-field metadata: the paper's user-metadata extension
point carrying dequantization parameters (DESIGN.md §12).

A quantized RawArray file stores a uint8 payload plus a small JSON object
in the trailing user metadata describing how to reconstruct the original
floating-point values::

    {"ra_quant": {"mode": "u8", "scale": [...], "bias": [...],
                  "orig_dtype": "float32", "axis": -1}}

``scale``/``bias`` are either scalars or one value per channel of the LAST
axis, and reconstruction is the affine map ``x ≈ q * scale + bias``
computed in float32 — exactly what the fused Pallas kernel
(``repro.kernels.ops.dequant_u8``) evaluates on device, so the host
(numpy) and device (Pallas) decode paths agree bit-for-bit on CPU
interpret mode and within float32 rounding on real accelerators.

The schema is deliberately tiny and self-contained: any RawArray reader
that understands JSON can decode a quantized file, and readers that don't
look at metadata still get a well-formed uint8 array — the backward-
compatible extension path the paper advertises for its metadata segment.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from .spec import RawArrayError

# the metadata key the schema lives under (shared with dataset manifests)
QUANT_KEY = "ra_quant"

_MODES = {"u8"}


@dataclass
class QuantInfo:
    """Dequantization parameters for one quantized array/field.

    ``scale`` and ``bias`` are float32 arrays of shape ``()`` (uniform) or
    ``(C,)`` (per-channel over the last axis). ``orig_dtype`` names the
    logical dtype the consumer should see after dequantization.
    """

    mode: str = "u8"
    scale: np.ndarray = field(default_factory=lambda: np.float32(1.0))
    bias: np.ndarray = field(default_factory=lambda: np.float32(0.0))
    orig_dtype: str = "float32"
    axis: int = -1  # channel axis the per-channel params broadcast over

    def __post_init__(self) -> None:
        if self.mode not in _MODES:
            raise RawArrayError(f"unknown quantization mode {self.mode!r}")
        if self.axis != -1:
            raise RawArrayError("only axis=-1 (last-axis channels) is supported")
        self.scale = np.asarray(self.scale, dtype=np.float32)
        self.bias = np.asarray(self.bias, dtype=np.float32)
        if self.scale.ndim > 1 or self.bias.ndim > 1:
            raise RawArrayError("quant scale/bias must be scalar or 1-D per-channel")

    # ---- numpy (host) paths ------------------------------------------------
    def quantize(self, arr: np.ndarray) -> np.ndarray:
        """Float array -> uint8 codes: ``round((x - bias) / scale)`` clipped
        to [0, 255]. Values outside the calibration range saturate."""
        a = np.asarray(arr, dtype=np.float32)
        q = np.rint((a - self.bias) / self.scale)
        return np.clip(q, 0, 255).astype(np.uint8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        """uint8 codes -> logical values, float32 math (``q*scale + bias``) —
        the numpy twin of the fused on-device Pallas kernel."""
        x = q.astype(np.float32) * self.scale + self.bias
        return x.astype(np.dtype(self.orig_dtype), copy=False)

    def channel_params(self, channels: int) -> Tuple[np.ndarray, np.ndarray]:
        """``(scale, bias)`` broadcast to exactly ``(channels,)`` float32 —
        the shape the Pallas dequant kernel wants."""
        for name, a in (("scale", self.scale), ("bias", self.bias)):
            if a.ndim == 1 and a.shape[0] not in (1, channels):
                raise RawArrayError(
                    f"per-channel {name} has {a.shape[0]} entries, "
                    f"field has {channels} channels"
                )
        s = np.broadcast_to(self.scale.reshape(-1) if self.scale.ndim else self.scale,
                            (channels,)).astype(np.float32)
        b = np.broadcast_to(self.bias.reshape(-1) if self.bias.ndim else self.bias,
                            (channels,)).astype(np.float32)
        return np.ascontiguousarray(s), np.ascontiguousarray(b)

    # ---- wire format -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        def _num(a: np.ndarray):
            return a.tolist() if a.ndim else float(a)

        return {
            "mode": self.mode,
            "scale": _num(self.scale),
            "bias": _num(self.bias),
            "orig_dtype": self.orig_dtype,
            "axis": self.axis,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "QuantInfo":
        try:
            return cls(
                mode=str(d["mode"]),
                scale=np.asarray(d["scale"], dtype=np.float32),
                bias=np.asarray(d["bias"], dtype=np.float32),
                orig_dtype=str(d.get("orig_dtype", "float32")),
                axis=int(d.get("axis", -1)),
            )
        except (KeyError, TypeError, ValueError) as e:
            raise RawArrayError(f"malformed {QUANT_KEY} metadata: {d!r}") from e

    def encode(self, extra: Optional[Dict[str, Any]] = None) -> bytes:
        """The metadata blob for a quantized file: a JSON object holding the
        schema under ``"ra_quant"`` (plus any caller keys)."""
        obj = dict(extra or {})
        obj[QUANT_KEY] = self.to_dict()
        return json.dumps(obj).encode()


QuantSpec = Union[str, Tuple[str, float, float], QuantInfo]


def resolve_quant_spec(spec: QuantSpec, dtype="float32") -> QuantInfo:
    """Normalize a user-facing quantize spec into a ``QuantInfo``.

    * ``"u8"``            — uniform range [0, 1] (normalized image pixels,
      the common training-ingest case; out-of-range values saturate);
    * ``("u8", lo, hi)``  — explicit uniform calibration range;
    * a ``QuantInfo``     — taken as-is.

    Streaming writers need the range BEFORE the data arrives, which is why
    the spec is declarative; ``quant_params`` computes a data-driven range
    when the whole array is in hand."""
    if isinstance(spec, QuantInfo):
        return spec
    if isinstance(spec, str):
        mode, lo, hi = spec, 0.0, 1.0
    else:
        mode, lo, hi = spec[0], float(spec[1]), float(spec[2])
    if mode not in _MODES:
        raise RawArrayError(f"unknown quantization mode {mode!r}")
    if not hi > lo:
        raise RawArrayError(f"quant range must have hi > lo, got [{lo}, {hi}]")
    return QuantInfo(
        mode=mode,
        scale=np.float32((hi - lo) / 255.0),
        bias=np.float32(lo),
        orig_dtype=str(np.dtype(dtype)),
    )


def quant_params(arr: np.ndarray, mode: str = "u8") -> QuantInfo:
    """Data-driven calibration: per channel of the LAST axis for ndim >= 2
    (each channel's [min, max] maps onto [0, 255]), one global scalar range
    for 1-D arrays (whose "last axis" is the data itself — per-element
    params would be metadata bigger than the payload). Constant channels
    get ``scale=1`` so they roundtrip exactly through ``bias``."""
    if mode not in _MODES:
        raise RawArrayError(f"unknown quantization mode {mode!r}")
    a = np.asarray(arr)
    if not np.issubdtype(a.dtype, np.floating):
        raise RawArrayError(f"can only quantize float arrays, got {a.dtype}")
    if a.ndim < 1:
        raise RawArrayError("cannot quantize a 0-d array (no channel axis)")
    flat = (a.reshape(-1, 1) if a.ndim == 1 else a.reshape(-1, a.shape[-1]))
    flat = flat.astype(np.float32)
    if flat.size == 0:  # empty array: any affine map roundtrips nothing
        return QuantInfo(mode=mode, scale=np.float32(1.0),
                         bias=np.float32(0.0), orig_dtype=str(a.dtype))
    lo = flat.min(axis=0)
    hi = flat.max(axis=0)
    scale = (hi - lo) / np.float32(255.0)
    scale = np.where(scale > 0, scale, np.float32(1.0)).astype(np.float32)
    if a.ndim == 1:  # scalar params, not one per element
        scale, lo = scale[0], lo[0]
    return QuantInfo(mode=mode, scale=scale, bias=np.asarray(lo, np.float32),
                     orig_dtype=str(a.dtype))


def decode_quant_metadata(meta: Optional[bytes]) -> Optional[QuantInfo]:
    """Parse a RawArray metadata blob; returns the typed ``QuantInfo`` when
    the ``"ra_quant"`` schema is present, ``None`` for any other metadata
    (non-JSON, JSON without the key, empty)."""
    if not meta:
        return None
    try:
        obj = json.loads(meta)
    except (ValueError, UnicodeDecodeError):
        return None
    if not isinstance(obj, dict) or QUANT_KEY not in obj:
        return None
    return QuantInfo.from_dict(obj[QUANT_KEY])
