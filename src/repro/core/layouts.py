"""Single registry of every on-disk struct layout (DESIGN.md §17).

The format's whole pitch is that every byte is introspectable with
``od -t u8`` — which only stays true while every writer and reader agrees
on the same geometry. Before this module, the header geometry lived in
``spec.py``, the chunk-table geometry in ``codec.py``, and the rastats
geometry in ``stats.py``, each as its own ``struct.Struct`` literal; a
drifted copy would produce files other layers misparse. Now each layout
is declared exactly once here, the declaring modules build their structs
FROM this registry, and two enforcement layers key off it:

* ``ralint`` (``repro.devtools.lint``) statically rejects any literal
  ``struct`` format string in the core plane that is not registered here;
* ``racat doctor`` (``repro.devtools.doctor``) checks real files on disk
  against the registered geometry and exits nonzero on drift.

This module is intentionally stdlib-only (``struct`` + ``dataclasses``)
and imports nothing from the rest of the package, so every layer — spec,
codec, stats, devtools, tools — can depend on it without cycles.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class Layout:
    """One on-disk record layout: a fixed head plus optional repeated entries.

    ``head_fmt`` is the ``struct`` format of the fixed head (always
    little-endian u64s — the ``od -t u8`` contract).  ``entry_bytes`` is
    the size of one repeated entry after the head (0 = no entries);
    ``entry_fmt`` is its ``struct`` format when entries are row-packed,
    or ``None`` for columnar entry regions (rastats stores four parallel
    arrays rather than packed rows — the 32 bytes per window are split
    as u64 count / u64 nan_count / f64 min / f64 max columns).
    """

    name: str
    magic: Optional[bytes]          # leading magic bytes, None = no magic
    head_fmt: str
    head_fields: Tuple[str, ...]    # names of the head's fields, in order
    entry_bytes: int = 0
    entry_fmt: Optional[str] = None
    entry_fields: Tuple[str, ...] = ()
    module: str = ""                # module that declares/owns this layout
    design: str = ""                # DESIGN.md section documenting it

    @property
    def head_struct(self) -> struct.Struct:
        return struct.Struct(self.head_fmt)

    @property
    def head_bytes(self) -> int:
        return self.head_struct.size

    @property
    def magic_int(self) -> Optional[int]:
        """The magic as the little-endian u64 its first head field holds."""
        if self.magic is None:
            return None
        return int.from_bytes(self.magic, "little")

    def nbytes(self, nentries: int) -> int:
        """Total encoded size for ``nentries`` repeated entries."""
        return self.head_bytes + self.entry_bytes * int(nentries)


# --- the registry -----------------------------------------------------------
# RawArray file header (paper Table 1; DESIGN.md §1).  The shape vector
# (u64 dims[ndims]) follows the fixed head as "entries" of one u64 each.
HEADER = Layout(
    name="header",
    magic=b"rawarray",
    head_fmt="<QQQQQQ",
    head_fields=("magic", "flags", "eltype", "elbyte", "data_length", "ndims"),
    entry_bytes=8,
    entry_fmt="<Q",
    entry_fields=("dim",),
    module="repro.core.spec",
    design="§1",
)

# Chunk-table trailer of FLAG_CHUNKED files (DESIGN.md §10): fixed head
# then one row-packed 4×u64 entry per chunk.
CHUNK_TABLE = Layout(
    name="rachunks",
    magic=b"rachunks",
    head_fmt="<QQQQ",
    head_fields=("magic", "codec_id", "chunk_bytes", "nchunks"),
    entry_bytes=32,
    entry_fmt="<QQQQ",
    entry_fields=("raw_offset", "stored_offset", "stored_len", "crc32"),
    module="repro.core.codec",
    design="§10",
)

# Per-chunk statistics block (DESIGN.md §16): fixed head then a COLUMNAR
# entry region — u64 count[n], u64 nan_count[n], f64 min[n], f64 max[n]
# (32 bytes per window, but stored as four parallel arrays, hence
# entry_fmt=None).
RASTATS = Layout(
    name="rastats",
    magic=b"rastats_",
    head_fmt="<QQQQQ",
    head_fields=("magic", "version", "block_bytes", "nchunks", "chunk_bytes"),
    entry_bytes=32,
    entry_fmt=None,
    entry_fields=("count", "nan_count", "min", "max"),
    module="repro.core.stats",
    design="§16",
)

# Bare little-endian u64 — the scalar every layout above is built from
# (also the file-level CRC32 trailer reads/writes through "<I", declared
# here so the linter's closed set covers every core-plane literal).
U64 = Layout(
    name="u64",
    magic=None,
    head_fmt="<Q",
    head_fields=("value",),
    module="repro.core.spec",
    design="§1",
)

CRC32 = Layout(
    name="crc32",
    magic=None,
    head_fmt="<I",
    head_fields=("crc32",),
    module="repro.core.io",
    design="§7",
)

LAYOUTS: Dict[str, Layout] = {
    lay.name: lay
    for lay in (HEADER, CHUNK_TABLE, RASTATS, U64, CRC32)
}

#: every registered struct format string — the closed set ``ralint``'s
#: struct-layout rule checks core-plane literals against
REGISTERED_FORMATS = frozenset(
    lay.head_fmt for lay in LAYOUTS.values()
) | frozenset(
    lay.entry_fmt for lay in LAYOUTS.values() if lay.entry_fmt is not None
)


def _selfcheck() -> None:
    """Internal consistency of the registry itself (runs at import)."""
    for lay in LAYOUTS.values():
        probe = struct.Struct(lay.head_fmt)
        vals = probe.unpack(b"\x00" * probe.size)
        if len(vals) != len(lay.head_fields):
            raise AssertionError(
                f"layout {lay.name}: head_fmt {lay.head_fmt!r} has "
                f"{len(vals)} fields but head_fields names {len(lay.head_fields)}"
            )
        if lay.entry_fmt is not None and struct.Struct(lay.entry_fmt).size != lay.entry_bytes:
            raise AssertionError(
                f"layout {lay.name}: entry_fmt {lay.entry_fmt!r} is "
                f"{struct.Struct(lay.entry_fmt).size} bytes, declared {lay.entry_bytes}"
            )
        if lay.magic is not None and len(lay.magic) != 8:
            raise AssertionError(f"layout {lay.name}: magic must be 8 bytes")


_selfcheck()

assert HEADER.head_bytes == 48
assert CHUNK_TABLE.head_bytes == 32 and CHUNK_TABLE.entry_bytes == 32
assert RASTATS.head_bytes == 40 and RASTATS.entry_bytes == 32
