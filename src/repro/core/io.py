"""RawArray read / write / memory-map.

Faithful to the paper: ``write`` emits header ++ raw bytes (++ optional
metadata); ``read`` parses the numeric header and hands back an ndarray;
``memmap`` maps the data segment directly (the format's linear up-front
layout makes this a single ``np.memmap`` with a computed offset).

Beyond-paper (flag-gated, backward compatible, DESIGN.md §7): optional CRC32
trailer, whole-file zlib payload compression, and — the fast compression
path — chunked compression (DESIGN.md §10): independently compressed chunks
plus a trailer chunk table, decoded chunk-parallel on the engine pool, with
partial reads touching only the chunks that overlap the request.

Large payloads (>= ``RA_IO_PARALLEL_MIN``) are read and written through the
slab-parallel engine (``repro.core.engine``, DESIGN.md §8); ``read_into``
streams a file into a caller-owned preallocated array with zero intermediate
copies.

Every read-side entry point also accepts ``http(s)://`` URLs and dispatches
to the remote data plane (``repro.remote``, DESIGN.md §9): the same header
decode and engine-planned slab reads, issued as parallel byte-range
requests. The URL may just as well point at a ``repro.fleet`` router
(DESIGN.md §14) — the consistent-hash edge tier speaks the same
byte-range dialect and serves the origin's ETag, so slab, span, and
gather waves run unchanged through the proxy.

The streaming ingest plane (DESIGN.md §11): ``RaWriter`` writes a file
incrementally — unknown leading dimension, row batches, chunk-parallel
compression as batches arrive, crash-safe temp-file + rename publish.
``write`` also accepts an ``http(s)://`` destination (one authenticated
PUT, server-side atomic publish); ``repro.remote.RemoteWriter`` is the
streaming equivalent. ``memmap``/``memmap_slice``/``append_metadata``
remain local-only and refuse URLs.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Any, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import codec as chunked_codec
from . import engine
from . import quant as quant_schema
from . import stats as stats_mod
from .header import Header, decode_header, read_header
from .spec import (
    FLAG_BIG_ENDIAN,
    FLAG_CHUNKED,
    FLAG_CRC32_TRAILER,
    FLAG_ZLIB,
    RawArrayError,
)

PathLike = Union[str, os.PathLike]

# Buffered single-syscall-ish writes: header+data concatenated when small,
# else two writes. Keeps the hot path syscall count minimal (paper's "Fast").
_SMALL = 1 << 20


def is_url(path: object) -> bool:
    """True for ``http(s)://`` paths served by the remote data plane."""
    return isinstance(path, str) and path.startswith(("http://", "https://"))


def join_path(base: str, name: str) -> str:
    """``os.path.join`` that also speaks URLs — the one helper every
    directory-shaped layout (sharded stores, datasets, checkpoints) uses to
    address its member files in both local and remote mode."""
    if is_url(base):
        from urllib.parse import quote

        return base.rstrip("/") + "/" + quote(name)
    return os.path.join(base, name)


def _remote():
    # deferred: repro.remote imports this module; function-local import
    # breaks the cycle and keeps purely-local workloads free of it
    from .. import remote

    return remote


def _reject_url(path: PathLike, op: str) -> None:
    if is_url(path):
        raise RawArrayError(f"{op} is local-only; cannot {op} a remote URL: {path}")


def _as_bytes_view(arr: np.ndarray) -> memoryview:
    """Zero-copy byte view of a contiguous array; copies only for dtypes that
    don't speak the buffer protocol (e.g. ml_dtypes bfloat16)."""
    if not arr.size:
        return memoryview(b"")
    try:
        return memoryview(arr).cast("B")
    except (ValueError, TypeError):
        return memoryview(arr.view(np.uint8).reshape(-1))


def write(
    path: PathLike,
    arr: np.ndarray,
    *,
    metadata: Optional[bytes] = None,
    big_endian: bool = False,
    crc32: bool = False,
    compress: bool = False,
    chunked: bool = False,
    codec: Optional[str] = None,
    chunk_bytes: Optional[int] = None,
    quantize: Optional[str] = None,
    stats: bool = False,
) -> int:
    """Write ``arr`` as a RawArray file. Returns bytes written.

    ``stats=True`` (DESIGN.md §16) additionally emits a ``rastats``
    block — per-chunk min/max/NaN-count/count — at the head of the
    trailing metadata region, enabling predicate pushdown
    (``RaDataset.select``) to prune chunks without touching the payload.
    Requires a bool/int/float dtype; for quantized files the statistics
    describe the STORED uint8 codes.

    ``quantize="u8"`` (DESIGN.md §12) stores a float array as uint8 codes
    with per-channel affine calibration over the last axis; the
    ``(scale, bias, orig_dtype)`` schema rides in the trailing user
    metadata (the paper's extension point), so ``read(..., dequantize=
    True)`` — or the on-device Pallas kernel — reconstructs the logical
    values while the wire/disk payload is 4× smaller than float32.
    A caller-supplied ``metadata`` must then be a JSON object (bytes or
    dict) for the quant schema to merge into.

    ``compress=True`` keeps the legacy whole-file zlib payload
    (``FLAG_ZLIB``: single-stream decode, no partial reads). ``chunked=True``
    — or simply passing ``codec=`` / ``chunk_bytes=`` — writes the payload as
    independently compressed chunks plus a trailer chunk table
    (``FLAG_CHUNKED``, DESIGN.md §10): compression runs chunk-parallel on
    the engine pool here, and every read path decodes only the chunks it
    needs. Defaults: codec ``RA_CODEC`` (zlib), chunk size ``RA_CHUNK_BYTES``
    (1 MiB).

    ``path`` may be an ``http(s)://`` URL of a write-enabled byte-range
    server (DESIGN.md §11): the identical bytes are shipped as ONE
    authenticated PUT with server-side atomic publish (token knob
    ``RA_REMOTE_TOKEN``). Incremental / unknown-length writes go through
    ``RaWriter`` (local) or ``repro.remote.RemoteWriter`` (URL) instead."""
    chunked = chunked or codec is not None or chunk_bytes is not None
    if compress and chunked:
        raise RawArrayError(
            "compress= (whole-file zlib) and chunked= are mutually exclusive"
        )
    if quantize is not None:
        if big_endian:
            raise RawArrayError("quantize= writes little-endian uint8 payloads only")
        info = quant_schema.quant_params(np.asarray(arr), mode=quantize)
        extra = None
        if metadata:
            if isinstance(metadata, dict):
                extra = metadata
            else:
                try:
                    extra = json.loads(metadata)
                except (TypeError, ValueError, UnicodeDecodeError):
                    extra = None
            if not isinstance(extra, dict):
                raise RawArrayError(
                    "quantize= stores its schema in JSON metadata; a "
                    "caller-supplied metadata blob must be a JSON object "
                    "(bytes or dict)"
                )
        arr = info.quantize(np.asarray(arr))
        metadata = info.encode(extra)
    orig_shape = np.asarray(arr).shape
    arr = np.ascontiguousarray(arr)  # NB: promotes 0-d to (1,)...
    arr = arr.reshape(orig_shape)    # ...so restore the true rank (ndims=0 is legal)
    flags = 0
    if big_endian:
        flags |= FLAG_BIG_ENDIAN
        arr = arr.astype(arr.dtype.newbyteorder(">"), copy=False)
    else:
        # normalize to little-endian on disk
        if arr.dtype.byteorder == ">":
            arr = arr.astype(arr.dtype.newbyteorder("<"), copy=False)
    payload = _as_bytes_view(arr)
    trailer_views: list = []  # chunk table, between payload and metadata
    table = None
    if chunked:
        flags |= FLAG_CHUNKED
        parts, table = chunked_codec.compress_chunked(
            payload, codec=codec, chunk_bytes=chunk_bytes
        )
        stored_views = [memoryview(p) for p in parts]
        trailer_views = [memoryview(table.encode())]
    elif compress:
        flags |= FLAG_ZLIB
        stored_views = [memoryview(zlib.compress(bytes(payload), level=1))]
    else:
        stored_views = [payload]
    if stats:
        if not stats_mod.stats_supported(arr.dtype):
            raise RawArrayError(f"stats=True unsupported for dtype {arr.dtype}")
        scb = table.chunk_bytes if table is not None \
            else chunked_codec.default_chunk_bytes()
        trailer_views.append(
            memoryview(stats_mod.compute_stats(arr, scb).encode()))
    if crc32:
        flags |= FLAG_CRC32_TRAILER
    data_length = sum(v.nbytes for v in stored_views)
    hdr = Header.for_array(arr, flags=flags, data_length=data_length)
    views = [memoryview(hdr.encode())] + stored_views + trailer_views
    if metadata:
        views.append(memoryview(metadata))
    if crc32:
        # file-level CRC of the stored data segment, always the last 4 bytes
        crc = 0
        for v in stored_views:
            crc = zlib.crc32(v, crc)
        views.append(memoryview(crc.to_bytes(4, "little")))
    total = sum(v.nbytes for v in views)
    if is_url(path):
        return _remote().upload_bytes(path, views)
    with open(os.fspath(path), "wb") as f:
        if total < _SMALL:
            buf = bytearray()
            for v in views:
                buf += v
            f.write(buf)
            return total
        os.ftruncate(f.fileno(), total)  # preallocate, then go wide (DESIGN.md §8)
        return engine.parallel_write(f.fileno(), 0, views)


class _FileSink:
    """Crash-safe local byte sink for ``RaWriter`` (DESIGN.md §11).

    Every byte lands in a hidden same-directory temp file; ``commit`` fsyncs
    and atomically renames it into place, so a crash at ANY point of a
    streamed write leaves no partial file visible under the final name.
    ``patch`` rewrites earlier bytes (the finalize header patch); ``abort``
    removes the temp file.
    """

    def __init__(self, path: PathLike):
        self.path = os.fspath(path)
        _reject_url(self.path, "RaWriter")  # URLs go through remote.RemoteWriter
        self._dir = os.path.dirname(self.path) or "."
        base = os.path.basename(self.path)
        self.tmp = os.path.join(
            self._dir, f".{base}.tmp-{os.getpid()}-{id(self) & 0xFFFF:04x}"
        )
        self.fd = os.open(self.tmp, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o666)
        self.size = 0

    def append(self, views: Sequence[object]) -> int:
        total = 0
        for v in views:
            mv = v if isinstance(v, memoryview) else memoryview(v)
            total += mv.nbytes
        if total >= engine.parallel_min():
            # preallocate the extension, then go slab-parallel (DESIGN.md §8)
            os.ftruncate(self.fd, self.size + total)
            engine.parallel_write(self.fd, self.size, views)
        else:
            pos = self.size
            for v in views:
                pos += engine.pwrite_from(self.fd, pos, v)
        self.size += total
        return total

    def patch(self, offset: int, data) -> None:
        engine.pwrite_from(self.fd, offset, data)

    def commit(self) -> None:
        os.fsync(self.fd)
        os.close(self.fd)
        self.fd = -1
        os.replace(self.tmp, self.path)
        try:  # make the rename itself durable (same contract as checkpoints)
            dfd = os.open(self._dir, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            pass  # directory fsync is best-effort (e.g. some network FS)

    def abort(self) -> None:
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1
        try:
            os.unlink(self.tmp)
        except OSError:
            pass


# Plain-payload writes buffer small row batches and flush in slabs of this
# many bytes, so a row-at-a-time ingest still writes in large sequential I/O.
_WRITER_BUF = 4 << 20


class RaWriter:
    """Incremental RawArray writer: the streaming ingest plane (DESIGN.md §11).

    Opens with an UNKNOWN leading dimension, accepts row batches of shape
    ``(n, *row_shape)`` via ``write_rows``, and on ``finalize`` patches
    ``dims[0]`` / ``data_length`` into the header, emits the chunk table
    (chunked mode), optional user metadata and optional CRC32 trailer, then
    atomically publishes the file (write-to-temp + rename) — a crash mid-
    stream leaves no partial file visible.

    The output is byte-identical to a monolithic ``write()`` of the same
    array for every supported flag combination: plain, ``crc32=True``, and
    ``chunked=True`` with any registered codec (chunk compression runs
    chunk-parallel on the engine pool AS BATCHES ARRIVE, so compression
    overlaps ingest). Whole-file zlib (``compress=``) is not streamable —
    use ``chunked`` (DESIGN.md §10).

    ``sink`` is the byte-sink escape hatch the remote plane plugs into
    (``repro.remote.RemoteWriter`` streams the same bytes as authenticated
    PUT appends); local callers never pass it.

    Usage::

        with RaWriter("out.ra", np.float32, (256,), chunked=True) as w:
            for batch in batches:          # (n, 256) float32 each
                w.write_rows(batch)
        # or explicitly: hdr = w.finalize(metadata=b"...")
    """

    def __init__(
        self,
        path: PathLike,
        dtype,
        row_shape: Tuple[int, ...] = (),
        *,
        crc32: bool = False,
        chunked: bool = False,
        codec: Optional[str] = None,
        chunk_bytes: Optional[int] = None,
        metadata: Optional[bytes] = None,
        stats: bool = False,
        sink=None,
    ):
        chunked = chunked or codec is not None or chunk_bytes is not None
        dt = np.dtype(dtype)
        if dt.byteorder == ">":
            raise RawArrayError("RaWriter writes little-endian files only")
        if stats and not stats_mod.stats_supported(dt):
            raise RawArrayError(f"stats=True unsupported for dtype {dt}")
        self._dtype = dt
        self._row_shape = tuple(int(d) for d in row_shape)
        self._row_nbytes = dt.itemsize
        for d in self._row_shape:
            self._row_nbytes *= d
        self._flags = 0
        if crc32:
            self._flags |= FLAG_CRC32_TRAILER
        if chunked:
            self._flags |= FLAG_CHUNKED
        self._crc32 = crc32
        self._metadata = metadata
        # prototype header (dims[0]=0, data_length=0): placeholder bytes now,
        # patched with the real values at finalize — the header size is fixed
        # because ndims is known up front
        proto = np.empty((0,) + self._row_shape, dtype=dt)
        self._hdr0 = Header.for_array(proto, flags=self._flags, data_length=0)
        self._compressor = (
            chunked_codec.ChunkStreamCompressor(
                codec=codec, chunk_bytes=chunk_bytes,
                stats_dtype=dt if stats else None,
            )
            if chunked
            else None
        )
        # plain mode computes stats itself (write_rows); chunked mode lets
        # the stream compressor accumulate them as chunks form (DESIGN.md §16)
        self._stats_acc = (
            stats_mod.StatsAccumulator(dt, chunked_codec.default_chunk_bytes())
            if stats and not chunked
            else None
        )
        self._buf = bytearray()  # plain mode: pending raw bytes, flushed in slabs
        self._rows = 0
        self._payload_nbytes = 0  # stored bytes appended so far
        self._crc = 0
        self._state = "open"
        self._sink = _FileSink(path) if sink is None else sink
        self._sink.append([memoryview(self._hdr0.encode())])

    # ---- introspection -----------------------------------------------------
    @property
    def rows(self) -> int:
        """Rows written so far (the eventual ``dims[0]``)."""
        return self._rows

    @property
    def stored_nbytes(self) -> int:
        """Stored payload bytes appended to the sink so far (compressed size
        in chunked mode; excludes buffered not-yet-flushed bytes)."""
        return self._payload_nbytes

    # ---- write path --------------------------------------------------------
    def _append_payload(self, view) -> None:
        """Append stored payload bytes, folding them into the file-level CRC
        (which covers the STORED data segment, exactly like ``write()``)."""
        if self._crc32:
            self._crc = zlib.crc32(view, self._crc)
        self._sink.append([view])
        mv = view if isinstance(view, memoryview) else memoryview(view)
        self._payload_nbytes += mv.nbytes

    def write_rows(self, rows) -> int:
        """Append a batch shaped ``(n, *row_shape)``; returns total rows so
        far. Rows are cast to the writer's dtype (same semantics as the
        dataset writer) and must be batched — a single row is ``rows[None]``."""
        if self._state != "open":
            raise RawArrayError(f"write_rows on a {self._state} RaWriter")
        a = np.asarray(rows)
        if a.shape[1:] != self._row_shape:
            raise RawArrayError(
                f"write_rows: batch row shape {a.shape[1:]} != writer row "
                f"shape {self._row_shape}"
            )
        a = np.ascontiguousarray(a.astype(self._dtype, copy=False))
        n = a.shape[0]
        if n == 0 or self._row_nbytes == 0:
            self._rows += n
            return self._rows
        view = _as_bytes_view(a)
        if self._stats_acc is not None:
            self._stats_acc.add(a)
        if self._compressor is not None:
            for part in self._compressor.feed(view):
                self._append_payload(part)
        elif view.nbytes >= _WRITER_BUF:
            # large batch: flush any buffered tail, then write the caller's
            # bytes straight through — never stage a big batch in the buffer
            if self._buf:
                self._append_payload(memoryview(self._buf))
                self._buf = bytearray()
            self._append_payload(view)
        else:
            self._buf += view
            if len(self._buf) >= _WRITER_BUF:
                self._append_payload(memoryview(self._buf))
                self._buf = bytearray()
        self._rows += n
        return self._rows

    # ---- lifecycle ---------------------------------------------------------
    def finalize(self, metadata: Optional[bytes] = None) -> Header:
        """Flush everything, emit trailers, patch the header, publish.

        Order (DESIGN.md §11): final short chunk → chunk table → ``rastats``
        block (``stats=True``, DESIGN.md §16) → metadata →
        CRC trailer → header patch (``dims[0]``, ``data_length``) → durable
        commit (fsync + atomic rename). Returns the final ``Header``.
        Calling it twice — or after ``abort`` — raises."""
        if self._state != "open":
            raise RawArrayError(f"finalize on a {self._state} RaWriter")
        meta = self._metadata if metadata is None else metadata
        if self._buf:
            self._append_payload(memoryview(self._buf))
            self._buf = bytearray()
        tail: List[memoryview] = []
        stats_block = None
        if self._compressor is not None:
            for part in self._compressor.flush():
                self._append_payload(part)
            tail.append(memoryview(self._compressor.table().encode()))
            cstats = self._compressor.chunk_stats()
            if cstats is not None:
                stats_block = cstats.encode()
        elif self._stats_acc is not None:
            stats_block = self._stats_acc.finish().encode()
        if stats_block is not None:
            tail.append(memoryview(stats_block))
        if meta:
            tail.append(memoryview(meta))
        if self._crc32:
            tail.append(memoryview(self._crc.to_bytes(4, "little")))
        if tail:
            self._sink.append(tail)
        hdr = Header(
            flags=self._flags,
            eltype=self._hdr0.eltype,
            elbyte=self._hdr0.elbyte,
            data_length=self._payload_nbytes,
            shape=(self._rows,) + self._row_shape,
        )
        self._sink.patch(0, memoryview(hdr.encode()))
        self._sink.commit()
        self._state = "finalized"
        return hdr

    def abort(self) -> None:
        """Drop the in-progress write: the temp file (or remote ``.part``)
        is deleted and the final path is never touched. Idempotent; a
        finalized writer cannot be aborted."""
        if self._state == "open":
            self._state = "aborted"
            self._sink.abort()

    def __enter__(self) -> "RaWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        elif self._state == "open":
            self.finalize()

    def __del__(self):  # a dropped writer must not leak its fd / temp file
        try:
            self.abort()
        except Exception:
            pass


def read(
    path: PathLike,
    *,
    with_metadata: bool = False,
    strict_flags: bool = True,
    dequantize: bool = False,
) -> Union[np.ndarray, Tuple[np.ndarray, bytes]]:
    """Read a RawArray file into an ndarray (native little-endian in memory).

    Fast path: plain little-endian payload with no trailer reads the header
    from one small syscall and ``readinto``s the payload DIRECTLY into the
    output array (zero intermediate copy — what the C reference does with
    fread into malloc'd memory).

    ``dequantize=True`` reconstructs the logical float values of a file
    written with ``quantize=`` (DESIGN.md §12) from its uint8 codes and the
    typed quant metadata; files without quant metadata pass through
    unchanged."""
    if dequantize:
        arr, meta = read(path, with_metadata=True, strict_flags=strict_flags)
        info = quant_schema.decode_quant_metadata(meta)
        if info is not None:
            arr = info.dequantize(arr)
        return (arr, meta) if with_metadata else arr
    if is_url(path):
        return _remote().remote_read(
            path, with_metadata=with_metadata, strict_flags=strict_flags
        )
    with open(path, "rb", buffering=0) as f:
        head = f.read(4096)
        hdr = decode_header(head, strict_flags=strict_flags)
        if hdr.flags & FLAG_CHUNKED:
            return read_chunked(
                f.fileno(), hdr,
                size=os.fstat(f.fileno()).st_size,
                with_metadata=with_metadata,
            )
        if hdr.plain and not with_metadata:
            out = np.empty(hdr.shape, dtype=hdr.dtype())
            if hdr.data_length == 0:
                return out
            mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
            if hdr.data_length >= engine.parallel_min():
                # big payload: slab-parallel preads straight into the output
                engine.parallel_read_into(f.fileno(), hdr.nbytes, mv)
                return out
            inline = head[hdr.nbytes : hdr.nbytes + hdr.data_length]
            mv[: len(inline)] = inline
            got = len(inline)
            while got < hdr.data_length:
                n = f.readinto(mv[got:])
                if not n:
                    raise RawArrayError(
                        f"truncated data segment: wanted {hdr.data_length}, got {got}"
                    )
                got += n
            return out
        rest = f.read()
        blob = head + rest
        payload = blob[hdr.nbytes : hdr.nbytes + hdr.data_length]
        if len(payload) != hdr.data_length:
            raise RawArrayError(
                f"truncated data segment: wanted {hdr.data_length}, got {len(payload)}"
            )
        trailer = blob[hdr.nbytes + hdr.data_length :]
    meta = trailer
    if hdr.flags & FLAG_CRC32_TRAILER:
        if len(trailer) < 4:
            raise RawArrayError("CRC flag set but trailer missing")
        meta, crc = trailer[:-4], int.from_bytes(trailer[-4:], "little")
        if zlib.crc32(payload) != crc:
            raise RawArrayError("CRC32 mismatch: data segment corrupted")
    if hdr.flags & FLAG_ZLIB:
        payload = zlib.decompress(payload)
        if len(payload) != hdr.logical_nbytes:
            raise RawArrayError(
                f"decompressed payload is {len(payload)} bytes, header shape "
                f"{hdr.shape} x elbyte={hdr.elbyte} wants {hdr.logical_nbytes}"
            )
    dtype = hdr.dtype()
    arr = np.frombuffer(payload, dtype=dtype)
    if hdr.big_endian:
        arr = arr.astype(dtype.newbyteorder("<"))
    arr = arr.reshape(hdr.shape)
    if with_metadata:
        # the rastats block rides at the head of the metadata region; user
        # metadata is what follows it (DESIGN.md §16)
        return arr, stats_mod.split_stats(meta)[1]
    return arr


def read_chunked(
    src,
    hdr: Header,
    *,
    size: int,
    with_metadata: bool = False,
) -> Union[np.ndarray, Tuple[np.ndarray, bytes]]:
    """Decode a ``FLAG_CHUNKED`` payload from any positioned-read source
    (int fd or ``RemoteReader``): read the trailer chunk table (two small
    reads), then fetch + CRC-check + decompress every chunk concurrently on
    the engine pool, each straight into its slice of the output array.

    Integrity comes from the per-chunk CRC32s (checked on every decode);
    the optional file-level CRC trailer is rechecked by ``racat verify``."""
    table = chunked_codec.read_table(src, hdr)
    out = np.empty(hdr.shape, hdr.dtype())
    if hdr.logical_nbytes:
        mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
        chunked_codec.decompress_into(src, hdr, table, mv)
    if hdr.big_endian:
        out = out.astype(hdr.dtype().newbyteorder("<"))
    if not with_metadata:
        return out
    start = hdr.nbytes + hdr.data_length + table.nbytes
    tail = bytearray(max(0, size - start))
    if tail:
        engine.pread_into(src, start, tail)
    meta = bytes(tail)
    if hdr.flags & FLAG_CRC32_TRAILER:
        if len(meta) < 4:
            raise RawArrayError("CRC flag set but trailer missing")
        meta = meta[:-4]
    return out, stats_mod.split_stats(meta)[1]


def _zlib_decompress_into(fd: int, hdr: Header, mv: memoryview, file_size: int) -> None:
    """Stream-decompress a whole-file zlib payload directly into the
    caller's buffer (no intermediate payload-sized allocation), verifying
    the file-level CRC trailer incrementally when present."""
    d = zlib.decompressobj()
    off, end = hdr.nbytes, hdr.nbytes + hdr.data_length
    pos = 0
    crc = 0
    buf = bytearray(min(1 << 20, max(1, hdr.data_length)))
    while off < end:
        n = min(len(buf), end - off)
        piece = memoryview(buf)[:n]
        engine.pread_into(fd, off, piece)
        off += n
        if hdr.flags & FLAG_CRC32_TRAILER:
            crc = zlib.crc32(piece, crc)
        raw = d.decompress(piece)
        if pos + len(raw) > mv.nbytes:
            raise RawArrayError(
                f"decompressed payload exceeds {mv.nbytes} bytes, header shape "
                f"{hdr.shape} x elbyte={hdr.elbyte}"
            )
        mv[pos : pos + len(raw)] = raw
        pos += len(raw)
    raw = d.flush()
    if pos + len(raw) > mv.nbytes:
        raise RawArrayError(
            f"decompressed payload exceeds {mv.nbytes} bytes, header shape "
            f"{hdr.shape} x elbyte={hdr.elbyte}"
        )
    mv[pos : pos + len(raw)] = raw
    pos += len(raw)
    if pos != hdr.logical_nbytes:
        raise RawArrayError(
            f"decompressed payload is {pos} bytes, header shape "
            f"{hdr.shape} x elbyte={hdr.elbyte} wants {hdr.logical_nbytes}"
        )
    if hdr.flags & FLAG_CRC32_TRAILER:
        if file_size < end + 4:
            raise RawArrayError("CRC flag set but trailer missing")
        stored = bytearray(4)
        engine.pread_into(fd, file_size - 4, stored)
        if int.from_bytes(stored, "little") != crc:
            raise RawArrayError("CRC32 mismatch: data segment corrupted")


def read_into(path: PathLike, out: np.ndarray) -> np.ndarray:
    """Read a RawArray file's payload straight into a preallocated array.

    ``out`` must be C-contiguous with the file's exact shape and dtype. This
    is the zero-copy restore primitive (DESIGN.md §8): the destination can be
    a reused (already page-faulted) buffer, a pinned host buffer, or one slab
    of a larger batch array — no intermediate allocation is made, and large
    payloads are read with slab-parallel preads.

    Compressed payloads honor ``out=`` too: chunked files decompress
    chunk-parallel straight into the caller's buffer, whole-file zlib
    streams through ``decompressobj`` into it. Only big-endian payloads
    fall back to ``read`` + one converting copy.
    """
    if is_url(path):
        return _remote().remote_read_into(path, out)
    with open(path, "rb", buffering=0) as f:
        head = f.read(4096)
        hdr = decode_header(head)
        if tuple(out.shape) != hdr.shape:
            raise RawArrayError(f"read_into: out.shape {out.shape} != file {hdr.shape}")
        # byte-order-insensitive: a big-endian payload lands in a native out
        # via the read() fallback below
        if out.dtype != hdr.dtype().newbyteorder("="):
            raise RawArrayError(f"read_into: out.dtype {out.dtype} != file {hdr.dtype()}")
        if not out.flags.c_contiguous:
            raise RawArrayError("read_into: out must be C-contiguous")
        if not hdr.big_endian:
            mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
            if hdr.flags & FLAG_CHUNKED:
                table = chunked_codec.read_table(f.fileno(), hdr)
                if hdr.logical_nbytes:
                    chunked_codec.decompress_into(f.fileno(), hdr, table, mv)
                return out
            if hdr.flags & FLAG_ZLIB:
                _zlib_decompress_into(
                    f.fileno(), hdr, mv, os.fstat(f.fileno()).st_size
                )
                return out
            if not (hdr.flags & FLAG_CRC32_TRAILER):
                if hdr.data_length:
                    engine.parallel_read_into(f.fileno(), hdr.nbytes, mv)
                return out
    out[...] = read(path)
    return out


def read_metadata(path: PathLike) -> bytes:
    """Read only the trailing user metadata (cheap: header + seek; for URLs
    a header fetch + one tail range request)."""
    if is_url(path):
        return _remote().remote_read_metadata(path)
    with open(path, "rb") as f:
        hdr = read_header(f)
        off = hdr.nbytes + hdr.data_length
        if hdr.flags & FLAG_CHUNKED:
            off += chunked_codec.table_nbytes(f.fileno(), hdr)
        f.seek(off)
        tail = f.read()
    if hdr.flags & FLAG_CRC32_TRAILER:
        tail = tail[:-4]
    return stats_mod.split_stats(tail)[1]


def _read_stats_src(src, hdr: Header, *, size: int,
                    table_nbytes: Optional[int] = None):
    """Decode the ``rastats`` block from a positioned-read source (int fd
    or ``RemoteReader``) with two small tail reads — the payload is never
    touched (DESIGN.md §16). Returns ``ChunkStats`` or ``None``."""
    if table_nbytes is None:
        table_nbytes = (
            chunked_codec.table_nbytes(src, hdr)
            if hdr.flags & FLAG_CHUNKED
            else 0
        )
    start = hdr.nbytes + hdr.data_length + table_nbytes
    end = size - (4 if hdr.flags & FLAG_CRC32_TRAILER else 0)
    avail = end - start
    if avail < stats_mod.HEAD_BYTES:
        return None
    head = bytearray(stats_mod.HEAD_BYTES)
    engine.pread_into(src, start, head)
    if not bytes(head).startswith(stats_mod.RASTATS_MAGIC_BYTES):
        return None
    block_bytes = int.from_bytes(head[16:24], "little")
    block = bytearray(min(max(block_bytes, stats_mod.HEAD_BYTES), avail))
    block[: len(head)] = head
    if len(block) > len(head):
        engine.pread_into(src, start + len(head), memoryview(block)[len(head):])
    return stats_mod.split_stats(bytes(block))[0]


def read_stats(path: PathLike):
    """Read only the per-chunk statistics block (DESIGN.md §16).

    Cheap for both local files (header + two tail reads) and
    ``http(s)://`` URLs (header fast path + tail ranges, never the
    payload). Returns :class:`repro.core.stats.ChunkStats`, or ``None``
    for files without a (valid) ``rastats`` block — corrupt blocks warn
    and return ``None`` so callers degrade to a full scan."""
    if is_url(path):
        return _remote().remote_read_stats(path)
    with open(path, "rb") as f:
        hdr = read_header(f)
        return _read_stats_src(
            f.fileno(), hdr, size=os.fstat(f.fileno()).st_size
        )


def read_quant_metadata(path: PathLike):
    """Typed view of a file's quantization schema (DESIGN.md §12): the
    ``QuantInfo`` decoded from the trailing metadata, or ``None`` when the
    file carries no ``"ra_quant"`` schema. Works locally and over URLs
    (one header fetch + one tail range)."""
    return quant_schema.decode_quant_metadata(read_metadata(path))


def header_of(path: PathLike) -> Header:
    if is_url(path):
        return _remote().remote_header_of(path)
    with open(path, "rb") as f:
        return read_header(f)


def memmap(path: PathLike, mode: str = "r") -> np.ndarray:
    """Memory-map the data segment (zero-copy, the format's raison d'etre).

    Raises for compressed or big-endian payloads (not mappable in-place).
    """
    _reject_url(path, "memmap")
    with open(path, "rb") as f:
        hdr = read_header(f)
    if hdr.compressed:
        raise RawArrayError("cannot memory-map a compressed payload")
    if hdr.big_endian:
        raise RawArrayError("cannot memory-map a big-endian payload on LE host")
    if hdr.shape == ():  # np.memmap coerces 0-d to (1,); reshape it back
        m = np.memmap(path, dtype=hdr.dtype(), mode=mode, offset=hdr.nbytes, shape=(1,))
        return m.reshape(())
    return np.memmap(path, dtype=hdr.dtype(), mode=mode, offset=hdr.nbytes, shape=hdr.shape)


def memmap_slice(path: PathLike, start: int, stop: int, mode: str = "r") -> np.ndarray:
    """Map only rows [start, stop) of axis 0 — the multi-host shard read.

    Because the layout is linear with a fixed-size numeric header, the byte
    range of a row slab is pure offset arithmetic; each host touches only
    its pages.
    """
    _reject_url(path, "memmap")
    with open(path, "rb") as f:
        hdr = read_header(f)
    if hdr.compressed:
        raise RawArrayError("cannot memory-map a compressed payload")
    if not hdr.shape:
        raise RawArrayError("cannot row-slice a 0-d array")
    n = hdr.shape[0]
    start, stop = max(0, start), min(stop, n)
    if stop < start:
        raise RawArrayError(f"bad slice [{start}, {stop})")
    row = hdr.elbyte
    for d in hdr.shape[1:]:
        row *= d
    return np.memmap(
        path,
        dtype=hdr.dtype(),
        mode=mode,
        offset=hdr.nbytes + start * row,
        shape=(stop - start,) + hdr.shape[1:],
    )


def append_metadata(path: PathLike, metadata: bytes) -> None:
    """Append user metadata to an existing file (paper: 'can be anything').

    On a CRC-trailed file the 4-byte CRC must stay the *last* bytes of the
    file (that is where every reader splits metadata from checksum), so the
    metadata is spliced in front of it: naively appending after the trailer
    would make readers treat the tail of the new metadata as the checksum
    and fail — or worse, silently mis-verify."""
    _reject_url(path, "append_metadata")
    hdr = header_of(path)
    if not (hdr.flags & FLAG_CRC32_TRAILER):
        with open(path, "ab") as f:
            f.write(metadata)
        return
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        if size < hdr.nbytes + hdr.data_length + 4:
            raise RawArrayError("CRC flag set but trailer missing")
        f.seek(size - 4)
        crc = f.read(4)
        f.seek(size - 4)
        # one write, not two: a crash between "overwrite CRC with metadata"
        # and "re-append CRC" would leave the file permanently mis-trailed
        f.write(bytes(metadata) + crc)


def write_like(path: PathLike, header: Header, payload: bytes) -> None:
    """Low-level escape hatch: write an explicit header + raw payload."""
    with open(path, "wb") as f:
        f.write(header.encode())
        f.write(payload)


def nbytes_on_disk(arr_or_shape: Any, dtype: Optional[np.dtype] = None) -> int:
    """Predict file size for an array (header + data, no metadata)."""
    if isinstance(arr_or_shape, np.ndarray):
        shape, itemsize = arr_or_shape.shape, arr_or_shape.dtype.itemsize
    else:
        shape, itemsize = tuple(arr_or_shape), np.dtype(dtype).itemsize
    n = itemsize
    for d in shape:
        n *= d
    return 48 + 8 * len(shape) + n
