"""RawArray read / write / memory-map.

Faithful to the paper: ``write`` emits header ++ raw bytes (++ optional
metadata); ``read`` parses the numeric header and hands back an ndarray;
``memmap`` maps the data segment directly (the format's linear up-front
layout makes this a single ``np.memmap`` with a computed offset).

Beyond-paper (flag-gated, backward compatible, DESIGN.md §7): optional CRC32
trailer and zlib payload compression.

Large payloads (>= ``RA_IO_PARALLEL_MIN``) are read and written through the
slab-parallel engine (``repro.core.engine``, DESIGN.md §8); ``read_into``
streams a file into a caller-owned preallocated array with zero intermediate
copies.

Every read-side entry point also accepts ``http(s)://`` URLs and dispatches
to the remote data plane (``repro.remote``, DESIGN.md §9): the same header
decode and engine-planned slab reads, issued as parallel byte-range
requests. Write-side and mmap entry points are local-only and refuse URLs.
"""

from __future__ import annotations

import os
import zlib
from typing import Any, Optional, Tuple, Union

import numpy as np

from . import engine
from .header import Header, decode_header, read_header
from .spec import FLAG_BIG_ENDIAN, FLAG_CRC32_TRAILER, FLAG_ZLIB, RawArrayError

PathLike = Union[str, os.PathLike]

# Buffered single-syscall-ish writes: header+data concatenated when small,
# else two writes. Keeps the hot path syscall count minimal (paper's "Fast").
_SMALL = 1 << 20


def is_url(path: object) -> bool:
    """True for ``http(s)://`` paths served by the remote data plane."""
    return isinstance(path, str) and path.startswith(("http://", "https://"))


def join_path(base: str, name: str) -> str:
    """``os.path.join`` that also speaks URLs — the one helper every
    directory-shaped layout (sharded stores, datasets, checkpoints) uses to
    address its member files in both local and remote mode."""
    if is_url(base):
        from urllib.parse import quote

        return base.rstrip("/") + "/" + quote(name)
    return os.path.join(base, name)


def _remote():
    # deferred: repro.remote imports this module; function-local import
    # breaks the cycle and keeps purely-local workloads free of it
    from .. import remote

    return remote


def _reject_url(path: PathLike, op: str) -> None:
    if is_url(path):
        raise RawArrayError(f"{op} is local-only; cannot {op} a remote URL: {path}")


def _as_bytes_view(arr: np.ndarray) -> memoryview:
    """Zero-copy byte view of a contiguous array; copies only for dtypes that
    don't speak the buffer protocol (e.g. ml_dtypes bfloat16)."""
    if not arr.size:
        return memoryview(b"")
    try:
        return memoryview(arr).cast("B")
    except (ValueError, TypeError):
        return memoryview(arr.view(np.uint8).reshape(-1))


def write(
    path: PathLike,
    arr: np.ndarray,
    *,
    metadata: Optional[bytes] = None,
    big_endian: bool = False,
    crc32: bool = False,
    compress: bool = False,
) -> int:
    """Write ``arr`` as a RawArray file. Returns bytes written."""
    _reject_url(path, "write")
    orig_shape = np.asarray(arr).shape
    arr = np.ascontiguousarray(arr)  # NB: promotes 0-d to (1,)...
    arr = arr.reshape(orig_shape)    # ...so restore the true rank (ndims=0 is legal)
    flags = 0
    if big_endian:
        flags |= FLAG_BIG_ENDIAN
        arr = arr.astype(arr.dtype.newbyteorder(">"), copy=False)
    else:
        # normalize to little-endian on disk
        if arr.dtype.byteorder == ">":
            arr = arr.astype(arr.dtype.newbyteorder("<"), copy=False)
    payload = _as_bytes_view(arr)
    if compress:
        flags |= FLAG_ZLIB
        payload = memoryview(zlib.compress(bytes(payload), level=1))
    if crc32:
        flags |= FLAG_CRC32_TRAILER
    hdr = Header.for_array(arr, flags=flags, data_length=len(payload))
    head = hdr.encode()
    tmp = os.fspath(path)
    with open(tmp, "wb") as f:
        if len(payload) < _SMALL:
            buf = bytearray(head)
            buf += payload
            if metadata:
                buf += metadata
            if crc32:
                buf += zlib.crc32(payload).to_bytes(4, "little")
            f.write(buf)
            return len(buf)
        views = [memoryview(head), payload]
        if metadata:
            views.append(memoryview(metadata))
        if crc32:
            views.append(memoryview(zlib.crc32(payload).to_bytes(4, "little")))
        total = sum(v.nbytes for v in views)
        os.ftruncate(f.fileno(), total)  # preallocate, then go wide (DESIGN.md §8)
        return engine.parallel_write(f.fileno(), 0, views)


def read(
    path: PathLike,
    *,
    with_metadata: bool = False,
    strict_flags: bool = True,
) -> Union[np.ndarray, Tuple[np.ndarray, bytes]]:
    """Read a RawArray file into an ndarray (native little-endian in memory).

    Fast path: plain little-endian payload with no trailer reads the header
    from one small syscall and ``readinto``s the payload DIRECTLY into the
    output array (zero intermediate copy — what the C reference does with
    fread into malloc'd memory)."""
    if is_url(path):
        return _remote().remote_read(
            path, with_metadata=with_metadata, strict_flags=strict_flags
        )
    with open(path, "rb", buffering=0) as f:
        head = f.read(4096)
        hdr = decode_header(head, strict_flags=strict_flags)
        plain = not (hdr.flags & (FLAG_ZLIB | FLAG_CRC32_TRAILER)) and not hdr.big_endian
        if plain and not with_metadata:
            out = np.empty(hdr.shape, dtype=hdr.dtype())
            if hdr.data_length == 0:
                return out
            mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
            if hdr.data_length >= engine.parallel_min():
                # big payload: slab-parallel preads straight into the output
                engine.parallel_read_into(f.fileno(), hdr.nbytes, mv)
                return out
            inline = head[hdr.nbytes : hdr.nbytes + hdr.data_length]
            mv[: len(inline)] = inline
            got = len(inline)
            while got < hdr.data_length:
                n = f.readinto(mv[got:])
                if not n:
                    raise RawArrayError(
                        f"truncated data segment: wanted {hdr.data_length}, got {got}"
                    )
                got += n
            return out
        rest = f.read()
        blob = head + rest
        payload = blob[hdr.nbytes : hdr.nbytes + hdr.data_length]
        if len(payload) != hdr.data_length:
            raise RawArrayError(
                f"truncated data segment: wanted {hdr.data_length}, got {len(payload)}"
            )
        trailer = blob[hdr.nbytes + hdr.data_length :]
    meta = trailer
    if hdr.flags & FLAG_CRC32_TRAILER:
        if len(trailer) < 4:
            raise RawArrayError("CRC flag set but trailer missing")
        meta, crc = trailer[:-4], int.from_bytes(trailer[-4:], "little")
        if zlib.crc32(payload) != crc:
            raise RawArrayError("CRC32 mismatch: data segment corrupted")
    if hdr.flags & FLAG_ZLIB:
        payload = zlib.decompress(payload)
        if len(payload) != hdr.logical_nbytes:
            raise RawArrayError(
                f"decompressed payload is {len(payload)} bytes, header shape "
                f"{hdr.shape} x elbyte={hdr.elbyte} wants {hdr.logical_nbytes}"
            )
    dtype = hdr.dtype()
    arr = np.frombuffer(payload, dtype=dtype)
    if hdr.big_endian:
        arr = arr.astype(dtype.newbyteorder("<"))
    arr = arr.reshape(hdr.shape)
    if with_metadata:
        return arr, meta
    return arr


def read_into(path: PathLike, out: np.ndarray) -> np.ndarray:
    """Read a RawArray file's payload straight into a preallocated array.

    ``out`` must be C-contiguous with the file's exact shape and dtype. This
    is the zero-copy restore primitive (DESIGN.md §8): the destination can be
    a reused (already page-faulted) buffer, a pinned host buffer, or one slab
    of a larger batch array — no intermediate allocation is made, and large
    payloads are read with slab-parallel preads.

    Compressed / big-endian / CRC-trailed payloads fall back to ``read`` +
    one copy (they cannot be streamed in place).
    """
    if is_url(path):
        return _remote().remote_read_into(path, out)
    with open(path, "rb", buffering=0) as f:
        head = f.read(4096)
        hdr = decode_header(head)
        if tuple(out.shape) != hdr.shape:
            raise RawArrayError(f"read_into: out.shape {out.shape} != file {hdr.shape}")
        # byte-order-insensitive: a big-endian payload lands in a native out
        # via the read() fallback below
        if out.dtype != hdr.dtype().newbyteorder("="):
            raise RawArrayError(f"read_into: out.dtype {out.dtype} != file {hdr.dtype()}")
        if not out.flags.c_contiguous:
            raise RawArrayError("read_into: out must be C-contiguous")
        plain = not (hdr.flags & (FLAG_ZLIB | FLAG_CRC32_TRAILER)) and not hdr.big_endian
        if plain:
            if hdr.data_length:
                mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
                engine.parallel_read_into(f.fileno(), hdr.nbytes, mv)
            return out
    out[...] = read(path)
    return out


def read_metadata(path: PathLike) -> bytes:
    """Read only the trailing user metadata (cheap: header + seek; for URLs
    a header fetch + one tail range request)."""
    if is_url(path):
        return _remote().remote_read_metadata(path)
    with open(path, "rb") as f:
        hdr = read_header(f)
        f.seek(hdr.nbytes + hdr.data_length)
        tail = f.read()
    if hdr.flags & FLAG_CRC32_TRAILER:
        tail = tail[:-4]
    return tail


def header_of(path: PathLike) -> Header:
    if is_url(path):
        return _remote().remote_header_of(path)
    with open(path, "rb") as f:
        return read_header(f)


def memmap(path: PathLike, mode: str = "r") -> np.ndarray:
    """Memory-map the data segment (zero-copy, the format's raison d'etre).

    Raises for compressed or big-endian payloads (not mappable in-place).
    """
    _reject_url(path, "memmap")
    with open(path, "rb") as f:
        hdr = read_header(f)
    if hdr.flags & FLAG_ZLIB:
        raise RawArrayError("cannot memory-map a compressed payload")
    if hdr.big_endian:
        raise RawArrayError("cannot memory-map a big-endian payload on LE host")
    if hdr.shape == ():  # np.memmap coerces 0-d to (1,); reshape it back
        m = np.memmap(path, dtype=hdr.dtype(), mode=mode, offset=hdr.nbytes, shape=(1,))
        return m.reshape(())
    return np.memmap(path, dtype=hdr.dtype(), mode=mode, offset=hdr.nbytes, shape=hdr.shape)


def memmap_slice(path: PathLike, start: int, stop: int, mode: str = "r") -> np.ndarray:
    """Map only rows [start, stop) of axis 0 — the multi-host shard read.

    Because the layout is linear with a fixed-size numeric header, the byte
    range of a row slab is pure offset arithmetic; each host touches only
    its pages.
    """
    _reject_url(path, "memmap")
    with open(path, "rb") as f:
        hdr = read_header(f)
    if hdr.flags & FLAG_ZLIB:
        raise RawArrayError("cannot memory-map a compressed payload")
    if not hdr.shape:
        raise RawArrayError("cannot row-slice a 0-d array")
    n = hdr.shape[0]
    start, stop = max(0, start), min(stop, n)
    if stop < start:
        raise RawArrayError(f"bad slice [{start}, {stop})")
    row = hdr.elbyte
    for d in hdr.shape[1:]:
        row *= d
    return np.memmap(
        path,
        dtype=hdr.dtype(),
        mode=mode,
        offset=hdr.nbytes + start * row,
        shape=(stop - start,) + hdr.shape[1:],
    )


def append_metadata(path: PathLike, metadata: bytes) -> None:
    """Append user metadata to an existing file (paper: 'can be anything')."""
    _reject_url(path, "append_metadata")
    hdr = header_of(path)
    if hdr.flags & FLAG_CRC32_TRAILER:
        raise RawArrayError("append to CRC-trailed file would corrupt the trailer")
    with open(path, "ab") as f:
        f.write(metadata)


def write_like(path: PathLike, header: Header, payload: bytes) -> None:
    """Low-level escape hatch: write an explicit header + raw payload."""
    with open(path, "wb") as f:
        f.write(header.encode())
        f.write(payload)


def nbytes_on_disk(arr_or_shape: Any, dtype: Optional[np.dtype] = None) -> int:
    """Predict file size for an array (header + data, no metadata)."""
    if isinstance(arr_or_shape, np.ndarray):
        shape, itemsize = arr_or_shape.shape, arr_or_shape.dtype.itemsize
    else:
        shape, itemsize = tuple(arr_or_shape), np.dtype(dtype).itemsize
    n = itemsize
    for d in shape:
        n *= d
    return 48 + 8 * len(shape) + n
