"""RawArray on-disk format constants (paper Table 1 & 2; DESIGN.md §1, flag bits §7).

The file is a simple concatenation::

    u64 magic            "rawarray" as little-endian ASCII = 0x7961727261776172
    u64 flags            bit field (bit0 = big-endian payload)
    u64 eltype           element *kind* code (Table 2)
    u64 elbyte           element size in bytes
    u64 data_length      total payload bytes (redundant sanity check)
    u64 ndims            number of dimensions
    u64 dims[ndims]      shape vector
    u8  data[data_length]
    u8  metadata[...]    optional trailing user metadata (anything)

Everything before ``data`` is unsigned 64-bit little-endian integers, so the
header is introspectable with ``od -t u8`` (see ``repro.core.racat``).
"""

from __future__ import annotations

import os

from . import layouts


def env_int(name: str, default: int) -> int:
    """Integer env knob, read at call time; malformed/unset falls back."""
    try:
        return int(os.environ.get(name, ""))
    except ValueError:
        return default


def env_float(name: str, default: float) -> float:
    """Float env knob, read at call time; malformed/unset falls back."""
    try:
        return float(os.environ.get(name, ""))
    except ValueError:
        return default


def env_str(name: str, default: str = "") -> str:
    """String env knob, read at call time; unset/empty falls back.

    Every ``RA_*`` environment read in the tree goes through one of the
    ``env_*`` helpers — ralint's env-knob rule rejects raw ``os.environ``
    access elsewhere, and ``tools/check_docs.py`` cross-checks the knob
    names against the README table.
    """
    v = os.environ.get(name, "")
    return v if v else default

# ASCII of "rawarray" read as a little-endian u64. The byte sequence on disk
# is literally the string b"rawarray".
MAGIC: int = layouts.HEADER.magic_int
assert MAGIC == 0x7961727261776172

MAGIC_BYTES: bytes = layouts.HEADER.magic

# --- header geometry -------------------------------------------------------
# Derived from the single layout registry (core/layouts.py): the fixed head is
# magic, flags, eltype, elbyte, dlen, ndims — six little-endian u64s.
U64 = layouts.U64.head_struct
FIXED_HEADER = layouts.HEADER.head_struct
FIXED_HEADER_BYTES = layouts.HEADER.head_bytes  # 48
assert FIXED_HEADER_BYTES == 48


def header_nbytes(ndims: int) -> int:
    """Total header size for an array of ``ndims`` dimensions."""
    return FIXED_HEADER_BYTES + 8 * ndims


# --- element type codes (paper Table 2) -------------------------------------
ELTYPE_STRUCT = 0    # user-defined struct / opaque records
ELTYPE_INT = 1       # signed integer
ELTYPE_UINT = 2      # unsigned integer
ELTYPE_FLOAT = 3     # IEEE-754 floating point (incl. float16, bfloat16*)
ELTYPE_COMPLEX = 4   # complex float (contiguous float tuples)
# 5+ reserved by the paper for future use. We claim code 5 for brain floats,
# which are NOT IEEE-754 binary16 and therefore deserve their own kind —
# this is exactly the extension path the paper advertises (new codes are
# backward compatible: old readers reject unknown kinds loudly).
ELTYPE_BRAIN = 5     # brain floating point (bfloat16 and friends)

ELTYPE_NAMES = {
    ELTYPE_STRUCT: "struct",
    ELTYPE_INT: "int",
    ELTYPE_UINT: "uint",
    ELTYPE_FLOAT: "float",
    ELTYPE_COMPLEX: "complex",
    ELTYPE_BRAIN: "brain",
}

# --- flags bit field ---------------------------------------------------------
# bit 0 is the paper's byte-order bit. Higher bits are our backward-compatible
# extensions (DESIGN.md §7): a reader that doesn't know a bit can refuse it.
FLAG_BIG_ENDIAN = 1 << 0
FLAG_CRC32_TRAILER = 1 << 1   # 4-byte CRC32 of data segment appended AFTER metadata
FLAG_ZLIB = 1 << 2            # payload is zlib-compressed (data_length = compressed size)
FLAG_CHUNKED = 1 << 3         # payload is independently compressed chunks + a
                              # trailer chunk table (DESIGN.md §10);
                              # data_length = stored (compressed) size

KNOWN_FLAGS = FLAG_BIG_ENDIAN | FLAG_CRC32_TRAILER | FLAG_ZLIB | FLAG_CHUNKED

MAX_NDIMS = 64  # sanity bound; format itself allows 2**64


class RawArrayError(ValueError):
    """Malformed or unsupported RawArray file."""
