"""Command-line introspection for RawArray files (paper §3.2).

The paper demonstrates introspection with ``od``; this module is the
in-tree equivalent plus a self-test that our files *are* od-compatible::

    $ PYTHONPATH=src python -m repro.core.racat header test.ra
    $ PYTHONPATH=src python -m repro.core.racat data test.ra | head
    $ PYTHONPATH=src python -m repro.core.racat od test.ra   # prints the od commands
    $ PYTHONPATH=src python -m repro.core.racat verify test.ra  # integrity check
    $ PYTHONPATH=src python -m repro.core.racat inspect test.ra # chunk table
    $ PYTHONPATH=src python -m repro.core.racat compress in.ra out.ra --codec zlib

``header``, ``meta``, ``data``, ``inspect``, and ``verify`` also accept
``http(s)://`` URLs — introspection against a live byte-range server
(DESIGN.md §9) via the remote client, e.g. ``racat header
http://host:8742/train/x.ra``. Remote ``verify`` fetches the file exactly
ONCE and reuses that payload for every recheck (header, CRC, zlib, chunk
table) — never a header fast-path fetch plus a second full download.
``compress`` rewrites any RawArray file (local or URL source) as a
chunk-compressed one (DESIGN.md §10), preserving user metadata.
``ingest`` stream-concatenates ``.npy`` / ``.ra`` sources into one RawArray
file through the incremental writer (DESIGN.md §11) — the destination may
be a local path or the URL of a write-enabled server::

    $ PYTHONPATH=src python -m repro.core.racat ingest out.ra a.npy b.ra
    $ ... racat ingest http://host:8742/out.ra a.npy --codec zlib
"""

from __future__ import annotations

import argparse
import os
import sys
import warnings
import zlib
from typing import Iterator, List, Optional, Tuple

import numpy as np

from . import codec as chunked_codec
from . import io as raio
from . import stats as stats_mod
from .header import Header, decode_header
from .io import header_of, is_url, read, read_metadata
from .spec import (
    ELTYPE_NAMES,
    FLAG_CHUNKED,
    FLAG_CRC32_TRAILER,
    FLAG_ZLIB,
    RawArrayError,
)


def format_header(hdr: Header) -> str:
    notes = [
        name
        for bit, name in [
            (1, "big-endian"), (FLAG_CRC32_TRAILER, "crc32"),
            (FLAG_ZLIB, "zlib"), (FLAG_CHUNKED, "chunked"),
        ]
        if hdr.flags & bit
    ]
    lines = [
        f"magic        rawarray (0x7961727261776172)",
        f"flags        {hdr.flags:#x}"
        + (f" ({', '.join(notes)})" if notes else ""),
        f"eltype       {hdr.eltype} ({ELTYPE_NAMES.get(hdr.eltype, '?')})",
        f"elbyte       {hdr.elbyte}",
        f"data_length  {hdr.data_length}",
        f"ndims        {hdr.ndims}",
        f"dims         {list(hdr.shape)}",
        f"header_bytes {hdr.nbytes}",
        f"numpy dtype  {hdr.dtype()}",
    ]
    return "\n".join(lines)


def od_commands(path: str, hdr: Header) -> str:
    """Emit the exact od invocations from the paper for this file."""
    fmt = {4: "-f", 8: "-d"}.get(hdr.elbyte, "-t x1")
    return "\n".join(
        [
            f"od -N 48 -t u8 {path}        # fixed header as u64",
            f"od -N 48 -c {path}           # see the 'rawarray' magic",
            f"od -j {hdr.nbytes} {fmt} {path}   # the data segment",
        ]
    )


def _blob(path: str) -> bytes:
    """Whole file as bytes — local read or one remote GET."""
    if is_url(path):
        from .. import remote

        return remote.fetch_bytes(path)
    with open(path, "rb") as f:
        return f.read()


def verify_file(path: str) -> List[str]:
    """Recompute every redundant integrity signal in one file; returns the
    list of problems (empty = file is internally consistent).

    Checks: header parse + magic, dims/data_length consistency, payload
    present in full, CRC32 trailer recomputation, for zlib payloads that
    the *decompressed* size matches ``shape × elbyte``, and — when a
    ``rastats`` block is present (DESIGN.md §16) — that per-chunk
    min/max/NaN/count statistics recomputed from the decoded payload match
    the stored block exactly."""
    problems: List[str] = []
    try:
        blob = _blob(path)
    except (OSError, RawArrayError) as e:
        return [f"unreadable: {e}"]
    try:
        hdr = decode_header(blob, strict_flags=False)
    except RawArrayError as e:
        return [f"bad header: {e}"]
    if not hdr.compressed and hdr.data_length != hdr.logical_nbytes:
        problems.append(
            f"data_length={hdr.data_length} inconsistent with "
            f"shape={list(hdr.shape)} x elbyte={hdr.elbyte} (= {hdr.logical_nbytes})"
        )
    payload = blob[hdr.nbytes : hdr.nbytes + hdr.data_length]
    if len(payload) != hdr.data_length:
        problems.append(
            f"truncated data segment: header wants {hdr.data_length} bytes, "
            f"file holds {len(payload)}"
        )
        return problems  # downstream checks would only cascade
    trailer = blob[hdr.nbytes + hdr.data_length :]
    if hdr.flags & FLAG_CRC32_TRAILER:
        if len(trailer) < 4:
            problems.append("CRC32 flag set but trailer missing")
        else:
            want = int.from_bytes(trailer[-4:], "little")
            got = zlib.crc32(payload)
            if got != want:
                problems.append(f"CRC32 mismatch: stored {want:#010x}, computed {got:#010x}")
    if hdr.flags & FLAG_ZLIB:
        try:
            raw = zlib.decompress(payload)
        except zlib.error as e:
            problems.append(f"zlib payload does not decompress: {e}")
        else:
            if len(raw) != hdr.logical_nbytes:
                problems.append(
                    f"decompressed payload is {len(raw)} bytes, shape x elbyte "
                    f"wants {hdr.logical_nbytes}"
                )
    if hdr.flags & FLAG_CHUNKED:
        problems += _verify_chunked(hdr, payload, trailer)
    problems += _verify_stats(hdr, payload, trailer)
    return problems


def _verify_stats(hdr: Header, payload: bytes, trailer: bytes) -> List[str]:
    """Recompute the ``rastats`` block from the decoded payload and compare
    (DESIGN.md §16). Absent block -> nothing to check; damaged framing or
    statistics that disagree with the data are reported as problems —
    readers would full-scan either way, but a disagreement means the
    payload was rewritten without refreshing the stats."""
    meta = trailer
    if hdr.flags & FLAG_CHUNKED:
        try:
            table = chunked_codec.ChunkTable.decode(
                trailer, logical_nbytes=hdr.logical_nbytes,
                stored_nbytes=hdr.data_length)
        except RawArrayError:
            return []  # already reported by _verify_chunked
        meta = trailer[table.nbytes:]
    if hdr.flags & FLAG_CRC32_TRAILER:
        meta = meta[:-4] if len(meta) >= 4 else b""
    try:
        st, _ = stats_mod.split_stats(meta, strict=True)
    except RawArrayError as e:
        return [str(e)]
    if st is None:
        return []
    dt = hdr.dtype()
    if not stats_mod.stats_supported(dt):
        return [f"rastats block present for unsupported dtype {dt}"]
    # decode to raw logical bytes (chunk-by-chunk, whole-zlib, or as-is)
    if hdr.flags & FLAG_CHUNKED:
        try:
            table = chunked_codec.ChunkTable.decode(
                trailer, logical_nbytes=hdr.logical_nbytes,
                stored_nbytes=hdr.data_length)
            codec = chunked_codec.get_codec(table.codec_id)
            raw = b"".join(
                codec.decompress(
                    payload[int(table.stored_offsets[i]):
                            int(table.stored_offsets[i]) + int(table.stored_lens[i])])
                for i in range(table.nchunks))
        except Exception:
            return []  # chunk damage already reported by _verify_chunked
    elif hdr.flags & FLAG_ZLIB:
        try:
            raw = zlib.decompress(payload)
        except zlib.error:
            return []  # already reported above
    else:
        raw = payload
    try:
        acc = stats_mod.StatsAccumulator(dt, st.chunk_bytes)
        acc.feed(raw)
        got = acc.finish()
    except RawArrayError as e:
        return [f"rastats recompute failed: {e}"]
    problems: List[str] = []
    if got.nchunks != st.nchunks:
        problems.append(
            f"rastats window count {st.nchunks} disagrees with payload "
            f"(recomputed {got.nchunks} windows of {st.chunk_bytes} bytes)")
        return problems
    for name, a, b, eq in [
        ("count", st.counts, got.counts, np.array_equal),
        ("nan_count", st.nan_counts, got.nan_counts, np.array_equal),
        ("min", st.mins, got.mins,
         lambda x, y: np.array_equal(x, y, equal_nan=True)),
        ("max", st.maxs, got.maxs,
         lambda x, y: np.array_equal(x, y, equal_nan=True)),
    ]:
        if not eq(np.asarray(a), np.asarray(b)):
            bad = [i for i in range(st.nchunks)
                   if not eq(np.asarray(a[i:i + 1]), np.asarray(b[i:i + 1]))]
            problems.append(
                f"rastats {name} mismatch in window(s) {bad[:8]}"
                f"{'...' if len(bad) > 8 else ''}: stored statistics are "
                "stale for this payload")
    return problems


def _verify_chunked(hdr: Header, payload: bytes, trailer: bytes) -> List[str]:
    """Recheck a chunked payload against its trailer chunk table: table
    parse + geometry, per-chunk CRC32 of the stored bytes, and that every
    chunk decompresses to exactly its raw span (DESIGN.md §10)."""
    try:
        table = chunked_codec.ChunkTable.decode(
            trailer, logical_nbytes=hdr.logical_nbytes, stored_nbytes=hdr.data_length
        )
    except RawArrayError as e:
        return [f"bad chunk table: {e}"]
    problems: List[str] = []
    try:
        codec = chunked_codec.get_codec(table.codec_id)
    except RawArrayError as e:
        return [str(e)]
    raw_total = 0
    for i in range(table.nchunks):
        so = int(table.stored_offsets[i])
        slen = int(table.stored_lens[i])
        stored = payload[so : so + slen]
        if zlib.crc32(stored) != int(table.crcs[i]):
            problems.append(f"chunk {i} CRC32 mismatch: stored bytes corrupted")
            continue
        try:
            raw = codec.decompress(stored)
        except Exception as e:  # codec-specific error types
            problems.append(f"chunk {i} does not decompress: {e}")
            continue
        want = table.raw_len(i, hdr.logical_nbytes)
        if len(raw) != want:
            problems.append(
                f"chunk {i} decompressed to {len(raw)} bytes, table wants {want}"
            )
        raw_total += len(raw)
    if not problems and raw_total != hdr.logical_nbytes:
        problems.append(
            f"chunks decompress to {raw_total} bytes total, shape x elbyte "
            f"wants {hdr.logical_nbytes}"
        )
    return problems


def inspect_file(path: str) -> str:
    """Header, trailing-metadata length, and — for chunked files — a
    chunk-table summary."""
    hdr = header_of(path)
    lines = [format_header(hdr)]
    if is_url(path):
        from .. import remote

        size = remote.get_reader(path).size
    else:
        size = os.path.getsize(path)
    table = None
    if hdr.flags & FLAG_CHUNKED:
        # the table is two small positioned reads — never the payload (for a
        # URL: two ranged GETs through the pooled reader)
        if is_url(path):
            from .. import remote

            table = chunked_codec.read_table(remote.get_reader(path), hdr)
        else:
            with open(path, "rb") as f:
                table = chunked_codec.read_table(f.fileno(), hdr)
    # trailing user metadata = whatever sits between (payload + chunk table)
    # and the optional 4-byte CRC trailer
    meta_len = size - hdr.nbytes - hdr.data_length
    if table is not None:
        meta_len -= table.nbytes
    if hdr.flags & FLAG_CRC32_TRAILER:
        meta_len -= 4
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        st = raio.read_stats(path)
    if st is not None:
        meta_len -= st.nbytes
        lines.append(
            f"stats        {st.nchunks} windows x {st.chunk_bytes} bytes "
            f"(rastats v{st.version}, {st.nbytes} bytes)")
    else:
        lines.append("stats        none")
    lines.append(f"metadata     {max(0, meta_len)} bytes")
    if table is None:
        lines.append("chunks       none (payload is not chunk-compressed)")
        return "\n".join(lines)
    codec = chunked_codec.get_codec(table.codec_id)
    ratio = hdr.data_length / hdr.logical_nbytes if hdr.logical_nbytes else 1.0
    lines += [
        f"codec        {table.codec_id} ({codec.name})",
        f"chunk_bytes  {table.chunk_bytes}",
        f"nchunks      {table.nchunks}",
        f"stored       {hdr.data_length} ({ratio:.3f} of {hdr.logical_nbytes} raw)",
        f"table_bytes  {table.nbytes}",
    ]
    if table.nchunks:
        lens = table.stored_lens.astype(np.int64)
        lines.append(
            f"chunk stored min/mean/max  {int(lens.min())}/"
            f"{int(lens.mean())}/{int(lens.max())}"
        )
    return "\n".join(lines)


def stats_file(path: str, limit: int = 0) -> str:
    """Per-chunk ``rastats`` table (DESIGN.md §16) — header/table/tail
    ranged reads only, never the payload; works on local paths and URLs.
    Raises RawArrayError when the file carries no statistics block."""
    st = raio.read_stats(path)
    if st is None:
        raise RawArrayError(
            "no rastats block (written before PR 9, or with stats=False); "
            "predicates on this file degrade to a full scan")
    lines = [
        f"version      {st.version}",
        f"chunk_bytes  {st.chunk_bytes}",
        f"nchunks      {st.nchunks}",
        f"  {'win':>5}  {'count':>10}  {'nans':>10}  {'min':>24}  {'max':>24}",
    ]
    n = st.nchunks if limit <= 0 else min(limit, st.nchunks)
    for i in range(n):
        lines.append(
            f"  {i:>5}  {int(st.counts[i]):>10}  {int(st.nan_counts[i]):>10}  "
            f"{st.mins[i]:>24.17g}  {st.maxs[i]:>24.17g}")
    if n < st.nchunks:
        lines.append(f"  ... ({st.nchunks} windows total)")
    return "\n".join(lines)


def _checkpoint_dir(path: str) -> Optional[str]:
    """The checkpoint directory when ``path`` names one (a directory — or
    directory URL — holding ``manifest.json``, or that manifest itself);
    ``None`` for anything else, including every ``*.ra`` file."""
    stripped = path.rstrip("/")
    if stripped.endswith("manifest.json"):
        return stripped[: -len("manifest.json")].rstrip("/") or "."
    if stripped.endswith(".ra") or stripped.endswith(".npy"):
        return None
    if not is_url(path):
        if os.path.isdir(path) and os.path.exists(os.path.join(path, "manifest.json")):
            return stripped
        return None
    # a directory URL has no marker; one cheap manifest probe decides
    import json

    from .. import remote

    try:
        obj = json.loads(remote.fetch_bytes(raio.join_path(stripped, "manifest.json")))
    except (RawArrayError, ValueError, OSError):
        return None
    return stripped if isinstance(obj, dict) and "leaves" in obj else None


def _flag_names(hdr: Header) -> str:
    names = [
        name
        for bit, name in [
            (1, "big-endian"), (FLAG_CRC32_TRAILER, "crc32"),
            (FLAG_ZLIB, "zlib"), (FLAG_CHUNKED, "chunked"),
        ]
        if hdr.flags & bit
    ]
    return ",".join(names) if names else "-"


def inspect_checkpoint(ckpt: str) -> str:
    """Audit a checkpoint's cold-start footprint without loading a single
    payload byte: per-leaf logical dtype/shape/flags/codec/quant schema plus
    total stored vs logical bytes. Headers (and chunk-table heads) resolve
    in one parallel engine wave — the same wave 1 the restore engine runs
    (DESIGN.md §13), so this is also a dry run of restore resolution."""
    from ..checkpoint.store import _entry_quant, _load_manifest

    from . import engine

    manifest = _load_manifest(ckpt)
    leaves = manifest.get("leaves", {})
    names = sorted(leaves)
    rows: dict = {}

    def _resolve(name: str) -> None:
        entry = leaves[name]
        fpath = raio.join_path(ckpt, entry["file"])
        hdr = header_of(fpath)
        codec_name = "-"
        if hdr.flags & FLAG_CHUNKED:
            if is_url(fpath):
                from .. import remote

                table = chunked_codec.read_table(remote.get_reader(fpath), hdr)
            else:
                fd = os.open(fpath, os.O_RDONLY)
                try:
                    table = chunked_codec.read_table(fd, hdr)
                finally:
                    os.close(fd)
            codec_name = chunked_codec.get_codec(table.codec_id).name
        elif hdr.flags & FLAG_ZLIB:
            codec_name = "zlib-whole"
        rows[name] = (hdr, codec_name, _entry_quant(entry, fpath, hdr))

    engine.run_tasks([(lambda n=n: _resolve(n)) for n in names])

    stored = logical = 0
    body: List[str] = []
    for name in names:
        hdr, codec_name, quant = rows[name]
        if quant is not None:
            dtype = quant.orig_dtype
            leaf_logical = hdr.logical_nbytes * np.dtype(quant.orig_dtype).itemsize
            per = "per-channel" if quant.scale.ndim else "scalar"
            qdesc = f"{quant.mode}->{quant.orig_dtype} {per}"
        else:
            dtype = str(hdr.dtype())
            leaf_logical = hdr.logical_nbytes
            qdesc = "-"
        stored += hdr.data_length
        logical += leaf_logical
        body.append(
            f"  {name:<40} {dtype:<9} {str(list(hdr.shape)):<16} "
            f"{_flag_names(hdr):<14} {codec_name:<10} {qdesc}"
        )
    ratio = stored / logical if logical else 1.0
    head = [
        f"checkpoint   {ckpt}",
        f"step         {manifest.get('step', '?')}",
        f"leaves       {len(names)}",
        f"stored       {stored} bytes",
        f"logical      {logical} bytes ({ratio:.3f} stored/logical)",
        f"  {'leaf':<40} {'dtype':<9} {'shape':<16} {'flags':<14} {'codec':<10} quant",
    ]
    return "\n".join(head + body)


def compress_file(
    src: str,
    dst: str,
    *,
    codec: str = None,
    chunk_bytes: int = None,
    crc32: bool = False,
) -> Tuple[int, int]:
    """Rewrite any RawArray file (local path or URL) as a chunk-compressed
    one, preserving user metadata. Returns (logical, stored) byte sizes."""
    arr, meta = read(src, with_metadata=True, strict_flags=False)
    raio.write(
        dst, arr, metadata=meta or None,
        chunked=True, codec=codec, chunk_bytes=chunk_bytes, crc32=crc32,
    )
    hdr = header_of(dst)
    return hdr.logical_nbytes, hdr.data_length


def _source_rows(src: str) -> np.ndarray:
    """Open one ingest source as an array-like with a leading row dim.
    Plain local ``.ra`` files and ``.npy`` are memory-mapped (rows stream
    without loading the file); compressed / remote sources decode fully."""
    if src.endswith(".npy"):
        if is_url(src):
            import io as _io

            from .. import remote

            return np.load(_io.BytesIO(remote.fetch_bytes(src)), allow_pickle=False)
        return np.load(src, mmap_mode="r", allow_pickle=False)
    hdr = header_of(src)
    if not is_url(src) and not hdr.compressed and not hdr.big_endian:
        return raio.memmap(src)
    return np.asarray(read(src, strict_flags=False))


def ingest_files(
    dst: str,
    sources: List[str],
    *,
    codec: Optional[str] = None,
    chunk_bytes: Optional[int] = None,
    crc32: bool = False,
    batch_rows: Optional[int] = None,
) -> Tuple[int, "Header"]:
    """Stream-concatenate ``sources`` (``.npy`` or ``.ra``, local or URL)
    along axis 0 into one RawArray file through the incremental writer
    (DESIGN.md §11) — rows flow source → writer in bounded batches, so the
    result never materializes in RAM. ``dst`` may be a local path (crash-
    safe temp + rename) or the URL of a write-enabled server (streamed
    authenticated PUTs). Passing ``codec=``/``chunk_bytes=`` writes
    chunk-compressed. Returns ``(rows, final_header)``."""
    if not sources:
        raise RawArrayError("ingest needs at least one source file")
    first = _source_rows(sources[0])
    if first.ndim == 0:
        raise RawArrayError(f"{sources[0]}: cannot ingest a 0-d array")
    row_shape = first.shape[1:]
    dtype = np.dtype(first.dtype)
    row_nbytes = max(1, int(dtype.itemsize * int(np.prod(row_shape, dtype=np.int64))))
    if batch_rows is None:
        batch_rows = max(1, (32 << 20) // row_nbytes)  # ~32 MiB per batch
    chunked = codec is not None or chunk_bytes is not None
    if is_url(dst):
        from .. import remote

        writer = remote.RemoteWriter(
            dst, dtype, row_shape,
            crc32=crc32, chunked=chunked, codec=codec, chunk_bytes=chunk_bytes,
        )
    else:
        writer = raio.RaWriter(
            dst, dtype, row_shape,
            crc32=crc32, chunked=chunked, codec=codec, chunk_bytes=chunk_bytes,
        )
    with writer as w:
        for i, src in enumerate(sources):
            a = first if i == 0 else _source_rows(src)
            if a.shape[1:] != row_shape or np.dtype(a.dtype) != dtype:
                raise RawArrayError(
                    f"{src}: rows are {a.dtype}{list(a.shape[1:])}, expected "
                    f"{dtype}{list(row_shape)} (from {sources[0]})"
                )
            for lo in range(0, a.shape[0], batch_rows):
                w.write_rows(a[lo : lo + batch_rows])
        hdr = w.finalize()
    return int(hdr.shape[0]), hdr


def format_owners(table: dict) -> str:
    """Render ``data_mesh.owners_table`` output: per-shard assignment rows,
    per-host byte totals, and the imbalance ratio."""
    lines = [f"{'shard':>5}  {'rows':>10}  {'bytes':>14}  owner"]
    for s in table["shards"]:
        lines.append(
            f"{s['shard']:>5}  {s['rows']:>10}  {s['bytes']:>14}  {s['owner']}"
        )
    lines.append("")
    lines.append(f"{'host':<16}  {'shards':>6}  {'rows':>10}  {'bytes':>14}")
    for h in table["hosts"]:
        t = table["per_host"][h]
        lines.append(
            f"{h:<16}  {t['shards']:>6}  {t['rows']:>10}  {t['bytes']:>14}"
        )
    lines.append("")
    lines.append(
        f"epoch {table['epoch']}: {len(table['shards'])} shards, "
        f"{table['total_rows']} rows, {table['total_bytes']} bytes, "
        f"imbalance {table['imbalance']:.3f} (max host bytes / mean)"
    )
    return "\n".join(lines)


def _parse_hosts(spec: str) -> List[str]:
    names = [h.strip() for h in spec.split(",") if h.strip()]
    if len(names) == 1 and names[0].isdigit():
        return [f"host{i}" for i in range(int(names[0]))]
    return names


_EPILOG = """\
subcommands:
  header     print the decoded numeric header
  data       print the first payload elements (--limit)
  meta       dump the trailing user metadata to stdout
  od         print the od(1) commands that introspect this file (paper §3.2)
  verify     recompute every integrity signal (header consistency, CRC32
             trailer, zlib size, chunk-table geometry + per-chunk CRCs,
             rastats min/max/NaN/count vs the decoded payload)
  stats      print the per-chunk rastats table (DESIGN.md §16) — ranged
             reads only, the payload is never fetched; exits 1 when the
             file has no statistics block
  inspect    header + metadata length + chunk-table summary; pointed at a
             checkpoint directory (or its manifest.json), prints the
             per-leaf dtype/shape/flags/codec/quant audit instead —
             stored vs logical bytes without loading any payload
  compress   rewrite as chunk-compressed:  racat compress <src> <dst>
  ingest     stream-concatenate .npy/.ra sources into one file or URL:
             racat ingest <dst> <src...> [--codec C] [--crc32]
  doctor     layout-geometry checks against the core/layouts.py registry:
             racat doctor FILE|DIR [...] — header/chunk-table/rastats
             framing, segment tiling, stale-stats detection; never decodes
             the payload; exits 1 on any drift (DESIGN.md §17)
  owners     shard -> host ownership table for a dataset manifest (or
             sharded index.json) under the data mesh (DESIGN.md §15):
             racat owners <manifest> --hosts N [--epoch E] [--vnodes V]
             prints (shard, rows, bytes, owner) rows, per-host byte
             totals, and the imbalance ratio — ZERO payload reads

every subcommand accepts http(s):// URLs where a byte-range server is
serving (ingest destinations need a write-enabled server + RA_REMOTE_TOKEN).

exit codes:
  0   success (verify: file is internally consistent)
  1   failure (verify found problems, source unreadable, ingest/upload
      refused, malformed file)
  2   usage error (unknown subcommand or bad arguments)
"""


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="racat",
        description=__doc__,
        epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    p.add_argument(
        "cmd",
        choices=["header", "data", "meta", "od", "verify", "inspect",
                 "stats", "compress", "ingest", "owners", "doctor"],
    )
    p.add_argument("path", help="file path or http(s):// URL "
                   "(compress: source; ingest: destination)")
    p.add_argument("rest", nargs="*", default=[],
                   help="compress: output path; ingest: source files")
    p.add_argument("--limit", type=int, default=16, help="max elements to print")
    p.add_argument("--codec", default=None,
                   help="codec name for compress/ingest (default: RA_CODEC or zlib)")
    p.add_argument("--chunk-bytes", type=int, default=None,
                   help="raw chunk size for compress/ingest "
                   "(default: RA_CHUNK_BYTES or 1 MiB)")
    p.add_argument("--crc32", action="store_true",
                   help="also write a file-level CRC trailer (compress/ingest)")
    p.add_argument("--batch-rows", type=int, default=None,
                   help="rows per streamed ingest batch (default: ~32 MiB worth)")
    p.add_argument("--hosts", default=None,
                   help="owners: host count (N -> host0..host{N-1}) or a "
                   "comma-separated list of host names")
    p.add_argument("--epoch", type=int, default=0,
                   help="owners: epoch whose ownership deal to print "
                   "(RA_MESH_EPOCH_REOWN re-deals shards per epoch)")
    p.add_argument("--vnodes", type=int, default=None,
                   help="owners: virtual nodes per host on the ring "
                   "(default: RA_MESH_VNODES or 64)")
    args = p.parse_args(argv)
    if args.rest and args.cmd not in ("compress", "ingest", "doctor"):
        p.error(f"{args.cmd} takes exactly one path "
                f"(unexpected extra arguments: {' '.join(args.rest)})")

    try:
        if args.cmd == "doctor":
            # deferred: devtools is a dev dependency of the data plane,
            # not the other way around
            from ..devtools import doctor as doctor_mod

            return doctor_mod.main([args.path] + args.rest)

        if args.cmd == "verify":
            problems = verify_file(args.path)
            if problems:
                for msg in problems:
                    print(f"FAIL {args.path}: {msg}", file=sys.stderr)
                return 1
            print(f"OK {args.path}")
            return 0

        if args.cmd == "compress":
            if len(args.rest) != 1:
                p.error("compress needs an output path: racat compress <src> <dst>")
            logical, stored = compress_file(
                args.path, args.rest[0],
                codec=args.codec, chunk_bytes=args.chunk_bytes, crc32=args.crc32,
            )
            ratio = stored / logical if logical else 1.0
            print(f"OK {args.rest[0]}: {logical} -> {stored} bytes ({ratio:.3f})")
            return 0

        if args.cmd == "ingest":
            if not args.rest:
                p.error("ingest needs sources: racat ingest <dst> <src...>")
            rows, hdr = ingest_files(
                args.path, args.rest,
                codec=args.codec, chunk_bytes=args.chunk_bytes,
                crc32=args.crc32, batch_rows=args.batch_rows,
            )
            print(f"OK {args.path}: {rows} rows {list(hdr.shape)} "
                  f"{hdr.dtype()} ({hdr.data_length} stored bytes)")
            return 0

        if args.cmd == "owners":
            if not args.hosts:
                p.error("owners needs --hosts N (or --hosts a,b,c)")
            hosts = _parse_hosts(args.hosts)
            if not hosts:
                p.error(f"--hosts {args.hosts!r} names no hosts")
            # deferred: the mesh module (numpy + the fleet's hash ring only)
            from ..distributed.data_mesh import owners_table

            table = owners_table(
                args.path, hosts, epoch=args.epoch, vnodes=args.vnodes
            )
            print(format_owners(table))
            return 0

        if args.cmd == "inspect":
            ckpt = _checkpoint_dir(args.path)
            print(inspect_checkpoint(ckpt) if ckpt else inspect_file(args.path))
            return 0

        if args.cmd == "stats":
            print(stats_file(args.path))
            return 0

        hdr = header_of(args.path)
        if args.cmd == "header":
            print(format_header(hdr))
        elif args.cmd == "data":
            arr = read(args.path, strict_flags=False)
            flat = np.asarray(arr).reshape(-1)
            np.set_printoptions(threshold=args.limit)
            print(flat[: args.limit])
            if flat.size > args.limit:
                print(f"... ({flat.size} elements total)")
        elif args.cmd == "meta":
            sys.stdout.buffer.write(read_metadata(args.path))
        elif args.cmd == "od":
            print(od_commands(args.path, hdr))
        return 0
    except (RawArrayError, OSError) as e:
        print(f"FAIL {args.path}: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    raise SystemExit(main())
