"""Command-line introspection for RawArray files (paper §3.2).

The paper demonstrates introspection with ``od``; this module is the
in-tree equivalent plus a self-test that our files *are* od-compatible::

    $ PYTHONPATH=src python -m repro.core.racat header test.ra
    $ PYTHONPATH=src python -m repro.core.racat data test.ra | head
    $ PYTHONPATH=src python -m repro.core.racat od test.ra   # prints the od commands
    $ PYTHONPATH=src python -m repro.core.racat verify test.ra  # integrity check

``header``, ``meta``, ``data``, and ``verify`` also accept ``http(s)://``
URLs — introspection against a live byte-range server (DESIGN.md §9) via
the remote client, e.g. ``racat header http://host:8742/train/x.ra``.
"""

from __future__ import annotations

import argparse
import sys
import zlib
from typing import List

import numpy as np

from .header import Header, decode_header
from .io import header_of, is_url, read, read_metadata
from .spec import ELTYPE_NAMES, FLAG_CRC32_TRAILER, FLAG_ZLIB, RawArrayError


def format_header(hdr: Header) -> str:
    lines = [
        f"magic        rawarray (0x7961727261776172)",
        f"flags        {hdr.flags:#x}"
        + (" (big-endian)" if hdr.big_endian else ""),
        f"eltype       {hdr.eltype} ({ELTYPE_NAMES.get(hdr.eltype, '?')})",
        f"elbyte       {hdr.elbyte}",
        f"data_length  {hdr.data_length}",
        f"ndims        {hdr.ndims}",
        f"dims         {list(hdr.shape)}",
        f"header_bytes {hdr.nbytes}",
        f"numpy dtype  {hdr.dtype()}",
    ]
    return "\n".join(lines)


def od_commands(path: str, hdr: Header) -> str:
    """Emit the exact od invocations from the paper for this file."""
    fmt = {4: "-f", 8: "-d"}.get(hdr.elbyte, "-t x1")
    return "\n".join(
        [
            f"od -N 48 -t u8 {path}        # fixed header as u64",
            f"od -N 48 -c {path}           # see the 'rawarray' magic",
            f"od -j {hdr.nbytes} {fmt} {path}   # the data segment",
        ]
    )


def _blob(path: str) -> bytes:
    """Whole file as bytes — local read or one remote GET."""
    if is_url(path):
        from .. import remote

        return remote.fetch_bytes(path)
    with open(path, "rb") as f:
        return f.read()


def verify_file(path: str) -> List[str]:
    """Recompute every redundant integrity signal in one file; returns the
    list of problems (empty = file is internally consistent).

    Checks: header parse + magic, dims/data_length consistency, payload
    present in full, CRC32 trailer recomputation, and — for zlib payloads —
    that the *decompressed* size matches ``shape × elbyte``."""
    problems: List[str] = []
    try:
        blob = _blob(path)
    except (OSError, RawArrayError) as e:
        return [f"unreadable: {e}"]
    try:
        hdr = decode_header(blob, strict_flags=False)
    except RawArrayError as e:
        return [f"bad header: {e}"]
    if not (hdr.flags & FLAG_ZLIB) and hdr.data_length != hdr.logical_nbytes:
        problems.append(
            f"data_length={hdr.data_length} inconsistent with "
            f"shape={list(hdr.shape)} x elbyte={hdr.elbyte} (= {hdr.logical_nbytes})"
        )
    payload = blob[hdr.nbytes : hdr.nbytes + hdr.data_length]
    if len(payload) != hdr.data_length:
        problems.append(
            f"truncated data segment: header wants {hdr.data_length} bytes, "
            f"file holds {len(payload)}"
        )
        return problems  # downstream checks would only cascade
    trailer = blob[hdr.nbytes + hdr.data_length :]
    if hdr.flags & FLAG_CRC32_TRAILER:
        if len(trailer) < 4:
            problems.append("CRC32 flag set but trailer missing")
        else:
            want = int.from_bytes(trailer[-4:], "little")
            got = zlib.crc32(payload)
            if got != want:
                problems.append(f"CRC32 mismatch: stored {want:#010x}, computed {got:#010x}")
    if hdr.flags & FLAG_ZLIB:
        try:
            raw = zlib.decompress(payload)
        except zlib.error as e:
            problems.append(f"zlib payload does not decompress: {e}")
        else:
            if len(raw) != hdr.logical_nbytes:
                problems.append(
                    f"decompressed payload is {len(raw)} bytes, shape x elbyte "
                    f"wants {hdr.logical_nbytes}"
                )
    return problems


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="racat", description=__doc__)
    p.add_argument("cmd", choices=["header", "data", "meta", "od", "verify"])
    p.add_argument("path", help="file path or http(s):// URL")
    p.add_argument("--limit", type=int, default=16, help="max elements to print")
    args = p.parse_args(argv)

    if args.cmd == "verify":
        problems = verify_file(args.path)
        if problems:
            for msg in problems:
                print(f"FAIL {args.path}: {msg}", file=sys.stderr)
            return 1
        print(f"OK {args.path}")
        return 0

    hdr = header_of(args.path)
    if args.cmd == "header":
        print(format_header(hdr))
    elif args.cmd == "data":
        arr = read(args.path, strict_flags=False)
        flat = np.asarray(arr).reshape(-1)
        np.set_printoptions(threshold=args.limit)
        print(flat[: args.limit])
        if flat.size > args.limit:
            print(f"... ({flat.size} elements total)")
    elif args.cmd == "meta":
        sys.stdout.buffer.write(read_metadata(args.path))
    elif args.cmd == "od":
        print(od_commands(args.path, hdr))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
