"""Command-line introspection for RawArray files (paper §3.2).

The paper demonstrates introspection with ``od``; this module is the
in-tree equivalent plus a self-test that our files *are* od-compatible::

    $ PYTHONPATH=src python -m repro.core.racat header test.ra
    $ PYTHONPATH=src python -m repro.core.racat data test.ra | head
    $ PYTHONPATH=src python -m repro.core.racat od test.ra   # prints the od commands
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from .header import Header
from .io import header_of, read, read_metadata
from .spec import ELTYPE_NAMES


def format_header(hdr: Header) -> str:
    lines = [
        f"magic        rawarray (0x7961727261776172)",
        f"flags        {hdr.flags:#x}"
        + (" (big-endian)" if hdr.big_endian else ""),
        f"eltype       {hdr.eltype} ({ELTYPE_NAMES.get(hdr.eltype, '?')})",
        f"elbyte       {hdr.elbyte}",
        f"data_length  {hdr.data_length}",
        f"ndims        {hdr.ndims}",
        f"dims         {list(hdr.shape)}",
        f"header_bytes {hdr.nbytes}",
        f"numpy dtype  {hdr.dtype()}",
    ]
    return "\n".join(lines)


def od_commands(path: str, hdr: Header) -> str:
    """Emit the exact od invocations from the paper for this file."""
    fmt = {4: "-f", 8: "-d"}.get(hdr.elbyte, "-t x1")
    return "\n".join(
        [
            f"od -N 48 -t u8 {path}        # fixed header as u64",
            f"od -N 48 -c {path}           # see the 'rawarray' magic",
            f"od -j {hdr.nbytes} {fmt} {path}   # the data segment",
        ]
    )


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="racat", description=__doc__)
    p.add_argument("cmd", choices=["header", "data", "meta", "od"])
    p.add_argument("path")
    p.add_argument("--limit", type=int, default=16, help="max elements to print")
    args = p.parse_args(argv)

    hdr = header_of(args.path)
    if args.cmd == "header":
        print(format_header(hdr))
    elif args.cmd == "data":
        arr = read(args.path, strict_flags=False)
        flat = np.asarray(arr).reshape(-1)
        np.set_printoptions(threshold=args.limit)
        print(flat[: args.limit])
        if flat.size > args.limit:
            print(f"... ({flat.size} elements total)")
    elif args.cmd == "meta":
        sys.stdout.buffer.write(read_metadata(args.path))
    elif args.cmd == "od":
        print(od_commands(args.path, hdr))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
