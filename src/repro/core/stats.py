"""Per-chunk statistics + predicate pushdown (DESIGN.md §16).

Two halves, one module:

1. The ``rastats`` block — a versioned, ``od``-introspectable metadata
   block (like ``rachunks``) holding min / max / NaN-count / count per
   *stats window* per field.  A stats window is the run of elements whose
   byte span intersects ``[i*chunk_bytes, (i+1)*chunk_bytes)``; for
   chunked files the windows coincide exactly with the chunk table's
   chunks, for plain files they are virtual chunks at multiples of the
   same default.  Elements straddling a boundary are counted in *both*
   windows, so every window's ``[min, max]`` interval conservatively
   covers every element it touches.  All arrays are little-endian:
   counts/nan-counts as ``<u8``, bounds as f64 (integer bounds are
   rounded *outward* via nextafter so pruning can never overshoot).

2. The predicate engine — a small composable AST (``col("label") == 3``,
   ``(col("t") >= a) & (col("t") < b)``, ``&``/``|``/``~``) that maps a
   predicate plus per-field stats to per-row verdicts
   {take-all, prune, scan} using exact three-valued interval logic.
   A comparison is row-true iff **all** elements of that field's row
   satisfy it; NaN fails every comparison except ``!=`` (IEEE-754).
   Verdicts are conservative: a row is *pruned* only when the stats
   prove every element fails, *taken* only when they prove every
   element passes; anything else is *scanned* (decoded + masked), so
   missing, corrupt, or unknown-version stats degrade to a full scan —
   never a wrong answer.

Wire format (all little-endian, 40 + 32*nchunks bytes, prepended to the
user-metadata region after the chunk table)::

    u64 magic        = "rastats_"
    u64 version      = 1
    u64 block_bytes  = 40 + 32*nchunks
    u64 nchunks      (number of stats windows)
    u64 chunk_bytes  (window width in payload bytes)
    u64 count[nchunks]   elements per window (straddlers counted twice)
    u64 nan_count[nchunks]
    f64 min[nchunks]     NaN when the window holds no numeric value
    f64 max[nchunks]
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Set, Tuple

import numpy as np

from . import layouts
from .spec import RawArrayError

RASTATS_MAGIC: int = layouts.RASTATS.magic_int
RASTATS_MAGIC_BYTES: bytes = layouts.RASTATS.magic
STATS_VERSION = 1

_HEAD = layouts.RASTATS.head_struct  # magic, version, block_bytes, nchunks, chunk_bytes
HEAD_BYTES = layouts.RASTATS.head_bytes  # 40
ENTRY_BYTES = layouts.RASTATS.entry_bytes  # u64 count + u64 nan_count + f64 min + f64 max


def stats_supported(dtype) -> bool:
    """True when per-chunk min/max statistics are defined for ``dtype``.

    Covers bool, signed/unsigned integers and IEEE floats (DESIGN.md
    §16); complex, strings and exotic dtypes get no stats block and
    therefore always full-scan.
    """
    return np.dtype(dtype).kind in "biuf"


def _f64_down(x) -> float:
    """Largest-or-equal f64 lower bound of exact value ``x`` (int)."""
    f = float(x)
    return f if f <= x else float(np.nextafter(f, -np.inf))


def _f64_up(x) -> float:
    """Smallest-or-equal f64 upper bound of exact value ``x`` (int)."""
    f = float(x)
    return f if f >= x else float(np.nextafter(f, np.inf))


# --------------------------------------------------------------------------
# the rastats block
# --------------------------------------------------------------------------
@dataclass
class ChunkStats:
    """Decoded ``rastats`` block: per-window statistics (DESIGN.md §16).

    ``mins``/``maxs`` are f64 with integer bounds rounded outward; a NaN
    bound means the window holds no numeric (non-NaN) value at all.
    """

    chunk_bytes: int
    counts: np.ndarray      # u64 [nchunks]
    nan_counts: np.ndarray  # u64 [nchunks]
    mins: np.ndarray        # f64 [nchunks]
    maxs: np.ndarray        # f64 [nchunks]
    version: int = STATS_VERSION

    @property
    def nchunks(self) -> int:
        return len(self.counts)

    @property
    def nbytes(self) -> int:
        return HEAD_BYTES + ENTRY_BYTES * self.nchunks

    def encode(self) -> bytes:
        """Serialize to the little-endian wire form (DESIGN.md §16)."""
        n = self.nchunks
        head = _HEAD.pack(RASTATS_MAGIC, self.version,
                          HEAD_BYTES + ENTRY_BYTES * n, n, self.chunk_bytes)
        return (head
                + np.ascontiguousarray(self.counts, dtype="<u8").tobytes()
                + np.ascontiguousarray(self.nan_counts, dtype="<u8").tobytes()
                + np.ascontiguousarray(self.mins, dtype="<f8").tobytes()
                + np.ascontiguousarray(self.maxs, dtype="<f8").tobytes())

    @classmethod
    def decode(cls, buf: bytes) -> "ChunkStats":
        """Strict decode of one block; raises RawArrayError on any damage."""
        st, rest = split_stats(buf, strict=True)
        if st is None:
            raise RawArrayError("rastats: no statistics block found")
        return st


def split_stats(meta: bytes, *, strict: bool = False
                ) -> Tuple[Optional[ChunkStats], bytes]:
    """Split a trailing-metadata region into ``(stats, user_metadata)``.

    Files written before the stats era (or with stats off) simply have
    no ``rastats_`` magic and pass through as ``(None, meta)``.  A block
    with damaged framing (truncated, impossible geometry) yields
    ``(None, meta)`` with a warning — callers then full-scan rather than
    trust bad bounds (DESIGN.md §16).  With ``strict=True`` damage
    raises RawArrayError instead (used by ``racat verify``).
    """
    b = bytes(meta)
    if len(b) < HEAD_BYTES or not b.startswith(RASTATS_MAGIC_BYTES):
        return None, b

    def _bad(msg: str):
        if strict:
            raise RawArrayError(f"rastats: {msg}")
        warnings.warn(f"rastats: {msg}; ignoring statistics (full scan)",
                      RuntimeWarning, stacklevel=3)
        return None, b

    magic, version, block_bytes, n, chunk_bytes = _HEAD.unpack_from(b)
    if n > (len(b) - HEAD_BYTES) // ENTRY_BYTES:
        return _bad(f"truncated block ({n} chunks, {len(b)} bytes available)")
    if block_bytes != HEAD_BYTES + ENTRY_BYTES * n:
        return _bad(f"block_bytes {block_bytes} inconsistent with nchunks {n}")
    if n > 0 and chunk_bytes <= 0:
        return _bad(f"invalid chunk_bytes {chunk_bytes}")
    rest = b[block_bytes:]
    if version != STATS_VERSION:
        # framing is sound, content rules unknown: strip but don't trust
        if strict:
            raise RawArrayError(f"rastats: unknown version {version}")
        warnings.warn(f"rastats: unknown version {version}; ignoring "
                      "statistics (full scan)", RuntimeWarning, stacklevel=3)
        return None, rest
    off = HEAD_BYTES
    counts = np.frombuffer(b, dtype="<u8", count=n, offset=off)
    nans = np.frombuffer(b, dtype="<u8", count=n, offset=off + 8 * n)
    mins = np.frombuffer(b, dtype="<f8", count=n, offset=off + 16 * n)
    maxs = np.frombuffer(b, dtype="<f8", count=n, offset=off + 24 * n)
    if bool(np.any(nans.astype(np.int64) > counts.astype(np.int64))):
        return _bad("nan_count exceeds count")
    return ChunkStats(chunk_bytes=int(chunk_bytes), counts=counts,
                      nan_counts=nans, mins=mins, maxs=maxs,
                      version=int(version)), rest


class StatsAccumulator:
    """Streaming min/max/NaN/count accumulator (DESIGN.md §16).

    Feed it the payload as it is produced — either typed batches via
    :meth:`add` (``RaWriter.write_rows``) or raw stored-order bytes via
    :meth:`feed` (``ChunkStreamCompressor``) — and collect the finished
    :class:`ChunkStats` with :meth:`finish`.  Both entry points produce
    byte-identical blocks for the same payload, which is what keeps the
    streamed writers byte-identical to the monolithic ``io.write``.
    """

    def __init__(self, dtype, chunk_bytes: int):
        dt = np.dtype(dtype)
        if not stats_supported(dt):
            raise RawArrayError(f"rastats: unsupported dtype {dt}")
        if int(chunk_bytes) <= 0:
            raise RawArrayError(f"rastats: invalid chunk_bytes {chunk_bytes}")
        self._dt = dt              # stored-order dtype (for feed())
        self._eb = dt.itemsize
        self._cb = int(chunk_bytes)
        self._isfloat = dt.kind == "f"
        self._carry = b""
        self._elems = 0
        self._counts: list = []
        self._nans: list = []
        self._mins: list = []
        self._maxs: list = []

    def feed(self, data) -> None:
        """Accumulate raw payload bytes (stored byte order, any framing)."""
        b = bytes(data)
        if self._carry:
            b = self._carry + b
        n = len(b) // self._eb
        self._carry = b[n * self._eb:]
        if n:
            self._update(np.frombuffer(b, dtype=self._dt, count=n))

    def add(self, arr) -> None:
        """Accumulate a typed batch (rows in logical order, any shape)."""
        a = np.ascontiguousarray(arr).reshape(-1)
        if a.size:
            self._update(a)

    def _grow(self, upto: int) -> None:
        while len(self._counts) <= upto:
            self._counts.append(0)
            self._nans.append(0)
            self._mins.append(float("nan"))
            self._maxs.append(float("nan"))

    def _update(self, vals: np.ndarray) -> None:
        e0, n, eb, cb = self._elems, vals.size, self._eb, self._cb
        ci0 = (e0 * eb) // cb
        ci1 = ((e0 + n) * eb - 1) // cb
        self._grow(ci1)
        for ci in range(ci0, ci1 + 1):
            lo = max(e0, (ci * cb) // eb)
            hi = min(e0 + n, -(-((ci + 1) * cb) // eb))
            if hi <= lo:
                continue
            seg = vals[lo - e0:hi - e0]
            self._counts[ci] += seg.size
            if self._isfloat:
                self._nans[ci] += int(np.count_nonzero(np.isnan(seg)))
                mn = float(np.fmin.reduce(seg.astype(np.float64, copy=False)))
                mx = float(np.fmax.reduce(seg.astype(np.float64, copy=False)))
            else:
                mn = _f64_down(int(seg.min()))
                mx = _f64_up(int(seg.max()))
            self._mins[ci] = float(np.fmin(self._mins[ci], mn))
            self._maxs[ci] = float(np.fmax(self._maxs[ci], mx))
        self._elems += n

    def finish(self) -> ChunkStats:
        """Return the accumulated block (empty payload -> zero windows)."""
        return ChunkStats(
            chunk_bytes=self._cb,
            counts=np.asarray(self._counts, dtype="<u8"),
            nan_counts=np.asarray(self._nans, dtype="<u8"),
            mins=np.asarray(self._mins, dtype="<f8"),
            maxs=np.asarray(self._maxs, dtype="<f8"),
        )


def compute_stats(arr, chunk_bytes: int) -> ChunkStats:
    """One-shot stats for a whole logical array (monolithic ``io.write``)."""
    acc = StatsAccumulator(np.asarray(arr).dtype, chunk_bytes)
    acc.add(arr)
    return acc.finish()


# --------------------------------------------------------------------------
# predicate engine
# --------------------------------------------------------------------------
def _round2(value) -> Tuple[float, float, bool]:
    """(v_down, v_up, exact): outward f64 bounds of a comparison value."""
    v = float(value)
    if v == value:
        return v, v, True
    if v < value:
        return v, float(np.nextafter(v, np.inf)), False
    return float(np.nextafter(v, -np.inf)), v, False


class Expr:
    """Composable predicate over dataset fields (DESIGN.md §16).

    Build leaves with :func:`col` and combine with ``&`` / ``|`` / ``~``.
    A comparison is row-true iff *all* elements of the field's row
    satisfy it (NaN satisfies only ``!=``).
    """

    def __and__(self, other: "Expr") -> "Expr":
        return And(self, _as_expr(other))

    def __or__(self, other: "Expr") -> "Expr":
        return Or(self, _as_expr(other))

    def __invert__(self) -> "Expr":
        return Not(self)

    def __bool__(self):
        raise TypeError(
            "predicates combine with & | ~ (not and/or/not or chained "
            "comparisons); e.g. (col('t') >= a) & (col('t') < b)")

    def fields(self) -> Set[str]:
        """Names of every field this predicate reads."""
        raise NotImplementedError

    def mask(self, batch: Dict[str, np.ndarray]) -> np.ndarray:
        """Exact per-row boolean mask over decoded rows."""
        raise NotImplementedError

    def row_verdicts(self, nrows: int, field_info) -> Tuple[np.ndarray, np.ndarray]:
        """Conservative per-row ``(definitely_true, definitely_false)``.

        ``field_info`` maps field name -> ``(ChunkStats | None,
        row_nbytes)``.  Rows in neither array must be scanned.  Missing
        stats or geometry that disagrees with ``nrows * row_nbytes``
        (stale block) degrade that leaf to scan-everything.
        """
        raise NotImplementedError


def _as_expr(e) -> "Expr":
    if not isinstance(e, Expr):
        raise TypeError(f"expected a predicate Expr, got {type(e).__name__}")
    return e


def _row_intervals(st: Optional[ChunkStats], nrows: int, row_nbytes: int):
    """Per-row abstract value set from window intervals, or None to scan.

    Returns ``(mn, mx, has_nan, has_num)`` f64/bool arrays of length
    ``nrows`` where each row's interval is the fmin/fmax union over every
    window its byte span intersects (straddling windows painted on both
    sides — the dual of the writer's double-counting).
    """
    if st is None or nrows <= 0:
        return None
    total = nrows * row_nbytes
    expected = -(-total // st.chunk_bytes) if (total > 0 and st.chunk_bytes > 0) else 0
    if st.nchunks != expected:
        warnings.warn(
            f"rastats: window count {st.nchunks} does not match payload "
            f"geometry (expected {expected}); ignoring statistics (full "
            "scan)", RuntimeWarning, stacklevel=4)
        return None
    mn = np.full(nrows, np.nan)
    mx = np.full(nrows, np.nan)
    has_nan = np.zeros(nrows, dtype=bool)
    has_num = np.zeros(nrows, dtype=bool)
    cb, rnb = st.chunk_bytes, row_nbytes
    win_num = ~np.isnan(st.mins)
    win_nan = st.nan_counts > 0
    for ci in range(st.nchunks):
        r0 = (ci * cb) // rnb
        r1 = min(nrows, -(-min((ci + 1) * cb, total) // rnb))
        if r1 <= r0:
            continue
        s = slice(r0, r1)
        mn[s] = np.fmin(mn[s], st.mins[ci])
        mx[s] = np.fmax(mx[s], st.maxs[ci])
        if win_nan[ci]:
            has_nan[s] = True
        if win_num[ci]:
            has_num[s] = True
    return mn, mx, has_nan, has_num


_OPS = {
    "eq": lambda a, v: a == v,
    "ne": lambda a, v: a != v,
    "lt": lambda a, v: a < v,
    "le": lambda a, v: a <= v,
    "gt": lambda a, v: a > v,
    "ge": lambda a, v: a >= v,
}


class Cmp(Expr):
    """Leaf comparison ``col(field) <op> value`` (DESIGN.md §16)."""

    def __init__(self, field: str, op: str, value):
        if op not in _OPS:
            raise RawArrayError(f"unknown predicate op {op!r}")
        self.field, self.op, self.value = field, op, value

    def __repr__(self):
        sym = dict(eq="==", ne="!=", lt="<", le="<=", gt=">", ge=">=")[self.op]
        return f"(col({self.field!r}) {sym} {self.value!r})"

    def fields(self) -> Set[str]:
        return {self.field}

    def mask(self, batch):
        a = batch[self.field]
        m = _OPS[self.op](a, self.value)
        if m.ndim > 1:
            m = m.all(axis=tuple(range(1, m.ndim)))
        return np.asarray(m, dtype=bool)

    def row_verdicts(self, nrows, field_info):
        st, rnb = field_info[self.field]
        if rnb <= 0:
            # zero-width rows: the all-elements quantifier is vacuously true
            return np.ones(nrows, dtype=bool), np.zeros(nrows, dtype=bool)
        iv = _row_intervals(st, nrows, rnb)
        if iv is None:
            z = np.zeros(nrows, dtype=bool)
            return z, z.copy()
        mn, mx, has_nan, has_num = iv
        v_dn, v_up, exact = _round2(self.value)
        op = self.op
        if op == "eq":
            dt_num = (mn == v_dn) & (mx == v_dn) if exact \
                else np.zeros(nrows, dtype=bool)
            df_num = (mx < v_dn) | (mn > v_up)
        elif op == "ne":
            dt_num = (mx < v_dn) | (mn > v_up)
            df_num = (mn == v_dn) & (mx == v_dn) if exact \
                else np.zeros(nrows, dtype=bool)
        elif op == "lt":
            dt_num, df_num = mx < v_dn, mn >= v_up
        elif op == "le":
            dt_num, df_num = mx <= v_dn, mn > v_up
        elif op == "gt":
            dt_num, df_num = mn > v_up, mx <= v_dn
        else:  # ge
            dt_num, df_num = mn >= v_up, mx < v_dn
        nan_true = op == "ne"  # IEEE-754: NaN fails everything but !=
        dt = (~has_num | dt_num) & (True if nan_true else ~has_nan)
        df = (~has_num | df_num) & (~has_nan if nan_true else True)
        return dt, df


class IsNan(Expr):
    """Leaf ``col(field).isnan()`` — row-true iff every element is NaN."""

    def __init__(self, field: str):
        self.field = field

    def __repr__(self):
        return f"col({self.field!r}).isnan()"

    def fields(self) -> Set[str]:
        return {self.field}

    def mask(self, batch):
        a = batch[self.field]
        m = np.isnan(a) if a.dtype.kind == "f" else np.zeros(a.shape, bool)
        if m.ndim > 1:
            m = m.all(axis=tuple(range(1, m.ndim)))
        return np.asarray(m, dtype=bool)

    def row_verdicts(self, nrows, field_info):
        st, rnb = field_info[self.field]
        if rnb <= 0:
            return np.ones(nrows, dtype=bool), np.zeros(nrows, dtype=bool)
        iv = _row_intervals(st, nrows, rnb)
        if iv is None:
            z = np.zeros(nrows, dtype=bool)
            return z, z.copy()
        _, _, has_nan, has_num = iv
        return ~has_num, ~has_nan


class And(Expr):
    """Conjunction of two row predicates."""

    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def __repr__(self):
        return f"({self.a!r} & {self.b!r})"

    def fields(self):
        return self.a.fields() | self.b.fields()

    def mask(self, batch):
        return self.a.mask(batch) & self.b.mask(batch)

    def row_verdicts(self, nrows, field_info):
        dta, dfa = self.a.row_verdicts(nrows, field_info)
        dtb, dfb = self.b.row_verdicts(nrows, field_info)
        return dta & dtb, dfa | dfb


class Or(Expr):
    """Disjunction of two row predicates."""

    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def __repr__(self):
        return f"({self.a!r} | {self.b!r})"

    def fields(self):
        return self.a.fields() | self.b.fields()

    def mask(self, batch):
        return self.a.mask(batch) | self.b.mask(batch)

    def row_verdicts(self, nrows, field_info):
        dta, dfa = self.a.row_verdicts(nrows, field_info)
        dtb, dfb = self.b.row_verdicts(nrows, field_info)
        return dta | dtb, dfa & dfb


class Not(Expr):
    """Negation of a row predicate (swaps the two verdict sides)."""

    def __init__(self, a: Expr):
        self.a = a

    def __repr__(self):
        return f"~{self.a!r}"

    def fields(self):
        return self.a.fields()

    def mask(self, batch):
        return ~self.a.mask(batch)

    def row_verdicts(self, nrows, field_info):
        dt, df = self.a.row_verdicts(nrows, field_info)
        return df, dt


class Col:
    """Named-field handle; comparison operators build :class:`Cmp` leaves."""

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"col({self.name!r})"

    def __eq__(self, other):  # type: ignore[override]
        return Cmp(self.name, "eq", other)

    def __ne__(self, other):  # type: ignore[override]
        return Cmp(self.name, "ne", other)

    def __lt__(self, other):
        return Cmp(self.name, "lt", other)

    def __le__(self, other):
        return Cmp(self.name, "le", other)

    def __gt__(self, other):
        return Cmp(self.name, "gt", other)

    def __ge__(self, other):
        return Cmp(self.name, "ge", other)

    def isnan(self) -> Expr:
        return IsNan(self.name)

    __hash__ = None  # == builds an Expr, so Col must not be hashable


def col(name: str) -> Col:
    """Start a predicate leaf: ``col("label") == 3`` (DESIGN.md §16)."""
    return Col(name)
