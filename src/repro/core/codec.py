"""Chunked parallel compression codec for RawArray payloads (DESIGN.md §10).

Whole-file zlib (``FLAG_ZLIB``) forces single-threaded decode and defeats
every partial-read path the format exists for. ``FLAG_CHUNKED`` fixes that
the way Zarr does: the payload is a sequence of *independently* compressed
chunks, followed by a trailer chunk table — so decode parallelizes chunk-
wise over the engine pool, and any logical byte range touches only the
chunks that overlap it (``read_slice`` / ``gather`` / remote ranged GETs
stay partial).

On-disk layout of a chunked file::

    header                      (flags has FLAG_CHUNKED;
                                 data_length = stored chunk-stream bytes)
    stored chunk 0..n-1         back-to-back compressed chunks
    chunk table                 see below
    metadata[...]               optional trailing user metadata
    crc32                       optional 4-byte file-level CRC (of the
                                stored chunk stream, FLAG_CRC32_TRAILER)

Chunk table wire format (all ``<u8``, introspectable with ``od -t u8``
exactly like the header — the paper's "trailer can be anything" clause)::

    u64 magic                   "rachunks" as little-endian ASCII
    u64 codec_id                registry code (0=raw, 1=zlib, 2=lz4, ...)
    u64 chunk_bytes             nominal raw chunk size (last may be short)
    u64 nchunks
    u64 entries[nchunks][4]     raw_offset, stored_offset, stored_len, crc32
                                (crc32 is of the *stored* chunk bytes, so
                                verification never needs to decompress)

Codec registry: numeric id + name -> (compress, decompress). zlib is always
present (stdlib); lz4 / zstd register themselves only when importable, so a
file written elsewhere with an unavailable codec fails with a clear error
instead of an ImportError. ``RA_CODEC`` picks the default codec name and
``RA_CHUNK_BYTES`` the default chunk size (1 MiB).

Module-level counters (``stats()`` / ``reset_stats()``) count every chunk
actually fetched + decompressed — the observable that proves partial reads
touch only overlapping chunks (surfaced via ``RaDataset.io_stats()``).
"""

from __future__ import annotations

import os
import threading
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from . import engine, layouts
from .spec import RawArrayError, env_int as _env_int, env_str as _env_str

CHUNK_MAGIC: int = layouts.CHUNK_TABLE.magic_int
TABLE_HEAD = layouts.CHUNK_TABLE.head_struct  # magic, codec_id, chunk_bytes, nchunks
TABLE_HEAD_BYTES = layouts.CHUNK_TABLE.head_bytes  # 32
ENTRY_BYTES = layouts.CHUNK_TABLE.entry_bytes  # 4 x u64 per chunk


def default_chunk_bytes() -> int:
    """Raw bytes per chunk (knob ``RA_CHUNK_BYTES``, default 1 MiB)."""
    return max(1 << 12, _env_int("RA_CHUNK_BYTES", 1 << 20))


def default_codec_name() -> str:
    """Default codec (knob ``RA_CODEC``)."""
    return _env_str("RA_CODEC", "zlib")


# ------------------------------------------------------------ codec registry
@dataclass(frozen=True)
class Codec:
    codec_id: int
    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]


_by_id: Dict[int, Codec] = {}
_by_name: Dict[str, Codec] = {}


def register_codec(
    codec_id: int,
    name: str,
    compress: Callable[[bytes], bytes],
    decompress: Callable[[bytes], bytes],
) -> Codec:
    """Add a codec to the registry (id is the on-disk code; keep them stable)."""
    c = Codec(codec_id, name, compress, decompress)
    _by_id[codec_id] = c
    _by_name[name] = c
    return c


def get_codec(key: Union[int, str, None]) -> Codec:
    """Resolve a codec by registry id, name, or ``None`` (the env default)."""
    if key is None:
        key = default_codec_name()
    c = _by_name.get(key) if isinstance(key, str) else _by_id.get(key)
    if c is None:
        known = ", ".join(f"{c.codec_id}={c.name}" for c in sorted(_by_id.values(), key=lambda c: c.codec_id))
        raise RawArrayError(
            f"unknown or unavailable codec {key!r} (registered: {known})"
        )
    return c


# Codecs take and return bytes-like objects (memoryview in, bytes-like
# out) so the hot path never makes defensive copies.
# id 0 reserved for "store": identity transform, useful for incompressible
# data where chunking still buys parallel + partial reads.
register_codec(0, "raw", lambda b: b, lambda b: b)
# zlib level 1: same speed/ratio point as the FLAG_ZLIB writer.
register_codec(1, "zlib", lambda b: zlib.compress(b, 1), zlib.decompress)
try:  # pragma: no cover - depends on container
    import lz4.frame as _lz4

    register_codec(2, "lz4", _lz4.compress, _lz4.decompress)
except ImportError:
    pass
try:  # pragma: no cover - depends on container
    import zstandard as _zstd

    register_codec(
        3, "zstd",
        lambda b: _zstd.ZstdCompressor().compress(b),
        lambda b: _zstd.ZstdDecompressor().decompress(b),
    )
except ImportError:
    pass
# lzma is stdlib: slow but always present; preset 0 keeps it usable.
try:  # pragma: no cover - lzma can be absent on minimal builds
    import lzma as _lzma

    register_codec(
        4, "lzma",
        lambda b: _lzma.compress(b, preset=0),
        _lzma.decompress,
    )
except ImportError:
    pass


# ------------------------------------------------------------- read counters
_stats_lock = threading.Lock()
# Audit note (ralint guarded-by): every _count/stats/reset_stats access was
# already under _stats_lock when audited; the annotation locks that in.
_stats = {"chunk_reads": 0, "chunk_stored_bytes": 0, "chunk_raw_bytes": 0}  # guarded-by: _stats_lock


def _count(stored: int, raw: int) -> None:
    with _stats_lock:
        _stats["chunk_reads"] += 1
        _stats["chunk_stored_bytes"] += stored
        _stats["chunk_raw_bytes"] += raw


def stats() -> Dict[str, int]:
    """Process-wide chunk decode counters (chunks fetched+decompressed)."""
    with _stats_lock:
        return dict(_stats)


def reset_stats() -> None:
    with _stats_lock:
        for k in _stats:
            _stats[k] = 0


# --------------------------------------------------------------- chunk table
@dataclass(frozen=True)
class ChunkTable:
    """Decoded trailer chunk table of one chunked file."""

    codec_id: int
    chunk_bytes: int
    raw_offsets: np.ndarray     # <u8 [n], raw_offsets[0] == 0, increasing
    stored_offsets: np.ndarray  # <u8 [n], relative to start of data segment
    stored_lens: np.ndarray     # <u8 [n]
    crcs: np.ndarray            # <u8 [n], CRC32 of each *stored* chunk

    @property
    def nchunks(self) -> int:
        return len(self.raw_offsets)

    @property
    def nbytes(self) -> int:
        """Encoded table size on disk."""
        return TABLE_HEAD_BYTES + ENTRY_BYTES * self.nchunks

    @property
    def stored_nbytes(self) -> int:
        if not self.nchunks:
            return 0
        return int(self.stored_offsets[-1] + self.stored_lens[-1])

    def raw_len(self, i: int, logical_nbytes: int) -> int:
        """Raw (decompressed) size of chunk ``i``; the last chunk may be short."""
        end = (
            int(self.raw_offsets[i + 1])
            if i + 1 < self.nchunks
            else logical_nbytes
        )
        return end - int(self.raw_offsets[i])

    def overlapping(self, raw_start: int, raw_stop: int, logical_nbytes: int) -> range:
        """Chunk indices whose raw span intersects [raw_start, raw_stop)."""
        if raw_stop <= raw_start or not self.nchunks:
            return range(0)
        raw_start = max(0, raw_start)
        raw_stop = min(raw_stop, logical_nbytes)
        i0 = int(np.searchsorted(self.raw_offsets, raw_start, side="right")) - 1
        i1 = int(np.searchsorted(self.raw_offsets, raw_stop, side="left"))
        return range(max(0, i0), min(self.nchunks, i1))

    def encode(self) -> bytes:
        head = TABLE_HEAD.pack(CHUNK_MAGIC, self.codec_id, self.chunk_bytes, self.nchunks)
        if not self.nchunks:
            return head
        body = np.column_stack(
            [self.raw_offsets, self.stored_offsets, self.stored_lens, self.crcs]
        ).astype("<u8")
        return head + body.tobytes()

    @classmethod
    def decode(cls, buf: bytes, *, logical_nbytes: int, stored_nbytes: int) -> "ChunkTable":
        """Parse + validate a table from ``buf`` (which may hold extra tail
        bytes — metadata, CRC — after the entries)."""
        if len(buf) < TABLE_HEAD_BYTES:
            raise RawArrayError("chunked flag set but chunk table missing/truncated")
        magic, codec_id, chunk_bytes, n = TABLE_HEAD.unpack(buf[:TABLE_HEAD_BYTES])
        if magic != CHUNK_MAGIC:
            raise RawArrayError(
                f"bad chunk-table magic {magic:#018x} (expected 'rachunks')"
            )
        if n > max(1, logical_nbytes):
            raise RawArrayError(
                f"chunk table claims {n} chunks for a {logical_nbytes}-byte payload"
            )
        need = TABLE_HEAD_BYTES + ENTRY_BYTES * n
        if len(buf) < need:
            raise RawArrayError(
                f"truncated chunk table: wanted {need} bytes, got {len(buf)}"
            )
        cols = np.frombuffer(
            buf, dtype="<u8", count=4 * n, offset=TABLE_HEAD_BYTES
        ).reshape(n, 4)
        t = cls(
            codec_id=int(codec_id),
            chunk_bytes=int(chunk_bytes),
            raw_offsets=cols[:, 0].copy(),
            stored_offsets=cols[:, 1].copy(),
            stored_lens=cols[:, 2].copy(),
            crcs=cols[:, 3].copy(),
        )
        t._validate(logical_nbytes, stored_nbytes)
        return t

    def _validate(self, logical_nbytes: int, stored_nbytes: int) -> None:
        n = self.nchunks
        if n == 0:
            if logical_nbytes or stored_nbytes:
                raise RawArrayError(
                    f"empty chunk table for a {logical_nbytes}-byte payload"
                )
            return
        if int(self.raw_offsets[0]) != 0 or int(self.stored_offsets[0]) != 0:
            raise RawArrayError("chunk table does not start at offset 0")
        if n > 1 and not (np.diff(self.raw_offsets.astype(np.int64)) > 0).all():
            raise RawArrayError("chunk table raw offsets not strictly increasing")
        ends = self.stored_offsets + self.stored_lens
        if n > 1 and (self.stored_offsets[1:] < ends[:-1]).any():
            raise RawArrayError("chunk table stored spans overlap")
        if int(self.raw_offsets[-1]) >= logical_nbytes:
            raise RawArrayError("chunk table raw offsets exceed the logical size")
        if int(ends[-1]) != stored_nbytes:
            raise RawArrayError(
                f"chunk table stored size {int(ends[-1])} != data_length {stored_nbytes}"
            )


# ----------------------------------------------------------------- compress
def compress_chunked(
    payload,
    *,
    codec: Union[int, str, None] = None,
    chunk_bytes: Optional[int] = None,
) -> Tuple[List[bytes], ChunkTable]:
    """Chunk-split ``payload`` and compress every chunk concurrently on the
    engine pool. Returns ``(stored_parts, table)`` where ``stored_parts[i]``
    is chunk ``i``'s compressed bytes-like object (write them back-to-back,
    then the encoded table; the store codec returns zero-copy views into
    ``payload``)."""
    c = get_codec(codec)
    cbytes = default_chunk_bytes() if chunk_bytes is None else chunk_bytes
    if cbytes < 1:
        raise RawArrayError(f"chunk_bytes must be positive, got {cbytes}")
    mv = payload if isinstance(payload, memoryview) else memoryview(payload)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    total = mv.nbytes
    n = (total + cbytes - 1) // cbytes
    parts: List[Optional[bytes]] = [None] * n

    def job(i: int) -> None:
        a = i * cbytes
        b = min(a + cbytes, total)
        parts[i] = c.compress(mv[a:b])

    engine.run_tasks([(lambda i=i: job(i)) for i in range(n)])
    raw_offs = np.arange(n, dtype="<u8") * cbytes
    lens = np.array([len(p) for p in parts], dtype="<u8")
    stored_offs = np.zeros(n, dtype="<u8")
    if n:
        stored_offs[1:] = np.cumsum(lens)[:-1]
    crcs = np.array([zlib.crc32(p) for p in parts], dtype="<u8")
    table = ChunkTable(
        codec_id=c.codec_id,
        chunk_bytes=cbytes,
        raw_offsets=raw_offs,
        stored_offsets=stored_offs,
        stored_lens=lens,
        crcs=crcs,
    )
    return [p for p in parts], table


class ChunkStreamCompressor:
    """Incremental chunk compression for the streaming write plane
    (DESIGN.md §11).

    ``RaWriter`` feeds raw payload bytes in arbitrary-sized pieces; every
    complete ``chunk_bytes`` window is compressed (one parallel engine wave
    per feed) and handed back as stored parts to append, so compression
    overlaps ingest instead of waiting for the full array. Chunk boundaries
    fall at absolute multiples of ``chunk_bytes`` of the logical payload —
    exactly where ``compress_chunked`` puts them — which is what makes a
    streamed file byte-identical to a monolithic ``io.write``.
    """

    def __init__(
        self,
        codec: Union[int, str, None] = None,
        chunk_bytes: Optional[int] = None,
        stats_dtype=None,
    ):
        self._codec = get_codec(codec)
        self._cbytes = default_chunk_bytes() if chunk_bytes is None else int(chunk_bytes)
        if self._cbytes < 1:
            raise RawArrayError(f"chunk_bytes must be positive, got {self._cbytes}")
        self._buf = bytearray()
        self._raw_offs: List[int] = []
        self._lens: List[int] = []
        self._crcs: List[int] = []
        self._raw_consumed = 0  # raw bytes already turned into stored chunks
        # per-chunk statistics (DESIGN.md §16) accumulate as raw bytes stream
        # through, so stats cost no extra pass over the payload
        if stats_dtype is not None:
            from . import stats as _stats_mod

            self._stats_acc = _stats_mod.StatsAccumulator(stats_dtype, self._cbytes)
        else:
            self._stats_acc = None

    @property
    def codec_id(self) -> int:
        return self._codec.codec_id

    @property
    def chunk_bytes(self) -> int:
        return self._cbytes

    def _compress(self, mv: memoryview) -> List[bytes]:
        """Compress ``mv`` chunk-parallel (chunk boundaries at multiples of
        ``chunk_bytes`` within ``mv``; callers guarantee ``mv`` itself starts
        on a chunk boundary of the logical payload)."""
        cb = self._cbytes
        n = (mv.nbytes + cb - 1) // cb
        out: List[Optional[bytes]] = [None] * n
        c = self._codec

        def job(i: int) -> None:
            a = i * cb
            b = min(a + cb, mv.nbytes)
            p = c.compress(mv[a:b])
            # the store codec returns a view into our (mutable, soon-recycled)
            # staging buffer — detach it
            out[i] = p if isinstance(p, bytes) else bytes(p)

        engine.run_tasks([(lambda i=i: job(i)) for i in range(n)])
        for i, p in enumerate(out):
            self._raw_offs.append(self._raw_consumed)
            self._raw_consumed += min(cb, mv.nbytes - i * cb)
            self._lens.append(len(p))
            self._crcs.append(zlib.crc32(p))
        return out  # type: ignore[return-value]

    def feed(self, data) -> List[bytes]:
        """Consume a piece of raw payload; returns the stored parts of every
        chunk completed by it (append them to the file in order)."""
        mv = data if isinstance(data, memoryview) else memoryview(data)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
        if self._stats_acc is not None:
            self._stats_acc.feed(mv)
        parts: List[bytes] = []
        cb = self._cbytes
        if not self._buf and mv.nbytes >= cb:
            # fast path: full chunks compress straight out of the caller's
            # buffer, no staging copy
            nfull = (mv.nbytes // cb) * cb
            parts += self._compress(mv[:nfull])
            mv = mv[nfull:]
        if mv.nbytes:
            self._buf += mv
            if len(self._buf) >= cb:
                nfull = (len(self._buf) // cb) * cb
                staged = memoryview(self._buf)[:nfull]
                parts += self._compress(staged)
                staged.release()
                del self._buf[:nfull]
        return parts

    def flush(self) -> List[bytes]:
        """Compress the final short chunk (if any buffered bytes remain)."""
        if not self._buf:
            return []
        staged = memoryview(self._buf)
        parts = self._compress(staged)
        staged.release()
        self._buf = bytearray()
        return parts

    def table(self) -> ChunkTable:
        """The trailer chunk table for everything fed so far (call after
        ``flush``)."""
        n = len(self._lens)
        lens = np.array(self._lens, dtype="<u8")
        stored = np.zeros(n, dtype="<u8")
        if n:
            stored[1:] = np.cumsum(lens)[:-1]
        return ChunkTable(
            codec_id=self._codec.codec_id,
            chunk_bytes=self._cbytes,
            raw_offsets=np.array(self._raw_offs, dtype="<u8"),
            stored_offsets=stored,
            stored_lens=lens,
            crcs=np.array(self._crcs, dtype="<u8"),
        )

    def chunk_stats(self):
        """The accumulated per-chunk statistics (DESIGN.md §16), or ``None``
        when the compressor was built without ``stats_dtype``. Call after
        ``flush`` so the final short chunk is included."""
        if self._stats_acc is None:
            return None
        return self._stats_acc.finish()


# ------------------------------------------------------------------- decode
def _src_size(src) -> Optional[int]:
    """Total byte size of a positioned-read source when cheaply knowable
    (fstat for fds, ``.size`` for remote readers)."""
    if isinstance(src, int):
        try:
            return os.fstat(src).st_size
        except OSError:
            return None
    size = getattr(src, "size", None)
    return size if isinstance(size, int) else None


def table_nbytes(src, hdr) -> int:
    """Encoded table size of a chunked file without parsing the entries —
    one 32-byte positioned read of the table head (``src`` is an int fd or
    any ``engine.pread_into`` source, e.g. a ``RemoteReader``)."""
    base = hdr.nbytes + hdr.data_length
    head = bytearray(TABLE_HEAD_BYTES)
    engine.pread_into(src, base, head)
    magic, _, _, n = TABLE_HEAD.unpack(bytes(head))
    if magic != CHUNK_MAGIC:
        raise RawArrayError("chunked flag set but chunk table magic missing")
    if n > max(1, hdr.logical_nbytes):
        raise RawArrayError(
            f"chunk table claims {n} chunks for a {hdr.logical_nbytes}-byte payload"
        )
    # bound by the bytes actually present: a corrupted count must fail fast,
    # not allocate gigabytes before discovering the entries aren't there
    size = _src_size(src)
    if size is not None and TABLE_HEAD_BYTES + ENTRY_BYTES * n > size - base:
        raise RawArrayError(
            f"truncated chunk table: {n} chunks need "
            f"{TABLE_HEAD_BYTES + ENTRY_BYTES * n} bytes, file has {max(0, size - base)}"
        )
    return TABLE_HEAD_BYTES + ENTRY_BYTES * n


def read_table(src, hdr) -> ChunkTable:
    """Read + validate the chunk table of a chunked file: two small
    positioned reads (head, then entries), so a remote source costs at most
    two ranged GETs — never the payload."""
    base = hdr.nbytes + hdr.data_length
    size = table_nbytes(src, hdr)
    buf = bytearray(size)
    try:
        engine.pread_into(src, base, buf)
    except RawArrayError as e:
        raise RawArrayError(f"truncated chunk table: {e}") from None
    return ChunkTable.decode(
        bytes(buf), logical_nbytes=hdr.logical_nbytes, stored_nbytes=hdr.data_length
    )


def _decode_chunk(src, hdr, table: ChunkTable, c: Codec, i: int):
    """Fetch + CRC-check + decompress chunk ``i``. Returns the raw
    bytes-like payload (NB: the store codec returns a fresh bytes copy so
    the result never aliases recycled scratch)."""
    rlen = table.raw_len(i, hdr.logical_nbytes)
    so = int(table.stored_offsets[i])
    slen = int(table.stored_lens[i])
    scratch = engine.acquire_scratch(slen)
    try:
        stored = memoryview(scratch)[:slen]
        engine.pread_into(src, hdr.nbytes + so, stored)
        if zlib.crc32(stored) != int(table.crcs[i]):
            raise RawArrayError(f"chunk {i} CRC32 mismatch: stored bytes corrupted")
        raw = c.decompress(stored)
        if raw is stored:  # store codec: detach from scratch before recycling
            raw = bytes(stored)
    finally:
        engine.release_scratch(scratch)
    if len(raw) != rlen:
        raise RawArrayError(
            f"chunk {i} decompressed to {len(raw)} bytes, table wants {rlen}"
        )
    _count(slen, rlen)
    return raw


def chunk_read_tasks(
    src,
    hdr,
    table: ChunkTable,
    raw_start: int,
    raw_stop: int,
    dst,
) -> List[Callable[[], None]]:
    """Plan a partial decode: one zero-arg task per chunk overlapping the
    logical byte range [raw_start, raw_stop), each fetching the stored chunk
    (positioned read on ``src`` — fd or remote reader), verifying its CRC32,
    decompressing, and copying the overlapping part into ``dst`` (a writable
    byte view of exactly ``raw_stop - raw_start`` bytes). Run them with
    ``engine.run_tasks`` — possibly merged with other shards' tasks into one
    wave."""
    mv = dst if isinstance(dst, memoryview) else memoryview(dst)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    if mv.nbytes != raw_stop - raw_start:
        raise RawArrayError(
            f"chunk decode: dst holds {mv.nbytes} bytes for range "
            f"[{raw_start}, {raw_stop})"
        )
    c = get_codec(table.codec_id)
    logical = hdr.logical_nbytes

    def job(i: int) -> None:
        raw = _decode_chunk(src, hdr, table, c, i)
        ro = int(table.raw_offsets[i])
        rlen = table.raw_len(i, logical)
        a, b = max(raw_start, ro), min(raw_stop, ro + rlen)
        mv[a - raw_start : b - raw_start] = memoryview(raw)[a - ro : b - ro]

    return [
        (lambda i=i: job(i))
        for i in table.overlapping(raw_start, raw_stop, logical)
    ]


def decompress_into(src, hdr, table: ChunkTable, dst) -> None:
    """Full parallel decode of a chunked payload into ``dst`` (a writable
    byte view of ``hdr.logical_nbytes`` bytes): one engine wave, each task
    fetch+verify+decompress of one chunk."""
    engine.run_tasks(chunk_read_tasks(src, hdr, table, 0, hdr.logical_nbytes, dst))


def gather_rows_tasks(
    src,
    hdr,
    table: ChunkTable,
    row_nbytes: int,
    rows: np.ndarray,
    positions: np.ndarray,
    dst,
) -> List[Callable[[], None]]:
    """Plan a scattered row gather over a chunked payload: decode each
    needed chunk EXACTLY ONCE, scattering every requested row (or the part
    of it the chunk covers — rows may straddle chunk boundaries) into
    ``dst`` at ``positions[k] * row_nbytes``. Without this, per-run chunk
    decodes re-decompress the same chunk once per sparse row — O(batch)
    decompressions of O(chunk) bytes each.

    ``rows`` are local row indices (any order, duplicates fine),
    ``positions[k]`` the destination row slot for ``rows[k]``, ``dst`` a
    writable byte view with row ``p`` at ``[p*row_nbytes, (p+1)*row_nbytes)``.
    """
    mv = dst if isinstance(dst, memoryview) else memoryview(dst)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    if row_nbytes == 0 or len(rows) == 0:
        return []
    c = get_codec(table.codec_id)
    logical = hdr.logical_nbytes
    starts = np.asarray(rows, dtype=np.int64) * row_nbytes
    pos = np.asarray(positions, dtype=np.int64)
    # chunk span of each row: raw_offsets is sorted, rows may straddle
    c0 = np.searchsorted(table.raw_offsets, starts, side="right") - 1
    c1 = np.searchsorted(table.raw_offsets, starts + row_nbytes, side="left")
    by_chunk: Dict[int, List[int]] = {}
    for k in range(len(starts)):
        for ci in range(int(c0[k]), int(c1[k])):
            by_chunk.setdefault(ci, []).append(k)

    def job(ci: int, ks: List[int]) -> None:
        raw = _decode_chunk(src, hdr, table, c, ci)
        ro = int(table.raw_offsets[ci])
        rend = ro + table.raw_len(ci, logical)
        rawmv = memoryview(raw)
        for k in ks:
            a = max(ro, int(starts[k]))
            b = min(rend, int(starts[k]) + row_nbytes)
            d0 = int(pos[k]) * row_nbytes + (a - int(starts[k]))
            mv[d0 : d0 + (b - a)] = rawmv[a - ro : b - ro]

    return [(lambda ci=ci, ks=ks: job(ci, ks)) for ci, ks in by_chunk.items()]
