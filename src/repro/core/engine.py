"""Parallel chunked I/O engine (DESIGN.md §8).

The RawArray layout is a fixed-size numeric header followed by one linear
data segment, so the byte range of *any* sub-array is pure offset
arithmetic.  This module turns that property into wall-clock wins: it
chunk-splits byte ranges into aligned slabs and issues concurrent
``os.pread``/``os.pwrite`` calls from a process-wide reusable thread pool
(the kernel copies run with the GIL released), and it plans coalesced
ranged reads for scattered row gathers.

Primitives (all take raw file descriptors so positioned I/O never races a
shared file offset):

* ``pread_into(fd, offset, view)``   — short-read-safe positioned read
* ``pwrite_from(fd, offset, view)``  — short-write-safe positioned write
* ``parallel_read_into(fd, offset, view)`` — slab-parallel read
* ``parallel_read_spans(jobs)``      — one pool wave over many (fd, off, view)
* ``parallel_write(fd, offset, views)`` — slab-parallel write of a view train
* ``coalesce(indices)``              — merge near-adjacent rows into ranged reads
* ``acquire_scratch / release_scratch`` — reusable (pre-faulted) bounce buffers

Everything degrades to plain sequential I/O below ``parallel_min`` bytes,
when the pool would have one worker, when ``RA_IO_SEQUENTIAL=1``, or when
already running *on* an engine worker thread (nested parallelism would
deadlock a bounded pool; the outer level already owns the concurrency).

Env knobs (read at call time so tests/benches can flip them):

=====================  ========================================  =========
variable               meaning                                   default
=====================  ========================================  =========
``RA_IO_WORKERS``      pool width                                2 x cores (<= 8)
``RA_IO_CHUNK``        slab size in bytes                        8 MiB
``RA_IO_PARALLEL_MIN`` below this many bytes stay sequential     4 MiB
``RA_IO_SEQUENTIAL``   "1" forces the sequential path            off
``RA_IO_GATHER_GAP``   max missing rows merged into one read     1
``RA_IO_GATHER_RUN``   min rows for a coalesced ranged read      4
=====================  ========================================  =========
"""

from __future__ import annotations

import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from .spec import RawArrayError, env_int as _env_int, env_str as _env_str

# Indirection points so tests can inject short reads/writes.
_preadv = os.preadv
_pwritev = os.pwritev

_THREAD_PREFIX = "ra-io"


def workers() -> int:
    return max(1, _env_int("RA_IO_WORKERS", min(8, 2 * (os.cpu_count() or 1))))


def chunk_bytes() -> int:
    return max(1 << 16, _env_int("RA_IO_CHUNK", 8 << 20))


def parallel_min() -> int:
    return max(0, _env_int("RA_IO_PARALLEL_MIN", 4 << 20))


def gather_gap() -> int:
    return max(0, _env_int("RA_IO_GATHER_GAP", 1))


def gather_min_run() -> int:
    return max(2, _env_int("RA_IO_GATHER_RUN", 4))


def sequential_forced() -> bool:
    return _env_str("RA_IO_SEQUENTIAL") == "1"


# --------------------------------------------------------------------- pool
_pool: Optional[ThreadPoolExecutor] = None  # guarded-by: _pool_lock
_pool_width = 0  # guarded-by: _pool_lock
_pool_lock = threading.Lock()


def get_pool() -> ThreadPoolExecutor:
    """Process-wide reusable executor (created lazily, resized on demand)."""
    global _pool, _pool_width
    w = workers()
    with _pool_lock:
        if _pool is None or _pool_width < w:
            if _pool is not None:
                _pool.shutdown(wait=False)
            _pool = ThreadPoolExecutor(max_workers=w, thread_name_prefix=_THREAD_PREFIX)
            _pool_width = w
        return _pool


def _reset_pool_after_fork() -> None:  # the child must not reuse parent threads
    global _pool, _pool_width
    # At-fork child handler: exactly one thread exists in the child, and
    # taking the lock here could deadlock on a parent thread's hold
    # snapshotted by fork.
    _pool = None     # ralint: allow=guarded-by -- single-threaded at-fork child
    _pool_width = 0  # ralint: allow=guarded-by -- single-threaded at-fork child


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reset_pool_after_fork)


def on_engine_thread() -> bool:
    return threading.current_thread().name.startswith(_THREAD_PREFIX)


def _parallel_ok(nbytes: int) -> bool:
    return (
        nbytes >= parallel_min()
        and workers() > 1
        and not sequential_forced()
        and not on_engine_thread()
    )


def run_tasks(tasks: Sequence[Callable[[], None]]) -> None:
    """Run callables on the shared pool; re-raise the first failure."""
    if not tasks:
        return
    if len(tasks) == 1 or workers() == 1 or sequential_forced() or on_engine_thread():
        for t in tasks:
            t()
        return
    futures = [get_pool().submit(t) for t in tasks]
    err = None
    for f in futures:
        try:
            f.result()
        except BaseException as e:  # drain all futures before raising
            err = err or e
    if err is not None:
        raise err


# ------------------------------------------------------------ positioned I/O
def _writable_byte_view(view) -> memoryview:
    mv = view if isinstance(view, memoryview) else memoryview(view)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    return mv


def pread_into(fd, offset: int, view) -> int:
    """Read ``len(view)`` bytes at ``offset`` into ``view`` (short-read loop).

    ``fd`` is either an ``int`` file descriptor or any object exposing
    ``pread_into(offset, view)`` — e.g. ``repro.remote.RemoteReader`` — so
    every slab/span/gather plan in this module works unchanged over
    non-local sources."""
    mv = _writable_byte_view(view)
    if not isinstance(fd, int):
        return fd.pread_into(offset, mv)
    want = mv.nbytes
    got = 0
    while got < want:
        n = _preadv(fd, [mv[got:]], offset + got)
        if n <= 0:
            raise RawArrayError(
                f"truncated read: wanted {want} bytes at offset {offset}, got {got}"
            )
        got += n
    return got


def pwrite_from(fd: int, offset: int, view) -> int:
    """Write all of ``view`` at ``offset`` (short-write loop)."""
    mv = view if isinstance(view, memoryview) else memoryview(view)
    if mv.format != "B" or mv.ndim != 1:
        mv = mv.cast("B")
    want = mv.nbytes
    put = 0
    while put < want:
        n = _pwritev(fd, [mv[put:]], offset + put)
        if n <= 0:
            raise OSError(f"short write at offset {offset + put}")
        put += n
    return put


def chunk_spans(offset: int, length: int, chunk: Optional[int] = None) -> List[Tuple[int, int]]:
    """Split [offset, offset+length) into slabs aligned to absolute multiples
    of ``chunk`` (so concurrent slabs never share a page-cache chunk)."""
    chunk = chunk or chunk_bytes()
    spans: List[Tuple[int, int]] = []
    pos = offset
    end = offset + length
    while pos < end:
        nxt = min(end, (pos // chunk + 1) * chunk)
        spans.append((pos, nxt - pos))
        pos = nxt
    return spans


def parallel_read_into(
    fd: int,
    offset: int,
    view,
    *,
    nworkers: Optional[int] = None,
    chunk: Optional[int] = None,
) -> int:
    """Fill ``view`` from ``fd`` at ``offset`` with slab-parallel preads.

    Falls back to one sequential positioned read below ``parallel_min`` or
    whenever parallelism is disabled. Returns bytes read; raises
    ``RawArrayError`` if the file ends early.
    """
    mv = _writable_byte_view(view)
    nbytes = mv.nbytes
    if nbytes == 0:
        return 0
    force = nworkers is not None and nworkers > 1
    if not force and (nworkers == 1 or not _parallel_ok(nbytes)):
        return pread_into(fd, offset, mv)
    spans = chunk_spans(offset, nbytes, chunk)
    if len(spans) == 1:
        return pread_into(fd, offset, mv)

    def job(span: Tuple[int, int]) -> None:
        off, ln = span
        rel = off - offset
        pread_into(fd, off, mv[rel : rel + ln])

    run_tasks([(lambda s=s: job(s)) for s in spans])
    return nbytes


class _SpanJob(NamedTuple):
    fd: object  # int fd or positioned-read object (see pread_into)
    offset: int
    view: memoryview


def _flatten_spans(jobs: Sequence[Tuple[object, int, object]]) -> Tuple[List[_SpanJob], int]:
    flat: List[_SpanJob] = []
    total = 0
    for fd, off, view in jobs:
        mv = _writable_byte_view(view)
        if mv.nbytes == 0:
            continue
        total += mv.nbytes
        for soff, sln in chunk_spans(off, mv.nbytes):
            rel = soff - off
            flat.append(_SpanJob(fd, soff, mv[rel : rel + sln]))
    return flat, total


def span_read_tasks(jobs: Sequence[Tuple[object, int, object]]) -> List[Callable[[], None]]:
    """Flatten (fd, offset, view) reads into slab-granular zero-arg tasks —
    the building blocks ``parallel_read_spans`` runs as one wave. Callers
    that also have non-pread work (e.g. chunk decode tasks, DESIGN.md §10)
    concatenate the lists and submit ONE ``run_tasks`` wave so both kinds
    of work share the pool with no barrier between them."""
    flat, _ = _flatten_spans(jobs)
    return [(lambda j=j: pread_into(j.fd, j.offset, j.view)) for j in flat]


def parallel_read_spans(jobs: Sequence[Tuple[object, int, object]]) -> int:
    """One pool wave over many (fd, offset, view) reads — possibly spanning
    multiple files (or remote readers; see ``pread_into``). Each large view
    is further slab-split; everything is submitted together so cross-file
    and intra-file parallelism share the same wave (no nested waiting)."""
    flat, total = _flatten_spans(jobs)
    if not flat:
        return 0
    if len(flat) == 1 or not _parallel_ok(total):
        for j in flat:
            pread_into(j.fd, j.offset, j.view)
        return total
    run_tasks([(lambda j=j: pread_into(j.fd, j.offset, j.view)) for j in flat])
    return total


def parallel_write(
    fd: int,
    offset: int,
    views: Sequence[object],
    *,
    nworkers: Optional[int] = None,
    chunk: Optional[int] = None,
) -> int:
    """Write ``views`` back-to-back starting at ``offset`` via slab-parallel
    pwrites. The caller should ``os.ftruncate`` the file to its final size
    first when extending (concurrent pwrite past EOF is fine on Linux, but a
    preallocated length avoids interleaved extension). Returns bytes written."""
    mvs = []
    total = 0
    for v in views:
        mv = v if isinstance(v, memoryview) else memoryview(v)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
        if mv.nbytes:
            mvs.append(mv)
            total += mv.nbytes
    if not total:
        return 0
    force = nworkers is not None and nworkers > 1
    if not force and (nworkers == 1 or not _parallel_ok(total)):
        pos = offset
        for mv in mvs:
            pwrite_from(fd, pos, mv)
            pos += mv.nbytes
        return total
    tasks = []
    pos = offset
    for mv in mvs:
        for soff, sln in chunk_spans(pos, mv.nbytes, chunk):
            rel = soff - pos
            tasks.append(
                lambda m=mv[rel : rel + sln], o=soff: pwrite_from(fd, o, m)
            )
        pos += mv.nbytes
    run_tasks(tasks)
    return total


# ------------------------------------------------------------- scratch pool
# Reusable bounce buffers for coalesced gathers. Reuse matters beyond malloc
# cost: a recycled buffer is already page-faulted, and on this class of
# kernel fault handling is the single-threaded bottleneck (see DESIGN.md §8).
_scratch_lock = threading.Lock()
_scratch_bufs: List[np.ndarray] = []  # guarded-by: _scratch_lock
_SCRATCH_KEEP = 16


def acquire_scratch(nbytes: int) -> np.ndarray:
    """Get a uint8 scratch array of at least ``nbytes`` (may be larger)."""
    with _scratch_lock:
        best = None
        for i, b in enumerate(_scratch_bufs):
            if b.nbytes >= nbytes and (best is None or b.nbytes < _scratch_bufs[best].nbytes):
                best = i
        if best is not None:
            return _scratch_bufs.pop(best)
    return np.empty(nbytes, np.uint8)


def release_scratch(buf: np.ndarray) -> None:
    with _scratch_lock:
        if len(_scratch_bufs) < _SCRATCH_KEEP:
            _scratch_bufs.append(buf)


# ---------------------------------------------------------------- coalesce
class Run(NamedTuple):
    """One coalesced ranged read: rows [lo, hi) serve ``sel`` (positions into
    the original index array)."""

    lo: int
    hi: int
    sel: np.ndarray  # positions into the caller's index array, sorted by row


def coalesce(
    indices: np.ndarray,
    *,
    gap: Optional[int] = None,
    min_run: Optional[int] = None,
) -> Tuple[List[Run], np.ndarray]:
    """Plan scattered row reads: merge adjacent/near-adjacent requests.

    ``indices`` may be unsorted and contain duplicates. Returns ``(runs,
    leftover)`` where each ``Run`` covers >= ``min_run`` requested rows whose
    sorted values have gaps <= ``gap`` (read amplification is bounded by
    ``gap + 1``), and ``leftover`` holds the positions of requests too sparse
    to be worth a ranged read (the caller services those point-wise).
    The union of all ``run.sel`` and ``leftover`` is exactly
    ``arange(len(indices))``.
    """
    indices = np.asarray(indices)
    if indices.size == 0:
        return [], np.empty(0, np.intp)
    order = np.argsort(indices, kind="stable")
    return coalesce_sorted(indices[order], order, gap=gap, min_run=min_run)


def coalesce_sorted(
    svals: np.ndarray,
    positions: np.ndarray,
    *,
    gap: Optional[int] = None,
    min_run: Optional[int] = None,
) -> Tuple[List[Run], np.ndarray]:
    """``coalesce`` for already-sorted row values (``positions[i]`` is where
    ``svals[i]`` lands in the caller's output). Fully vectorized so a sparse
    request (hundreds of singleton segments) costs one pass, not a Python
    loop per segment."""
    gap = gather_gap() if gap is None else gap
    min_run = gather_min_run() if min_run is None else min_run
    # break where the sorted row distance exceeds the merge gap (+1 = adjacent)
    brk = np.nonzero(np.diff(svals) > gap + 1)[0] + 1
    starts = np.concatenate([[0], brk])
    stops = np.concatenate([brk, [len(svals)]])
    lens = stops - starts
    dense = lens >= min_run
    if not dense.any():
        return [], positions
    runs = [
        Run(int(svals[a]), int(svals[b - 1]) + 1, positions[a:b])
        for a, b in zip(starts[dense], stops[dense])
    ]
    leftover = positions[~np.repeat(dense, lens)]
    return runs, leftover
