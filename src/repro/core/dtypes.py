"""Mapping between numpy dtypes and RawArray (eltype, elbyte) pairs
(DESIGN.md §1).

The paper's key type-system idea: *kind* and *width* are independent, so new
widths (f16, f128, 512-bit AVX lanes) need no format change. We register the
full numpy zoo plus ``ml_dtypes`` extended floats used by JAX on TPU.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .spec import (
    ELTYPE_BRAIN,
    ELTYPE_COMPLEX,
    ELTYPE_FLOAT,
    ELTYPE_INT,
    ELTYPE_STRUCT,
    ELTYPE_UINT,
    RawArrayError,
)

try:  # ml_dtypes ships with jax; guard anyway so core/ has no hard jax dep.
    import ml_dtypes

    _BFLOAT16 = np.dtype(ml_dtypes.bfloat16)
    _HAVE_ML_DTYPES = True
except ImportError:  # pragma: no cover - ml_dtypes is installed with jax
    _BFLOAT16 = None
    _HAVE_ML_DTYPES = False


def eltype_of(dtype: np.dtype) -> Tuple[int, int]:
    """Return ``(eltype, elbyte)`` for a numpy dtype."""
    dtype = np.dtype(dtype)
    if _HAVE_ML_DTYPES and dtype == _BFLOAT16:
        return ELTYPE_BRAIN, 2
    kind = dtype.kind
    if kind == "i":
        return ELTYPE_INT, dtype.itemsize
    if kind == "u":
        return ELTYPE_UINT, dtype.itemsize
    if kind == "f":
        return ELTYPE_FLOAT, dtype.itemsize
    if kind == "c":
        return ELTYPE_COMPLEX, dtype.itemsize
    if kind == "V" and dtype.itemsize > 0:  # structured / void records
        return ELTYPE_STRUCT, dtype.itemsize
    if kind == "b":
        # Bools ride as 1-byte unsigned — same bits, archival-safe.
        return ELTYPE_UINT, 1
    raise RawArrayError(f"dtype {dtype} has no RawArray element type")


def dtype_of(eltype: int, elbyte: int, *, big_endian: bool = False) -> np.dtype:
    """Return the numpy dtype for an ``(eltype, elbyte)`` pair."""
    order = ">" if big_endian else "<"
    if eltype == ELTYPE_INT:
        if elbyte in (1, 2, 4, 8):
            return np.dtype(f"{order}i{elbyte}")
    elif eltype == ELTYPE_UINT:
        if elbyte in (1, 2, 4, 8):
            return np.dtype(f"{order}u{elbyte}")
    elif eltype == ELTYPE_FLOAT:
        if elbyte in (2, 4, 8) or (elbyte == 16 and hasattr(np, "float128")):
            return np.dtype(f"{order}f{elbyte}")
    elif eltype == ELTYPE_COMPLEX:
        if elbyte in (8, 16):
            return np.dtype(f"{order}c{elbyte}")
    elif eltype == ELTYPE_BRAIN:
        if elbyte == 2 and _HAVE_ML_DTYPES:
            if big_endian:
                raise RawArrayError("big-endian bfloat16 unsupported by this reader")
            return _BFLOAT16
    elif eltype == ELTYPE_STRUCT:
        # Opaque records: caller reinterprets. We hand back void bytes.
        return np.dtype((np.void, elbyte))
    raise RawArrayError(
        f"unsupported element type: eltype={eltype} elbyte={elbyte}"
    )


def is_native_reinterpretable(dtype: np.dtype) -> bool:
    """True if the dtype can be memory-mapped without byte swapping."""
    dtype = np.dtype(dtype)
    return dtype.byteorder in ("=", "|", "<")
