"""Sharded RawArray stores — one logical array striped over N ``.ra`` files.

Beyond-paper extension (DESIGN.md §7): the paper's vision is "metadata as
human-readable markup, raw data in RawArray files, organized by a file
system directory structure". A sharded store is exactly that — a directory::

    <name>/
      index.json          {"shape": [...], "dtype": "float32",
                           "axis": 0, "offsets": [0, r0, r0+r1, ...],
                           "files": ["shard_00000.ra", ...]}
      shard_00000.ra      rows [offsets[0], offsets[1])
      shard_00001.ra      ...

Each shard is an independent, self-describing RawArray file, so shards can
be written in parallel by different hosts and read back under a *different*
slicing (elastic restore): ``read_slice`` touches only the shards that
overlap the requested row range, fanning the overlapping shards out over
the parallel I/O engine straight into one output buffer (DESIGN.md §8).

``dirpath`` may also be an ``http(s)://`` URL of a served shard directory
(DESIGN.md §9): the index is fetched over HTTP and every shard read becomes
engine-planned parallel byte-range requests through ``repro.remote`` —
the same wave structure, remote sources.

Writing is streaming-capable too (DESIGN.md §11): ``ShardedWriter`` feeds
row batches of unknown total count, auto-rolls shards at a size threshold
(``RA_SHARD_BYTES``), and publishes the index atomically at finalize.
"""

from __future__ import annotations

import json
import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import codec as chunked_codec
from . import engine
from . import io as raio
from .io import RaWriter, is_url, join_path as _join
from .stats import stats_supported
from .spec import FLAG_CHUNKED, RawArrayError, env_int as _env_int

INDEX_NAME = "index.json"


def default_shard_bytes() -> int:
    """Auto-roll threshold for ``ShardedWriter`` in raw payload bytes
    (knob ``RA_SHARD_BYTES``, default 256 MiB)."""
    return max(1, _env_int("RA_SHARD_BYTES", 256 << 20))


@dataclass(frozen=True)
class ShardIndex:
    shape: Tuple[int, ...]
    dtype: str
    axis: int
    offsets: Tuple[int, ...]  # len = nshards + 1, offsets[0] == 0
    files: Tuple[str, ...]

    def to_json(self) -> str:
        return json.dumps(
            {
                "format": "rawarray-sharded-v1",
                "shape": list(self.shape),
                "dtype": self.dtype,
                "axis": self.axis,
                "offsets": list(self.offsets),
                "files": list(self.files),
            },
            indent=1,
        )

    @classmethod
    def from_json(cls, text: str) -> "ShardIndex":
        d = json.loads(text)
        if d.get("format") != "rawarray-sharded-v1":
            raise RawArrayError(f"not a sharded RawArray index: {d.get('format')}")
        return cls(
            shape=tuple(d["shape"]),
            dtype=d["dtype"],
            axis=int(d["axis"]),
            offsets=tuple(d["offsets"]),
            files=tuple(d["files"]),
        )

    def rows_per_shard(self) -> Tuple[int, ...]:
        """Row count of each shard along the sharded axis — the unit of
        distribution for the data mesh's ownership map (DESIGN.md §15)."""
        return tuple(b - a for a, b in zip(self.offsets, self.offsets[1:]))

    def row_nbytes(self) -> int:
        """Bytes one row (one index along ``axis``) occupies on disk — index
        arithmetic only, no shard is opened."""
        per = 1
        for i, d in enumerate(self.shape):
            if i != self.axis:
                per *= int(d)
        return per * np.dtype(self.dtype).itemsize


def _shard_name(i: int) -> str:
    return f"shard_{i:05d}.ra"


def _decode_rows(path: str, a: int, b: int, dst) -> None:
    """Fallback for shards that are not range-addressable (whole-file zlib,
    big-endian): decode the shard and copy rows [a, b) into ``dst``."""
    arr = np.asarray(raio.read(path))
    rows = np.ascontiguousarray(arr[a:b])
    dst[:] = memoryview(rows.view(np.uint8).reshape(-1))


def write_sharded(
    dirpath: str,
    arr: np.ndarray,
    *,
    nshards: int,
    axis: int = 0,
    workers: int = 4,
    chunked: bool = False,
    codec: Optional[str] = None,
    chunk_bytes: Optional[int] = None,
    stats: Optional[bool] = None,
) -> ShardIndex:
    """Split ``arr`` along ``axis`` into ``nshards`` RawArray files.

    ``chunked=True`` (or ``codec=``/``chunk_bytes=``) writes every shard
    chunk-compressed (DESIGN.md §10); ``read_slice`` then decodes only the
    chunks overlapping the requested rows.

    ``stats`` controls the per-chunk ``rastats`` block (DESIGN.md §16);
    the default ``None`` auto-enables it for bool/int/float dtypes so
    predicate pushdown works out of the box."""
    if is_url(dirpath):
        raise RawArrayError(f"write_sharded is local-only; got URL {dirpath}")
    if stats is None:
        stats = stats_supported(np.asarray(arr).dtype)
    if axis != 0:
        arr = np.moveaxis(arr, axis, 0)
    n = arr.shape[0]
    nshards = max(1, min(nshards, n)) if n else 1
    bounds = np.linspace(0, n, nshards + 1).astype(int)
    os.makedirs(dirpath, exist_ok=True)
    files = [_shard_name(i) for i in range(nshards)]

    def _write(i: int) -> None:
        raio.write(
            os.path.join(dirpath, files[i]),
            arr[bounds[i] : bounds[i + 1]],
            chunked=chunked,
            codec=codec,
            chunk_bytes=chunk_bytes,
            stats=stats,
        )

    if workers > 1 and nshards > 1:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            list(pool.map(_write, range(nshards)))
    else:
        for i in range(nshards):
            _write(i)

    # index records the *original* (pre-moveaxis) logical shape
    logical_shape = list(arr.shape)
    if axis != 0:
        logical_shape.insert(axis, logical_shape.pop(0))
    idx = ShardIndex(
        shape=tuple(logical_shape),
        dtype=str(arr.dtype),
        axis=axis,
        offsets=tuple(int(b) for b in bounds),
        files=tuple(files),
    )
    with open(os.path.join(dirpath, INDEX_NAME), "w") as f:
        f.write(idx.to_json())
    return idx


class ShardedWriter:
    """Streaming sharded-store writer (DESIGN.md §11): feed row batches of
    unknown total count; shards auto-roll when the current shard's RAW
    payload reaches ``shard_bytes`` (knob ``RA_SHARD_BYTES``, or pass
    ``shard_rows`` for an exact row count per shard).

    Every shard is an incremental ``RaWriter`` — written to a temp file and
    atomically renamed at its roll, so a crash mid-stream leaves only whole,
    valid shards plus one invisible temp. The ``index.json`` is written LAST
    (also temp + rename): the store does not exist as a store until finalize
    succeeds. The result is readable by ``read_slice`` / ``read_sharded``
    and byte-identical, shard by shard, to ``io.write`` of each row slab
    with matching options (``stats`` defaults ON for numeric dtypes here,
    DESIGN.md §16, so pass ``stats=True`` to the monolithic write when
    byte-comparing).
    """

    def __init__(
        self,
        dirpath: str,
        dtype,
        row_shape: Tuple[int, ...],
        *,
        shard_bytes: Optional[int] = None,
        shard_rows: Optional[int] = None,
        crc32: bool = False,
        chunked: bool = False,
        codec: Optional[str] = None,
        chunk_bytes: Optional[int] = None,
        stats: Optional[bool] = None,
    ):
        if is_url(dirpath):
            raise RawArrayError(f"ShardedWriter is local-only; got URL {dirpath}")
        self.dirpath = dirpath
        self._dtype = np.dtype(dtype)
        if stats is None:  # default-on for numeric dtypes (DESIGN.md §16)
            stats = stats_supported(self._dtype)
        self._row_shape = tuple(int(d) for d in row_shape)
        row_nbytes = self._dtype.itemsize
        for d in self._row_shape:
            row_nbytes *= d
        if shard_rows is not None:
            self._shard_rows = max(1, int(shard_rows))
        else:
            nbytes = default_shard_bytes() if shard_bytes is None else max(1, shard_bytes)
            self._shard_rows = max(1, nbytes // row_nbytes) if row_nbytes else 1 << 30
        self._wkw = dict(crc32=crc32, chunked=chunked, codec=codec,
                         chunk_bytes=chunk_bytes, stats=stats)
        self._offsets: List[int] = [0]
        self._files: List[str] = []
        self._writer: Optional[RaWriter] = None
        self._writer_rows = 0
        self._state = "open"
        os.makedirs(dirpath, exist_ok=True)

    @property
    def rows(self) -> int:
        """Total rows written so far across all shards."""
        return self._offsets[-1] + self._writer_rows

    def _open_shard(self) -> RaWriter:
        if self._writer is None:
            fname = _shard_name(len(self._files))
            self._files.append(fname)
            self._writer = RaWriter(
                os.path.join(self.dirpath, fname),
                self._dtype, self._row_shape, **self._wkw,
            )
            self._writer_rows = 0
        return self._writer

    def _roll(self) -> None:
        self._writer.finalize()
        self._offsets.append(self._offsets[-1] + self._writer_rows)
        self._writer = None
        self._writer_rows = 0

    def write_rows(self, rows: np.ndarray) -> int:
        """Append a batch shaped ``(n, *row_shape)``, splitting it across
        shard boundaries; returns total rows so far."""
        if self._state != "open":
            raise RawArrayError(f"write_rows on a {self._state} ShardedWriter")
        a = np.asarray(rows)
        pos, n = 0, a.shape[0]
        while pos < n:
            w = self._open_shard()
            take = min(n - pos, self._shard_rows - self._writer_rows)
            w.write_rows(a[pos : pos + take])
            self._writer_rows += take
            pos += take
            if self._writer_rows >= self._shard_rows:
                self._roll()
        return self.rows

    def finalize(self) -> ShardIndex:
        """Seal the last shard and atomically publish ``index.json``.
        A store that never received rows still gets one (empty) shard, the
        same layout ``write_sharded`` produces for an empty array."""
        if self._state != "open":
            raise RawArrayError(f"finalize on a {self._state} ShardedWriter")
        if self._writer is not None:
            self._roll()
        if not self._files:  # zero rows: one empty shard, like write_sharded
            self._open_shard()
            self._roll()
        idx = ShardIndex(
            shape=(self._offsets[-1],) + self._row_shape,
            dtype=str(self._dtype),
            axis=0,
            offsets=tuple(self._offsets),
            files=tuple(self._files),
        )
        tmp = os.path.join(self.dirpath, INDEX_NAME + ".tmp")
        with open(tmp, "w") as f:
            f.write(idx.to_json())
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.dirpath, INDEX_NAME))
        self._state = "finalized"
        return idx

    def abort(self) -> None:
        """Drop the in-progress shard (finished shards and any existing
        index are left as they were; no index is written)."""
        if self._state == "open":
            self._state = "aborted"
            if self._writer is not None:
                self._writer.abort()
                self._writer = None

    def __enter__(self) -> "ShardedWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        elif self._state == "open":
            self.finalize()


def load_index(dirpath: str) -> ShardIndex:
    if is_url(dirpath):
        from .. import remote

        return ShardIndex.from_json(
            remote.fetch_bytes(_join(dirpath, INDEX_NAME)).decode()
        )
    with open(os.path.join(dirpath, INDEX_NAME)) as f:
        return ShardIndex.from_json(f.read())


def _stored_rest(idx: ShardIndex) -> Tuple[int, ...]:
    """Per-row shape of the on-disk (axis-moved-to-front) layout."""
    s = list(idx.shape)
    if idx.axis < len(s):
        s.pop(idx.axis)
    else:
        s = s[1:]
    return tuple(s)


def _empty_slice(idx: ShardIndex) -> np.ndarray:
    shape = list(idx.shape)
    if idx.axis < len(shape):
        shape[idx.axis] = 0
    else:
        shape = [0] + shape[1:]
    return np.empty(tuple(shape), dtype=np.dtype(idx.dtype))


def read_slice(
    dirpath: str,
    start: int,
    stop: int,
    index: Optional[ShardIndex] = None,
    *,
    out: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Read rows [start, stop) along the shard axis, touching only the shards
    that overlap — the elastic-restore primitive.

    Overlapping shards are read concurrently (one engine wave, DESIGN.md §8)
    straight into a single output buffer — no per-shard intermediate arrays
    and no ``np.concatenate``. Pass ``out`` (C-contiguous, the result's exact
    shape and dtype) to stream into a preallocated / reused destination.
    """
    idx = index or load_index(dirpath)
    start, stop = max(0, start), min(stop, idx.offsets[-1])
    if stop <= start:
        return _empty_slice(idx)
    nrows = stop - start
    rest = _stored_rest(idx)
    dtype = np.dtype(idx.dtype)
    stored_shape = (nrows,) + rest
    if idx.axis == 0 and out is not None:
        if tuple(out.shape) != stored_shape or out.dtype != dtype or not out.flags.c_contiguous:
            raise RawArrayError(
                f"read_slice: out must be C-contiguous {stored_shape} {dtype}, "
                f"got {out.shape} {out.dtype}"
            )
        stored = out
    else:
        stored = np.empty(stored_shape, dtype)
    row_nbytes = dtype.itemsize
    for d in rest:
        row_nbytes *= d
    mv = memoryview(stored.reshape(-1).view(np.uint8)).cast("B") if row_nbytes else None
    offs = idx.offsets
    overlaps = []  # (shard index, path, lo, a, b)
    for i, fname in enumerate(idx.files):
        lo, hi = offs[i], offs[i + 1]
        if hi <= start or lo >= stop:
            continue
        a, b = max(start, lo) - lo, min(stop, hi) - lo
        overlaps.append((i, _join(dirpath, fname), lo, a, b))
    # resolve shard headers (and, for chunked shards, their chunk tables +
    # sources) concurrently: remotely each one is an HTTP round trip, and
    # doing them serially would dominate wide slices' latency
    hdrs: dict = {}
    tables: dict = {}
    srcs: dict = {}  # chunked shards: fd / reader, opened once and reused
    fds: List[int] = []

    def _resolve(i: int, path: str) -> None:
        hdr = raio.header_of(path)
        hdrs[i] = hdr
        # big-endian chunked shards take the decode-and-copy fallback (the
        # chunk fast path would stream BE bytes into a native-LE buffer)
        if hdr.flags & FLAG_CHUNKED and not hdr.big_endian:
            if is_url(path):
                from .. import remote

                src = remote.get_reader(path)  # registry-pooled; not closed here
            else:
                src = os.open(path, os.O_RDONLY)
                fds.append(src)
            srcs[i] = src
            tables[i] = chunked_codec.read_table(src, hdr)

    jobs = []
    tasks = []  # chunk decodes + whole-shard decode fallbacks
    try:
        engine.run_tasks(
            [(lambda i=i, p=p: _resolve(i, p)) for i, p, _, _, _ in overlaps]
        )
        for i, path, lo, a, b in overlaps:
            hdr = hdrs[i]
            if hdr.shape[1:] != rest or hdr.shape[0] != offs[i + 1] - lo:
                raise RawArrayError(
                    f"{idx.files[i]}: shard shape {hdr.shape} inconsistent with index"
                )
            if row_nbytes == 0 or b == a:
                continue
            dst = mv[(lo + a - start) * row_nbytes : (lo + b - start) * row_nbytes]
            if i in srcs:
                tasks += chunked_codec.chunk_read_tasks(
                    srcs[i], hdr, tables[i], a * row_nbytes, b * row_nbytes, dst
                )
            elif hdr.compressed or hdr.big_endian:
                # whole-file zlib / big-endian: not range-addressable — decode
                # the shard on a pool thread and copy the requested rows
                tasks.append(lambda p=path, a=a, b=b, d=dst: _decode_rows(p, a, b, d))
            else:
                if is_url(path):
                    from .. import remote

                    src = remote.get_reader(path)  # registry-pooled; not closed here
                else:
                    src = os.open(path, os.O_RDONLY)
                    fds.append(src)
                jobs.append((src, hdr.nbytes + a * row_nbytes, dst))
        if tasks:  # one wave: slab preads + chunk decodes share the pool
            engine.run_tasks(engine.span_read_tasks(jobs) + tasks)
        else:
            engine.parallel_read_spans(jobs)
    finally:
        for fd in fds:
            os.close(fd)
    result = stored
    if idx.axis != 0:
        result = np.moveaxis(result.reshape((nrows,) + rest), 0, idx.axis)
        if out is not None:
            if tuple(out.shape) != result.shape or out.dtype != dtype:
                raise RawArrayError(
                    f"read_slice: out shape {out.shape} != result {result.shape}"
                )
            out[...] = result
            result = out
    return result


def read_slice_naive(
    dirpath: str, start: int, stop: int, index: Optional[ShardIndex] = None
) -> np.ndarray:
    """Reference single-stream implementation (mmap each overlapping shard,
    then concatenate; whole-shard reads + slicing when remote). Kept for
    equivalence tests and as the sequential baseline in
    ``benchmarks/bench_formats.py``."""
    idx = index or load_index(dirpath)
    start, stop = max(0, start), min(stop, idx.offsets[-1])
    if stop <= start:
        return _empty_slice(idx)
    pieces: List[np.ndarray] = []
    offs = idx.offsets
    for i, fname in enumerate(idx.files):
        lo, hi = offs[i], offs[i + 1]
        if hi <= start or lo >= stop:
            continue
        a, b = max(start, lo) - lo, min(stop, hi) - lo
        path = _join(dirpath, fname)
        if is_url(path) or raio.header_of(path).compressed:
            pieces.append(np.asarray(raio.read(path))[a:b])
        else:
            pieces.append(np.asarray(raio.memmap_slice(path, a, b)))
    out = pieces[0] if len(pieces) == 1 else np.concatenate(pieces, axis=0)
    if idx.axis != 0:
        out = np.moveaxis(out, 0, idx.axis)
    return out


def read_sharded(dirpath: str) -> np.ndarray:
    idx = load_index(dirpath)
    return read_slice(dirpath, 0, idx.offsets[-1], idx)
