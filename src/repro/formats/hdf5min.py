"""Minimal, structurally faithful HDF5 writer/reader (pure Python;
benchmark baseline DESIGN.md §6).

Offline container ⇒ no h5py/libhdf5, but the paper's headline claim is
"2–3× faster than HDF5", so we implement the baseline ourselves per the
reproduction rules. This module emits *real* HDF5 (format spec v0
structures): superblock v0, root group with cached symbol-table entry,
local heap, B-tree v1 group node, SNOD symbol nodes, version-1 object
headers carrying dataspace / datatype / contiguous-layout messages.

Two deliberate fidelity choices:

* The writer performs **one seek+write per file section** (superblock,
  object headers, heap, B-tree, SNOD, each data segment) instead of
  assembling one buffer — mirroring libhdf5's scattered metadata I/O,
  which is precisely the overhead the paper attributes HDF5's slowness to.
  (A buffered variant is available as ``write_datasets(..., buffered=True)``
  to separate "format structure cost" from "syscall cost" in benchmarks.)
* Group leaf-k is sized so a single SNOD holds all links (spec-legal for
  u16 k), avoiding a full B-tree split implementation; this *favors* HDF5
  in benchmarks, keeping the measured RawArray speedup conservative.

Supported dtypes: i1..i8, u1..u8, f4, f8 (little-endian), which covers the
paper's benchmarks.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

import numpy as np

SIGNATURE = b"\x89HDF\r\n\x1a\n"
UNDEF = 0xFFFFFFFFFFFFFFFF
SNOD_MAX = 32768  # symbols per SNOD (u16 count field)


def _align8(n: int) -> int:
    return (n + 7) & ~7


# ---------------------------------------------------------------- datatype
def _datatype_message(dtype: np.dtype) -> bytes:
    """Version-1 datatype message payload for fixed-point / IEEE float."""
    dtype = np.dtype(dtype)
    size = dtype.itemsize
    if dtype.kind in "iu":
        cls, ver = 0, 1
        bits0 = 0x08 if dtype.kind == "i" else 0x00  # signed bit
        header = ((ver << 4) | cls, bits0, 0, 0)
        props = struct.pack("<HH", 0, size * 8)  # bit offset, precision
    elif dtype.kind == "f":
        cls, ver = 1, 1
        # little-endian IEEE: byte order 0, sign location per width
        if size == 4:
            bits0, props = 0x20, struct.pack("<HHBBBBI", 0, 32, 23, 8, 0, 23, 127)
        elif size == 8:
            bits0, props = 0x20, struct.pack("<HHBBBBI", 0, 64, 52, 11, 0, 52, 1023)
        else:
            raise ValueError(f"hdf5min: unsupported float width {size}")
        header = ((ver << 4) | cls, bits0 | 0x00, 0x1F if size == 8 else 0x0F, 0)
    else:
        raise ValueError(f"hdf5min: unsupported dtype {dtype}")
    return struct.pack("<BBBBI", *header, size) + props


def _parse_datatype(buf: bytes) -> np.dtype:
    b0, bits0, _, _, size = struct.unpack_from("<BBBBI", buf, 0)
    cls = b0 & 0x0F
    if cls == 0:
        return np.dtype(f"<{'i' if bits0 & 0x08 else 'u'}{size}")
    if cls == 1:
        return np.dtype(f"<f{size}")
    raise ValueError(f"hdf5min: unsupported datatype class {cls}")


# ---------------------------------------------------------------- messages
def _dataspace_message(shape: Tuple[int, ...]) -> bytes:
    body = struct.pack("<BBBB4x", 1, len(shape), 0, 0)
    body += b"".join(struct.pack("<Q", d) for d in shape)
    return body


def _layout_message(addr: int, nbytes: int) -> bytes:
    # version 3, class 1 (contiguous)
    return struct.pack("<BBQQ", 3, 1, addr, nbytes)


def _symtab_message(btree_addr: int, heap_addr: int) -> bytes:
    return struct.pack("<QQ", btree_addr, heap_addr)


def _message(mtype: int, body: bytes) -> bytes:
    body_p = body + b"\x00" * (_align8(len(body)) - len(body))
    return struct.pack("<HHBBBB", mtype, len(body_p), 0, 0, 0, 0) + body_p


def _object_header(messages: List[Tuple[int, bytes]]) -> bytes:
    msgs = b"".join(_message(t, b) for t, b in messages)
    return struct.pack("<BBHII4x", 1, 0, len(messages), 1, len(msgs)) + msgs


def _parse_object_header(data: bytes, off: int) -> Dict[int, bytes]:
    ver, _, nmsgs, _refcnt, hsize = struct.unpack_from("<BBHII", data, off)
    if ver != 1:
        raise ValueError("hdf5min: only v1 object headers supported")
    pos = off + 16
    out: Dict[int, bytes] = {}
    for _ in range(nmsgs):
        mtype, msize, _flags = struct.unpack_from("<HHB", data, pos)
        out[mtype] = data[pos + 8 : pos + 8 + msize]
        pos += 8 + msize
    return out


# ---------------------------------------------------------------- writer
def write_datasets(path: str, datasets: Dict[str, np.ndarray], *, buffered: bool = False) -> int:
    """Write named arrays as HDF5 datasets under the root group."""
    names = sorted(datasets)
    arrays = [np.ascontiguousarray(datasets[n]) for n in names]

    # ---- plan the file layout ------------------------------------------
    sb_size = 96
    # root group object header (symbol table message)
    root_oh = _object_header([(0x0011, _symtab_message(0, 0))])  # patched later
    root_oh_addr = sb_size
    heap_addr = _align8(root_oh_addr + len(root_oh))
    # local heap: data segment holds "" at offset 0 then each name
    heap_data = bytearray(b"\x00" * 8)
    name_offsets = []
    for n in names:
        name_offsets.append(len(heap_data))
        nb = n.encode() + b"\x00"
        heap_data += nb + b"\x00" * (_align8(len(nb)) - len(nb))
    heap_hdr_size = 32
    heap_data_addr = heap_addr + heap_hdr_size
    btree_addr = _align8(heap_data_addr + len(heap_data))
    # SNOD groups of <= SNOD_MAX symbols (u16 field); one leaf B-tree node
    # pointing at every group — how real HDF5 scales past 64k links
    groups = [list(range(i, min(i + SNOD_MAX, len(names)))) for i in range(0, max(1, len(names)), SNOD_MAX)]
    btree_size = 24 + 8 * (len(groups) + 1) + 8 * len(groups)
    snod_addrs = []
    cursor = _align8(btree_addr + btree_size)
    for g in groups:
        snod_addrs.append(cursor)
        cursor = _align8(cursor + 8 + 40 * max(1, len(g)))
    # dataset object headers
    oh_addrs, oh_blobs = [], []
    data_addrs = []
    # first pass to compute object header sizes with dummy addresses
    for arr in arrays:
        oh = _object_header(
            [
                (0x0001, _dataspace_message(arr.shape)),
                (0x0003, _datatype_message(arr.dtype)),
                (0x0008, _layout_message(0, arr.nbytes)),
            ]
        )
        oh_addrs.append(cursor)
        oh_blobs.append(oh)
        cursor = _align8(cursor + len(oh))
    for arr in arrays:
        data_addrs.append(cursor)
        cursor = _align8(cursor + max(1, arr.nbytes))
    eof = cursor

    # ---- rebuild blobs with real addresses ------------------------------
    root_oh = _object_header([(0x0011, _symtab_message(btree_addr, heap_addr))])
    for i, arr in enumerate(arrays):
        oh_blobs[i] = _object_header(
            [
                (0x0001, _dataspace_message(arr.shape)),
                (0x0003, _datatype_message(arr.dtype)),
                (0x0008, _layout_message(data_addrs[i], arr.nbytes)),
            ]
        )

    superblock = b"".join(
        [
            SIGNATURE,
            struct.pack("<BBBBBBBB", 0, 0, 0, 0, 0, 8, 8, 0),
            struct.pack("<HH", min(32767, max(4, (len(names) + 1) // 2)), 16),  # leaf k, internal k
            struct.pack("<I", 0),
            struct.pack("<QQQQ", 0, UNDEF, eof, UNDEF),
            # root symbol table entry: name off 0, OH addr, cache type 1 + scratch
            struct.pack("<QQII", 0, root_oh_addr, 1, 0),
            struct.pack("<QQ", btree_addr, heap_addr),
        ]
    )
    assert len(superblock) == sb_size, len(superblock)

    heap_hdr = b"HEAP" + struct.pack("<B3xQQQ", 0, len(heap_data), UNDEF, heap_data_addr)
    btree = b"TREE" + struct.pack("<BBHQQ", 0, 0, len(groups), UNDEF, UNDEF)
    for gi, g in enumerate(groups):
        key = name_offsets[g[0]] if (g and name_offsets) else 0
        btree += struct.pack("<Q", key if gi else 0)
        btree += struct.pack("<Q", snod_addrs[gi])
    btree += struct.pack("<Q", name_offsets[-1] if name_offsets else 0)
    snods = []
    for g in groups:
        snod = b"SNOD" + struct.pack("<BBH", 1, 0, len(g))
        for i in g:
            snod += struct.pack("<QQII16x", name_offsets[i], oh_addrs[i], 0, 0)
        snods.append(snod)

    sections: List[Tuple[int, bytes]] = [
        (0, superblock),
        (root_oh_addr, root_oh),
        (heap_addr, heap_hdr),
        (heap_data_addr, bytes(heap_data)),
        (btree_addr, btree),
    ] + list(zip(snod_addrs, snods))
    for i, arr in enumerate(arrays):
        sections.append((oh_addrs[i], oh_blobs[i]))
        sections.append((data_addrs[i], arr.tobytes()))

    with open(path, "wb") as f:
        if buffered:
            buf = bytearray(eof)
            for addr, blob in sections:
                buf[addr : addr + len(blob)] = blob
            f.write(bytes(buf))
        else:
            # libhdf5-style scattered metadata writes: seek+write per section
            for addr, blob in sections:
                f.seek(addr)
                f.write(blob)
            f.truncate(eof)
    return eof


def write(path: str, arr: np.ndarray, name: str = "data", **kw) -> int:
    return write_datasets(path, {name: arr}, **kw)


def write_datasets_incremental(path: str, datasets: Dict[str, np.ndarray]) -> int:
    """Emulates the libhdf5/h5py ``create_dataset``-in-a-loop call pattern:
    per dataset, the object header and data are appended and the group
    metadata (SNOD + superblock EOF) is rewritten — the incremental
    metadata churn that makes real HDF5 slow for many small objects.
    Together with the batch writer this brackets real libhdf5 cost."""
    names = sorted(datasets)
    # plan static sections once (heap holds all names; snod sized for all)
    sb_size = 96
    root_oh = _object_header([(0x0011, _symtab_message(0, 0))])
    root_oh_addr = sb_size
    heap_addr = _align8(root_oh_addr + len(root_oh))
    heap_data = bytearray(b"\x00" * 8)
    name_offsets = []
    for n in names:
        name_offsets.append(len(heap_data))
        nb = n.encode() + b"\x00"
        heap_data += nb + b"\x00" * (_align8(len(nb)) - len(nb))
    heap_hdr_size = 32
    heap_data_addr = heap_addr + heap_hdr_size
    btree_addr = _align8(heap_data_addr + len(heap_data))
    groups = [list(range(i, min(i + SNOD_MAX, len(names)))) for i in range(0, max(1, len(names)), SNOD_MAX)]
    btree_size = 24 + 8 * (len(groups) + 1) + 8 * len(groups)
    snod_addrs = []
    cursor = _align8(btree_addr + btree_size)
    for g in groups:
        snod_addrs.append(cursor)
        cursor = _align8(cursor + 8 + 40 * max(1, len(g)))

    root_oh = _object_header([(0x0011, _symtab_message(btree_addr, heap_addr))])
    heap_hdr = b"HEAP" + struct.pack("<B3xQQQ", 0, len(heap_data), UNDEF, heap_data_addr)
    btree = b"TREE" + struct.pack("<BBHQQ", 0, 0, len(groups), UNDEF, UNDEF)
    for gi, g in enumerate(groups):
        key = name_offsets[g[0]] if (g and name_offsets) else 0
        btree += struct.pack("<Q", key if gi else 0)
        btree += struct.pack("<Q", snod_addrs[gi])
    btree += struct.pack("<Q", name_offsets[-1] if name_offsets else 0)

    def superblock(eof):
        return b"".join([
            SIGNATURE,
            struct.pack("<BBBBBBBB", 0, 0, 0, 0, 0, 8, 8, 0),
            struct.pack("<HH", min(32767, max(4, (len(names) + 1) // 2)), 16),
            struct.pack("<I", 0),
            struct.pack("<QQQQ", 0, UNDEF, eof, UNDEF),
            struct.pack("<QQII", 0, root_oh_addr, 1, 0),
            struct.pack("<QQ", btree_addr, heap_addr),
        ])

    with open(path, "wb") as f:
        f.seek(0); f.write(superblock(cursor))
        f.seek(root_oh_addr); f.write(root_oh)
        f.seek(heap_addr); f.write(heap_hdr)
        f.seek(heap_data_addr); f.write(bytes(heap_data))
        f.seek(btree_addr); f.write(btree)
        snod_entries = []
        gi = 0
        for i, n in enumerate(names):
            if i // SNOD_MAX != gi:  # rolled into the next SNOD group
                gi = i // SNOD_MAX
                snod_entries = []
            arr = np.ascontiguousarray(datasets[n])
            oh_addr = cursor
            oh = _object_header([
                (0x0001, _dataspace_message(arr.shape)),
                (0x0003, _datatype_message(arr.dtype)),
                (0x0008, _layout_message(0, arr.nbytes)),
            ])
            data_addr = _align8(oh_addr + len(oh))
            oh = _object_header([
                (0x0001, _dataspace_message(arr.shape)),
                (0x0003, _datatype_message(arr.dtype)),
                (0x0008, _layout_message(data_addr, arr.nbytes)),
            ])
            # per-dataset churn: header, data, current-SNOD rewrite, SB EOF
            f.seek(oh_addr); f.write(oh)
            f.seek(data_addr); f.write(arr.tobytes())
            cursor = _align8(data_addr + max(1, arr.nbytes))
            snod_entries.append(struct.pack("<QQII16x", name_offsets[i], oh_addr, 0, 0))
            snod = b"SNOD" + struct.pack("<BBH", 1, 0, len(snod_entries)) + b"".join(snod_entries)
            f.seek(snod_addrs[gi]); f.write(snod)
            f.seek(0); f.write(superblock(cursor))
        f.truncate(cursor)
    return cursor


# ---------------------------------------------------------------- reader
class H5MinFile:
    """Parse the subset we write. Each access pattern mirrors libhdf5's:
    superblock → root entry → B-tree → SNOD → object header → data."""

    def __init__(self, path: str):
        self.path = path
        with open(path, "rb") as f:
            self._data = f.read()
        d = self._data
        if d[:8] != SIGNATURE:
            raise ValueError("not an HDF5 file")
        # root symbol table entry at offset 56 within 96-byte superblock
        self._btree_addr, self._heap_addr = struct.unpack_from("<QQ", d, 80)
        # local heap data segment address
        _, heap_len, _, heap_data_addr = struct.unpack_from("<B3xQQQ", d, self._heap_addr + 4)
        self._heap = d[heap_data_addr : heap_data_addr + heap_len]
        self.names: Dict[str, int] = {}
        self._walk_btree(self._btree_addr)

    def _walk_btree(self, addr: int) -> None:
        d = self._data
        if d[addr : addr + 4] != b"TREE":
            raise ValueError("bad B-tree node")
        _ntype, level, nused = struct.unpack_from("<BBH", d, addr + 4)
        pos = addr + 24
        children = []
        for i in range(nused):
            pos += 8  # key
            (child,) = struct.unpack_from("<Q", d, pos)
            children.append(child)
            pos += 8
        for child in children:
            if level > 0:
                self._walk_btree(child)
            else:
                self._read_snod(child)

    def _read_snod(self, addr: int) -> None:
        d = self._data
        if d[addr : addr + 4] != b"SNOD":
            raise ValueError("bad SNOD")
        (nsym,) = struct.unpack_from("<H", d, addr + 6)
        pos = addr + 8
        for _ in range(nsym):
            name_off, oh_addr = struct.unpack_from("<QQ", d, pos)
            end = self._heap.index(b"\x00", name_off)
            self.names[self._heap[name_off:end].decode()] = oh_addr
            pos += 40

    def read(self, name: str) -> np.ndarray:
        msgs = _parse_object_header(self._data, self.names[name])
        ver, ndims = struct.unpack_from("<BB", msgs[0x0001], 0)
        shape = struct.unpack_from(f"<{ndims}Q", msgs[0x0001], 8)
        dtype = _parse_datatype(msgs[0x0003])
        _v, _c, addr, nbytes = struct.unpack_from("<BBQQ", msgs[0x0008], 0)
        return (
            np.frombuffer(self._data[addr : addr + nbytes], dtype=dtype)
            .reshape(shape)
            .copy()
        )

    def read_all(self) -> Dict[str, np.ndarray]:
        return {n: self.read(n) for n in self.names}


def read(path: str, name: str = "data") -> np.ndarray:
    return H5MinFile(path).read(name)
