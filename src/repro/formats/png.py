"""Pure-Python PNG codec on stdlib zlib (for the paper's Fig-3 benchmark;
baseline DESIGN.md §6).

Supports 8-bit grayscale (color type 0) and 8-bit RGB (color type 2),
which covers MNIST- and CIFAR-style images. The encoder uses filter type 0
(None) per scanline — the *fastest possible* PNG to decode — so the measured
RawArray-vs-PNG gap is a conservative lower bound on the paper's (real
datasets use adaptive filtering, which decodes slower). The decoder handles
all five filter types so it is a complete reader.
"""

from __future__ import annotations

import struct
import zlib
from typing import Tuple

import numpy as np

_SIGNATURE = b"\x89PNG\r\n\x1a\n"


def _chunk(tag: bytes, payload: bytes) -> bytes:
    return (
        struct.pack(">I", len(payload))
        + tag
        + payload
        + struct.pack(">I", zlib.crc32(tag + payload) & 0xFFFFFFFF)
    )


def encode(img: np.ndarray, *, level: int = 6) -> bytes:
    """Encode a (H, W) or (H, W, 3) uint8 array as PNG bytes."""
    img = np.ascontiguousarray(img)
    if img.dtype != np.uint8:
        raise ValueError(f"png.encode wants uint8, got {img.dtype}")
    if img.ndim == 2:
        color_type, channels = 0, 1
        h, w = img.shape
    elif img.ndim == 3 and img.shape[2] == 3:
        color_type, channels = 2, 3
        h, w = img.shape[:2]
    else:
        raise ValueError(f"unsupported image shape {img.shape}")
    ihdr = struct.pack(">IIBBBBB", w, h, 8, color_type, 0, 0, 0)
    # filter byte 0 prepended to each scanline
    raw = np.empty((h, 1 + w * channels), dtype=np.uint8)
    raw[:, 0] = 0
    raw[:, 1:] = img.reshape(h, w * channels)
    idat = zlib.compress(raw.tobytes(), level)
    return b"".join(
        [_SIGNATURE, _chunk(b"IHDR", ihdr), _chunk(b"IDAT", idat), _chunk(b"IEND", b"")]
    )


def write(path: str, img: np.ndarray, *, level: int = 6) -> int:
    data = encode(img, level=level)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def _paeth(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    # a = left, b = up, c = upper-left (int16 to avoid overflow)
    p = a.astype(np.int16) + b.astype(np.int16) - c.astype(np.int16)
    pa, pb, pc = np.abs(p - a), np.abs(p - b), np.abs(p - c)
    out = np.where((pa <= pb) & (pa <= pc), a, np.where(pb <= pc, b, c))
    return out.astype(np.uint8)


def decode(data: bytes) -> np.ndarray:
    """Decode PNG bytes to a (H, W) or (H, W, C) uint8 array."""
    if data[:8] != _SIGNATURE:
        raise ValueError("not a PNG file")
    pos = 8
    width = height = None
    bit_depth = color_type = None
    idat = bytearray()
    while pos < len(data):
        (length,) = struct.unpack_from(">I", data, pos)
        tag = data[pos + 4 : pos + 8]
        payload = data[pos + 8 : pos + 8 + length]
        pos += 12 + length
        if tag == b"IHDR":
            width, height, bit_depth, color_type, comp, filt, inter = struct.unpack(
                ">IIBBBBB", payload
            )
            if bit_depth != 8 or comp != 0 or filt != 0 or inter != 0:
                raise ValueError("unsupported PNG variant")
        elif tag == b"IDAT":
            idat += payload
        elif tag == b"IEND":
            break
    channels = {0: 1, 2: 3, 4: 2, 6: 4}[color_type]
    raw = zlib.decompress(bytes(idat))
    stride = width * channels
    rows = np.frombuffer(raw, dtype=np.uint8).reshape(height, 1 + stride)
    filters = rows[:, 0]
    out = np.empty((height, stride), dtype=np.uint8)
    bpp = channels  # bytes per pixel at bit depth 8
    if not filters.any():
        # fast path: all scanlines unfiltered (what our encoder emits)
        out[:] = rows[:, 1:]
        return _reshape(out, height, width, channels)
    prev = np.zeros(stride, dtype=np.uint8)
    for y in range(height):
        f = filters[y]
        cur = rows[y, 1:].copy()
        if f == 0:
            pass
        elif f == 1:  # Sub
            for x in range(bpp, stride):
                cur[x] = (cur[x] + cur[x - bpp]) & 0xFF
        elif f == 2:  # Up
            cur = (cur.astype(np.int16) + prev).astype(np.uint8)
        elif f == 3:  # Average
            for x in range(stride):
                left = cur[x - bpp] if x >= bpp else 0
                cur[x] = (cur[x] + ((int(left) + int(prev[x])) >> 1)) & 0xFF
        elif f == 4:  # Paeth
            for x in range(stride):
                left = cur[x - bpp] if x >= bpp else 0
                ul = prev[x - bpp] if x >= bpp else 0
                cur[x] = (
                    cur[x]
                    + _paeth(
                        np.uint8(left), np.uint8(prev[x]), np.uint8(ul)
                    )
                ) & 0xFF
        else:
            raise ValueError(f"bad filter {f}")
        out[y] = cur
        prev = cur
    return _reshape(out, height, width, channels)


def _reshape(flat: np.ndarray, h: int, w: int, c: int) -> np.ndarray:
    return flat.reshape(h, w) if c == 1 else flat.reshape(h, w, c)


def read(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        return decode(f.read())


def inflate_floor(path: str) -> Tuple[int, bytes]:
    """Read + inflate only (no unfiltering) — the time floor any PNG library
    must pay. Used to bound the Fig-3 comparison honestly from below."""
    with open(path, "rb") as f:
        data = f.read()
    pos = 8
    idat = bytearray()
    while pos < len(data):
        (length,) = struct.unpack_from(">I", data, pos)
        tag = data[pos + 4 : pos + 8]
        if tag == b"IDAT":
            idat += data[pos + 8 : pos + 8 + length]
        pos += 12 + length
    raw = zlib.decompress(bytes(idat))
    return len(raw), raw
