"""numpy .npy wrapper (benchmark baseline, DESIGN.md §6) — the paper
discusses NPY as 'quite fast, but not so
simple and not widely implemented in other languages'. We benchmark against
numpy's own battle-tested implementation (no reimplementation needed)."""

from __future__ import annotations

import numpy as np


def write(path: str, arr: np.ndarray) -> int:
    np.save(path, arr, allow_pickle=False)
    return arr.nbytes


def read(path: str) -> np.ndarray:
    return np.load(path, allow_pickle=False)


def memmap(path: str) -> np.ndarray:
    return np.load(path, mmap_mode="r", allow_pickle=False)
