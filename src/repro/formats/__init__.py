"""Baseline formats the paper benchmarks against, implemented in-tree.

The evaluation container is offline (no h5py / libpng / pynrrd), and the
system prompt's rule is: *if the paper compares against a baseline,
implement the baseline too*. So:

* :mod:`repro.formats.hdf5min` — a minimal but structurally faithful HDF5
  writer/reader (superblock v0, B-tree v1 group node, local heap, SNOD,
  v1 object headers, contiguous layout).
* :mod:`repro.formats.png`     — a complete PNG codec on stdlib zlib
  (IHDR/IDAT/IEND, all five filter types on decode).
* :mod:`repro.formats.nrrd`    — NRRD text-header + raw payload.
* :mod:`repro.formats.npy`     — thin wrapper over numpy's own .npy.
* :mod:`repro.formats.ingest`  — foreign-format → RawArray dataset
  converters streaming through the ingest plane (DESIGN.md §11).
"""

from . import hdf5min, ingest, npy, nrrd, png  # noqa: F401
