"""NRRD (Nearly Raw Raster Data) — the paper's "strong competitor" (§1).
Benchmark baseline (DESIGN.md §6).

Text header + raw payload; raw encoding only (the paper prefers external
compression anyway). Implemented so benchmarks can compare header-parse
overhead of a text format vs RawArray's numeric header.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

_TYPE_TO_NRRD = {
    "int8": "int8", "uint8": "uint8",
    "int16": "int16", "uint16": "uint16",
    "int32": "int32", "uint32": "uint32",
    "int64": "int64", "uint64": "uint64",
    "float32": "float", "float64": "double",
}
_NRRD_TO_DTYPE = {v: k for k, v in _TYPE_TO_NRRD.items()}
_NRRD_TO_DTYPE.update({"signed char": "int8", "unsigned char": "uint8"})


def write(path: str, arr: np.ndarray, extra: Dict[str, str] | None = None) -> int:
    arr = np.ascontiguousarray(arr)
    t = _TYPE_TO_NRRD.get(arr.dtype.name)
    if t is None:
        raise ValueError(f"nrrd: unsupported dtype {arr.dtype}")
    # NRRD sizes are fastest-axis-first; numpy C-order last axis is fastest.
    sizes = " ".join(str(s) for s in arr.shape[::-1])
    lines = [
        "NRRD0004",
        f"type: {t}",
        f"dimension: {arr.ndim}",
        f"sizes: {sizes}",
        "encoding: raw",
        "endian: little",
    ]
    for k, v in (extra or {}).items():
        lines.append(f"{k}: {v}")
    header = ("\n".join(lines) + "\n\n").encode()
    with open(path, "wb") as f:
        f.write(header)
        f.write(arr.tobytes())
    return len(header) + arr.nbytes


def read(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        data = f.read()
    end = data.index(b"\n\n")
    fields: Dict[str, str] = {}
    head = data[:end].decode().splitlines()
    if not head[0].startswith("NRRD"):
        raise ValueError("not a NRRD file")
    for line in head[1:]:
        if ":" in line:
            k, v = line.split(":", 1)
            fields[k.strip()] = v.strip()
    if fields.get("encoding", "raw") != "raw":
        raise ValueError("nrrd: only raw encoding supported")
    if fields.get("endian", "little") != "little":
        raise ValueError("nrrd: only little endian supported")
    dtype = np.dtype(_NRRD_TO_DTYPE[fields["type"]])
    sizes = tuple(int(s) for s in fields["sizes"].split())
    shape = sizes[::-1]
    return np.frombuffer(data[end + 2 :], dtype=dtype, count=int(np.prod(shape))).reshape(shape)
