"""Foreign-format → RawArray dataset converters (DESIGN.md §11).

The paper's motivating workload is archival ingest: take a pile of
format-of-the-day files (``.npy`` dumps, PNG images) and land them as a
RawArray dataset directory that every downstream plane — parallel reads,
remote byte-range serving, chunked compression, the training loader —
consumes natively. These converters stream through
``repro.data.DatasetBuilder``, so an arbitrarily large corpus converts in
bounded memory (one write buffer per field) and the output directory is
atomic (manifest written last).
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core.spec import RawArrayError
from ..data.dataset import DatasetBuilder
from . import png as png_codec

PathList = Union[str, Sequence[str]]


def npy_to_dataset(
    root: str,
    field_files: Dict[str, PathList],
    *,
    shard_rows: int = 8192,
    batch_rows: Optional[int] = None,
    chunked: bool = False,
    codec: Optional[str] = None,
    chunk_bytes: Optional[int] = None,
    metadata: Optional[dict] = None,
) -> dict:
    """Stream ``.npy`` files into a RawArray dataset (DESIGN.md §11).

    ``field_files`` maps each dataset field to one ``.npy`` path or an
    ordered list of paths that concatenate along axis 0; all fields must
    yield the same total row count. Sources are memory-mapped and fed to
    ``DatasetBuilder`` in bounded row batches, so nothing materializes.
    Returns the dataset manifest.
    """
    srcs: Dict[str, List[np.ndarray]] = {}
    fields: Dict[str, tuple] = {}
    totals = set()
    for name, paths in field_files.items():
        paths = [paths] if isinstance(paths, (str, os.PathLike)) else list(paths)
        arrs = [np.load(p, mmap_mode="r", allow_pickle=False) for p in paths]
        if not arrs or arrs[0].ndim == 0:
            raise RawArrayError(f"{name}: need at least one non-0-d .npy source")
        row_shape, dtype = arrs[0].shape[1:], arrs[0].dtype
        for p, a in zip(paths, arrs):
            if a.shape[1:] != row_shape or a.dtype != dtype:
                raise RawArrayError(
                    f"{p}: rows are {a.dtype}{list(a.shape[1:])}, field "
                    f"{name!r} wants {dtype}{list(row_shape)}"
                )
        srcs[name] = arrs
        fields[name] = (tuple(row_shape), str(dtype))
        totals.add(sum(a.shape[0] for a in arrs))
    if len(totals) != 1:
        raise RawArrayError(f"fields disagree on total rows: {sorted(totals)}")
    (total,) = totals
    if batch_rows is None:
        row_nbytes = max(
            1,
            sum(
                np.dtype(d).itemsize * int(np.prod(s, dtype=np.int64))
                for s, d in fields.values()
            ),
        )
        batch_rows = max(1, (32 << 20) // row_nbytes)
    # per-field cursors into the (file, row) stream
    flat = {name: _Concat(arrs) for name, arrs in srcs.items()}
    with DatasetBuilder(
        root, fields, shard_rows=shard_rows,
        chunked=chunked, codec=codec, chunk_bytes=chunk_bytes,
    ) as b:
        for lo in range(0, total, batch_rows):
            n = min(batch_rows, total - lo)
            b.append(**{name: flat[name].take(n) for name in fields})
        return b.finish(metadata=metadata)


class _Concat:
    """Sequential row cursor over a list of arrays (no np.concatenate)."""

    def __init__(self, arrs: List[np.ndarray]):
        self._arrs = arrs
        self._i = 0
        self._off = 0

    def take(self, n: int) -> np.ndarray:
        pieces = []
        while n:
            a = self._arrs[self._i]
            got = min(n, a.shape[0] - self._off)
            pieces.append(a[self._off : self._off + got])
            self._off += got
            n -= got
            if self._off == a.shape[0] and self._i + 1 < len(self._arrs):
                self._i += 1
                self._off = 0
        return pieces[0] if len(pieces) == 1 else np.concatenate(pieces, axis=0)


def images_to_dataset(
    root: str,
    image_paths: Sequence[str],
    labels: Optional[np.ndarray] = None,
    *,
    shard_rows: int = 8192,
    chunked: bool = False,
    codec: Optional[str] = None,
    chunk_bytes: Optional[int] = None,
    metadata: Optional[dict] = None,
) -> dict:
    """Decode PNG images one by one into a RawArray dataset — the paper's
    MNIST/CIFAR-style ingest (DESIGN.md §11). All images must share one
    shape/dtype (the first image defines it); pass ``labels`` (one int per
    image) to add a ``label`` field. Returns the manifest."""
    if not image_paths:
        raise RawArrayError("images_to_dataset needs at least one image")
    first = png_codec.read(image_paths[0])
    fields: Dict[str, tuple] = {"image": (tuple(first.shape), str(first.dtype))}
    if labels is not None:
        labels = np.asarray(labels)
        if len(labels) != len(image_paths):
            raise RawArrayError(
                f"{len(labels)} labels for {len(image_paths)} images"
            )
        fields["label"] = ((), str(labels.dtype))
    with DatasetBuilder(
        root, fields, shard_rows=shard_rows,
        chunked=chunked, codec=codec, chunk_bytes=chunk_bytes,
    ) as b:
        for i, p in enumerate(image_paths):
            img = first if i == 0 else png_codec.read(p)
            if img.shape != first.shape or img.dtype != first.dtype:
                raise RawArrayError(
                    f"{p}: image is {img.dtype}{list(img.shape)}, dataset "
                    f"wants {first.dtype}{list(first.shape)}"
                )
            sample = {"image": img}
            if labels is not None:
                sample["label"] = labels[i]
            b.add(**sample)
        return b.finish(metadata=metadata)
