"""Dense (gated) MLP blocks."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import Initializer, activation
from .config import ModelConfig


def init_mlp(ini: Initializer, cfg: ModelConfig, path: str = "mlp", d_ff: int = 0) -> Dict[str, Any]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    p = {
        "w_up": ini.fanin(f"{path}.w_up", (d, ff)),
        "w_down": ini.fanin(f"{path}.w_down", (ff, d)),
    }
    if cfg.mlp_gated:
        p["w_gate"] = ini.fanin(f"{path}.w_gate", (d, ff))
    return p


def mlp(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = activation(cfg.mlp_act)
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if cfg.mlp_gated:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = act(gate) * up
    else:
        h = act(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
