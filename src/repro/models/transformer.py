"""Unified decoder-only transformer LM covering the dense / MoE / VLM
assigned architectures (gemma3, olmo, internlm2, qwen2.5, llava-mistral,
deepseek-v3, kimi-k2).

Layer stacks are `lax.scan`'d over stacked parameters (small HLO, fast
compile, remat-friendly). Heterogeneous per-layer behaviour (gemma3's 5:1
local:global pattern) rides through the scan as traced per-layer flags with
purely arithmetic masking. MoE models scan dense-prefix layers and MoE
layers separately (different param trees).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .attention import (
    gqa_attention,
    gqa_decode,
    gqa_prefill,
    init_gqa,
    init_mla,
    mla_attention,
    mla_decode,
)
from .common import (
    Initializer,
    cross_entropy_loss,
    embed_lookup,
    make_norm,
    stack_init,
)
from .config import ModelConfig
from .ffn import init_mlp, mlp
from .moe import init_moe, moe_ffn


# ---------------------------------------------------------------- layer defs
def _init_layer(ini: Initializer, cfg: ModelConfig, *, use_moe: bool) -> Dict[str, Any]:
    norm_init, _ = make_norm(cfg.norm)
    d = cfg.d_model
    p: Dict[str, Any] = {
        "ln_attn": norm_init(ini, "ln_attn", d),
        "ln_mlp": norm_init(ini, "ln_mlp", d),
    }
    if cfg.sandwich_norm:
        p["ln_attn_post"] = norm_init(ini, "ln_attn_post", d)
        p["ln_mlp_post"] = norm_init(ini, "ln_mlp_post", d)
    p["attn"] = init_mla(ini, cfg) if cfg.attn_type == "mla" else init_gqa(ini, cfg)
    p["ffn"] = init_moe(ini, cfg) if use_moe else init_mlp(ini, cfg)
    return p


def _layer_fwd(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    is_global,
    rope_theta,
    use_moe: bool,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    _, norm = make_norm(cfg.norm)
    h = norm(p["ln_attn"], x)
    if cfg.attn_type == "mla":
        a = mla_attention(p["attn"], h, cfg, positions=positions, chunk=cfg.attn_chunk)
    else:
        a = gqa_attention(
            p["attn"], h, cfg, positions=positions, is_global=is_global,
            rope_theta=rope_theta, chunk=cfg.attn_chunk,
        )
    if cfg.sandwich_norm:
        a = norm(p["ln_attn_post"], a)
    x = constrain(x + a, "batch", "act_seq", "embed")
    h = norm(p["ln_mlp"], x)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        f, aux = moe_ffn(p["ffn"], h, cfg)
    else:
        f = mlp(p["ffn"], h, cfg)
    if cfg.sandwich_norm:
        f = norm(p["ln_mlp_post"], f)
    x = constrain(x + f, "batch", "act_seq", "embed")
    return x, aux


def _layer_prefill(p, x, cfg, *, positions, is_global, rope_theta, use_moe):
    _, norm = make_norm(cfg.norm)
    h = norm(p["ln_attn"], x)
    if cfg.attn_type == "mla":
        a, kv = mla_attention(p["attn"], h, cfg, positions=positions, with_cache=True, chunk=cfg.attn_chunk)
    else:
        a, kv = gqa_prefill(
            p["attn"], h, cfg, positions=positions, is_global=is_global,
            rope_theta=rope_theta, chunk=cfg.attn_chunk,
        )
    if cfg.sandwich_norm:
        a = norm(p["ln_attn_post"], a)
    x = x + a
    h = norm(p["ln_mlp"], x)
    f = moe_ffn(p["ffn"], h, cfg)[0] if use_moe else mlp(p["ffn"], h, cfg)
    if cfg.sandwich_norm:
        f = norm(p["ln_mlp_post"], f)
    return x + f, kv


def _layer_decode(p, x, cache, pos, cfg, *, is_global, rope_theta, use_moe):
    _, norm = make_norm(cfg.norm)
    h = norm(p["ln_attn"], x)
    if cfg.attn_type == "mla":
        a, cache = mla_decode(p["attn"], h, cache, pos, cfg)
    else:
        a, cache = gqa_decode(
            p["attn"], h, cache, pos, cfg, is_global=is_global, rope_theta=rope_theta
        )
    if cfg.sandwich_norm:
        a = norm(p["ln_attn_post"], a)
    x = x + a
    h = norm(p["ln_mlp"], x)
    f = moe_ffn(p["ffn"], h, cfg)[0] if use_moe else mlp(p["ffn"], h, cfg)
    if cfg.sandwich_norm:
        f = norm(p["ln_mlp_post"], f)
    return x + f, cache


# ---------------------------------------------------------------- model
class TransformerLM:
    """Functional model: params are plain pytrees; methods are pure."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        m = cfg.moe
        self.n_dense = cfg.n_layers if not (m and m.n_experts) else m.first_dense
        self.n_moe = cfg.n_layers - self.n_dense

    # ---- init -----------------------------------------------------------
    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        ini = Initializer(keys[0], cfg.pdtype)
        params: Dict[str, Any] = {
            "embed": ini.normal("embed", (cfg.vocab, cfg.d_model), scale=1.0 / cfg.d_model**0.5),
        }
        norm_init, _ = make_norm(cfg.norm)
        params["ln_f"] = norm_init(ini, "ln_f", cfg.d_model)
        if not cfg.tie_embeddings:
            params["lm_head"] = ini.normal(
                "lm_head", (cfg.d_model, cfg.vocab), scale=1.0 / cfg.d_model**0.5
            )
        if self.n_dense:
            params["dense_layers"] = stack_init(
                self.n_dense,
                lambda i: _init_layer(i, cfg, use_moe=False),
                keys[1],
                cfg.pdtype,
            )
        if self.n_moe:
            params["moe_layers"] = stack_init(
                self.n_moe,
                lambda i: _init_layer(i, cfg, use_moe=True),
                keys[2],
                cfg.pdtype,
            )
        if cfg.n_patches:
            # VLM adapter: projects (stub) vision-encoder patch embeddings
            params["mm_proj"] = ini.fanin("mm_proj", (cfg.d_model, cfg.d_model))
        if cfg.mtp:
            params["mtp"] = {
                "proj": ini.fanin("mtp.proj", (2 * cfg.d_model, cfg.d_model)),
                "layer": _init_layer(Initializer(keys[3], cfg.pdtype), cfg, use_moe=False),
                "ln": norm_init(ini, "mtp.ln", cfg.d_model),
            }
        return params

    # ---- helpers ----------------------------------------------------------
    def _layer_flags(self, n: int, offset: int = 0):
        """(is_global, rope_theta) per layer, as scan xs."""
        cfg = self.cfg
        idx = jnp.arange(offset, offset + n)
        if cfg.global_every:
            is_global = ((idx + 1) % cfg.global_every == 0).astype(jnp.float32)
        elif cfg.sliding_window:
            is_global = jnp.zeros((n,), jnp.float32)  # all layers local (mistral)
        else:
            is_global = jnp.ones((n,), jnp.float32)
        theta_g = cfg.rope_theta_global or cfg.rope_theta
        rope_theta = jnp.where(is_global > 0, theta_g, cfg.rope_theta)
        return is_global, rope_theta

    def _scan_stack(self, layers, x, positions, *, use_moe: bool, offset: int):
        cfg = self.cfg
        n = jax.tree_util.tree_leaves(layers)[0].shape[0]
        flags = self._layer_flags(n, offset)

        def body(carry, inp):
            p, (g, th) = inp
            y, aux = _layer_fwd(
                p, carry, cfg, positions=positions, is_global=g,
                rope_theta=th, use_moe=use_moe,
            )
            return y, aux

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, auxes = jax.lax.scan(body, x, (layers, flags))
        return x, jnp.sum(auxes)

    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (x (B,S,d), positions (S,)). VLM prepends patch embeds."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens, cfg.embed_scale, cfg.cdtype)
        if cfg.n_patches and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(cfg.cdtype)
            pe = jnp.einsum("bpd,de->bpe", pe, params["mm_proj"].astype(cfg.cdtype))
            x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
        return constrain(x, "batch", "seq", "embed"), jnp.arange(S)

    def _backbone(self, params, x, positions):
        aux = jnp.zeros((), jnp.float32)
        if self.n_dense:
            x, a = self._scan_stack(params["dense_layers"], x, positions, use_moe=False, offset=0)
            aux += a
        if self.n_moe:
            x, a = self._scan_stack(
                params["moe_layers"], x, positions, use_moe=True, offset=self.n_dense
            )
            aux += a
        _, norm = make_norm(self.cfg.norm)
        return norm(params["ln_f"], x), aux

    def _logits(self, params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
        if cfg.logit_softcap:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        return constrain(logits, "batch", "seq", "vocab")

    def _chunked_ce(self, params, h, labels, mask, chunk: int = 256):
        """Scan CE over seq chunks so full (B,S,V) logits never materialize."""
        cfg = self.cfg
        B, S, d = h.shape
        chunk = min(chunk, S)
        n = S // chunk
        rem = S - n * chunk

        def piece(hc, lc, mc):
            logits = self._logits(params, hc)
            loss, acc = cross_entropy_loss(logits, lc, mc)
            cnt = jnp.maximum(jnp.sum(mc.astype(jnp.float32)), 1e-9)
            return loss * cnt, acc * cnt, cnt

        piece = jax.checkpoint(piece, prevent_cse=False)

        def body(carry, i):
            hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
            lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
            mc = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
            l, a, c = piece(hc, lc, mc)
            return (carry[0] + l, carry[1] + a, carry[2] + c), None

        (tl, ta, tc), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), jnp.arange(n)
        )
        if rem:
            l, a, c = piece(h[:, n * chunk :], labels[:, n * chunk :], mask[:, n * chunk :])
            tl, ta, tc = tl + l, ta + a, tc + c
        return tl / jnp.maximum(tc, 1e-9), ta / jnp.maximum(tc, 1e-9)

    # ---- train ------------------------------------------------------------
    def train_loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: tokens (B,S) [+ patch_embeds (B,P,d)]. Next-token LM loss
        over text positions."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        h, aux = self._backbone(params, x, positions)
        tokens = batch["tokens"]
        P = h.shape[1] - tokens.shape[1]  # vision prefix length (0 if pure LM)
        h_text = h[:, P:, :]
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        loss, acc = self._chunked_ce(params, h_text, labels, mask)
        metrics = {"ce": loss, "aux": aux, "acc": acc}
        total = loss + aux
        if cfg.mtp:
            mtp_loss = self._mtp_loss(params, h_text, tokens)
            metrics["mtp"] = mtp_loss
            total = total + cfg.mtp_weight * mtp_loss
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, h, tokens):
        """DeepSeek-V3 MTP depth-1: predict token t+2 from h_t ++ emb(t+1)."""
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        p = params["mtp"]
        # keep full length S (chunk-friendly): emb of token t+1, garbage at the
        # last position, masked out of the loss below.
        next_tok = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        emb_next = embed_lookup(params["embed"], next_tok, cfg.embed_scale, cfg.cdtype)
        h_in = jnp.concatenate([norm(p["ln"], h), emb_next], axis=-1)
        h_in = jnp.einsum("bsk,kd->bsd", h_in, p["proj"].astype(h.dtype))
        S = h_in.shape[1]
        h_out, _ = _layer_fwd(
            p["layer"], h_in, cfg, positions=jnp.arange(S),
            is_global=jnp.float32(1), rope_theta=cfg.rope_theta, use_moe=False,
        )
        # predict token t+2 from position t; mask the last two positions
        labels = jnp.concatenate([tokens[:, 2:], tokens[:, -2:]], axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -2:].set(0.0)
        loss, _ = self._chunked_ce(params, h_out, labels, mask)
        return loss

    # ---- serve ------------------------------------------------------------
    def prefill(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        """Process a prompt; returns (last-position logits, cache)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        cache: Dict[str, Any] = {}

        def run(layers, x, *, use_moe, offset):
            n = jax.tree_util.tree_leaves(layers)[0].shape[0]
            flags = self._layer_flags(n, offset)

            def body(carry, inp):
                p, (g, th) = inp
                y, kv = _layer_prefill(
                    p, carry, cfg, positions=positions, is_global=g,
                    rope_theta=th, use_moe=use_moe,
                )
                return y, kv

            return jax.lax.scan(body, x, (layers, flags))

        if self.n_dense:
            x, kv = run(params["dense_layers"], x, use_moe=False, offset=0)
            cache["dense"] = kv
        if self.n_moe:
            x, kv = run(params["moe_layers"], x, use_moe=True, offset=self.n_dense)
            cache["moe"] = kv
        _, norm = make_norm(cfg.norm)
        h = norm(params["ln_f"], x)
        logits = self._logits(params, h[:, -1:, :])
        cache["pos"] = jnp.asarray(x.shape[1], jnp.int32)
        return logits[:, 0], cache

    def empty_cache(self, batch: int, seq: int, dtype=None) -> Dict[str, Any]:
        """Allocate a zeroed KV cache of capacity ``seq`` (for decode shapes)."""
        cfg = self.cfg
        dtype = dtype or cfg.cdtype
        def kv(n):
            if cfg.attn_type == "mla":
                m = cfg.mla
                return {
                    "latent": jnp.zeros((n, batch, seq, m.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((n, batch, seq, m.qk_rope_head_dim), dtype),
                }
            return {
                "k": jnp.zeros((n, batch, cfg.n_kv_heads, seq, cfg.head_dim), dtype),
                "v": jnp.zeros((n, batch, cfg.n_kv_heads, seq, cfg.head_dim), dtype),
            }
        cache: Dict[str, Any] = {}
        if self.n_dense:
            cache["dense"] = kv(self.n_dense)
        if self.n_moe:
            cache["moe"] = kv(self.n_moe)
        cache["pos"] = jnp.zeros((), jnp.int32)
        return cache

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, Dict[str, Any]]:
        """One token for every sequence in the batch. tokens: (B, 1)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = embed_lookup(params["embed"], tokens, cfg.embed_scale, cfg.cdtype)
        x = constrain(x, "batch", None, "embed")

        def run(layers, layer_cache, x, *, use_moe, offset):
            n = jax.tree_util.tree_leaves(layers)[0].shape[0]
            flags = self._layer_flags(n, offset)

            def body(carry, inp):
                p, c, (g, th) = inp
                y, c2 = _layer_decode(
                    p, carry, c, pos, cfg, is_global=g, rope_theta=th, use_moe=use_moe
                )
                return y, c2

            return jax.lax.scan(body, x, (layers, layer_cache, flags))

        new_cache: Dict[str, Any] = {}
        if self.n_dense:
            x, c = run(params["dense_layers"], cache["dense"], x, use_moe=False, offset=0)
            new_cache["dense"] = c
        if self.n_moe:
            x, c = run(params["moe_layers"], cache["moe"], x, use_moe=True, offset=self.n_dense)
            new_cache["moe"] = c
        _, norm = make_norm(cfg.norm)
        h = norm(params["ln_f"], x)
        logits = self._logits(params, h)
        new_cache["pos"] = pos + 1
        return logits[:, 0], new_cache
