"""Unified model configuration covering all 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 8
    n_shared: int = 0             # shared (always-on) experts
    d_ff_expert: int = 0          # per-expert hidden
    capacity_factor: float = 1.25
    router: str = "sigmoid"       # 'sigmoid' (deepseek-v3/kimi) or 'softmax'
    aux_loss_coef: float = 0.001
    first_dense: int = 0          # leading dense layers (deepseek: 3)
    dispatch_chunks: int = 1      # scan MoE over token chunks (memory bound)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    head_dim: int = 0             # 0 => d_model // n_heads
    d_ff: int = 3072
    vocab: int = 32000
    max_seq: int = 131072

    # attention
    attn_type: str = "gqa"        # gqa | mla | none
    head_pad: int = 0             # extra ZERO q-heads for TP divisibility (exact no-op)
    attn_chunk: int = 512         # query-block size for chunked attention
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0       # 0 = full attention
    # layer pattern: e.g. gemma3 5 local : 1 global. global_every=0 => all full.
    global_every: int = 0         # every Nth layer is global (rest sliding window)
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0  # gemma3 global layers use different theta

    # norms / mlp
    norm: str = "rmsnorm"         # rmsnorm | layernorm | layernorm_np (non-parametric)
    sandwich_norm: bool = False   # gemma3: post-attn + post-ffn norms too
    mlp_act: str = "silu"         # silu (SwiGLU) | gelu (GeGLU or plain)
    mlp_gated: bool = True
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: scale embeddings by sqrt(d)
    logit_softcap: float = 0.0

    # extras
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp: bool = False             # deepseek multi-token-prediction depth-1
    mtp_weight: float = 0.3

    # hybrid (zamba2): shared attention block every k ssm layers
    hybrid_attn_every: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500           # whisper encoder positions after conv stub

    # vlm (llava): patch embeddings prepended to the token sequence
    n_patches: int = 0

    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    scan_layers: bool = True

    # optimizer memory plan (used by the distributed runtime)
    opt_moment_dtype: str = "float32"   # 'int8' => blockwise-quantized moments

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived ---------------------------------------------------------
    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm.headdim if self.ssm else 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            max_seq=512,
            scan_layers=self.scan_layers,
            remat=False,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=64,
                n_shared=min(self.moe.n_shared, 1), first_dense=min(self.moe.first_dense, 1),
                dispatch_chunks=1,
            )
        if self.mla:
            kw["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32,
            )
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, headdim=32, chunk=32)
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["enc_seq"] = 64
        if self.n_patches:
            kw["n_patches"] = 16
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 2
            kw["n_layers"] = 4
        if self.sliding_window:
            kw["sliding_window"] = 64
        return self.with_(**kw)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        per_layer = 0
        if self.hybrid_attn_every:
            # zamba2: ONE shared attention(2d)+MLP block reused at invocations
            hd2 = 2 * d // self.n_heads
            shared = 2 * d * self.n_heads * hd2 * 3      # wq,wk,wv over concat
            shared += self.n_heads * hd2 * 2 * d         # wo back to 2d width
            shared += 2 * d * d                          # out_proj 2d->d
            shared += (3 if self.mlp_gated else 2) * d * ff
            n += shared
        elif self.attn_type == "gqa":
            hd, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
            per_layer += d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.qkv_bias:
                per_layer += (H + 2 * KV) * hd
        elif self.attn_type == "mla":
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_dim
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        if self.ssm:
            di, N, H = self.d_inner, self.ssm.d_state, self.n_ssm_heads
            G = self.ssm.n_groups
            per_layer_ssm = d * (2 * di + 2 * G * N + H)  # in_proj
            per_layer_ssm += self.ssm.conv_width * (di + 2 * G * N)  # conv
            per_layer_ssm += H * 2 + di  # A, D, dt_bias... approx
            per_layer_ssm += di * d  # out_proj
            per_layer += per_layer_ssm
        if self.moe and self.moe.n_experts:
            ffe = self.moe.d_ff_expert
            moe_layer = d * self.moe.n_experts  # router
            moe_layer += self.moe.n_experts * 3 * d * ffe
            moe_layer += self.moe.n_shared * 3 * d * ffe
            dense_layer = 3 * d * ff if self.mlp_gated else 2 * d * ff
            n += self.moe.first_dense * dense_layer + (L - self.moe.first_dense) * moe_layer
        elif not self.ssm:
            n += L * (3 * d * ff if self.mlp_gated else 2 * d * ff)
        n += L * per_layer
        if self.n_enc_layers:  # whisper encoder
            hd, H = self.head_dim, self.n_heads
            enc = d * H * hd * 4 + (3 * d * ff if self.mlp_gated else 2 * d * ff)
            # decoder cross-attn
            n += self.n_enc_layers * enc + L * (d * H * hd * 4)
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: shared + top_k experts)."""
        if not (self.moe and self.moe.n_experts):
            return self.param_count()
        full = self.param_count()
        d, ffe = self.d_model, self.moe.d_ff_expert
        L_moe = self.n_layers - self.moe.first_dense
        all_experts = L_moe * self.moe.n_experts * 3 * d * ffe
        active_experts = L_moe * self.moe.top_k * 3 * d * ffe
        return int(full - all_experts + active_experts)
