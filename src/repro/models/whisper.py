"""Whisper-style encoder-decoder (audio backbone; conv frontend is a STUB).

Per the assignment sheet, ``input_specs()`` supplies precomputed mel-frame
embeddings (B, S_audio, d) — the two conv layers + GELU frontend of real
Whisper are host-side preprocessing we stub. Everything after that is
faithful: learned positional embeddings, pre-LN blocks, GELU MLP (non-gated),
decoder with causal self-attention + cross-attention over encoder states.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .attention import _mask_bias, _softmax_last
from .common import Initializer, cross_entropy_loss, layernorm, stack_init
from .config import ModelConfig


# ------------------------------------------------------------ primitives
def _init_attn(ini: Initializer, cfg: ModelConfig, path: str) -> Dict[str, Any]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": ini.fanin(f"{path}.wq", (d, H, hd)),
        "bq": ini.zeros(f"{path}.bq", (H, hd)),
        "wk": ini.fanin(f"{path}.wk", (d, H, hd)),
        "wv": ini.fanin(f"{path}.wv", (d, H, hd)),
        "bv": ini.zeros(f"{path}.bv", (H, hd)),
        "wo": ini.fanin(f"{path}.wo", (H, hd, d)),
        "bo": ini.zeros(f"{path}.bo", (d,)),
    }


def _attn(p, xq, xkv, cfg: ModelConfig, *, causal: bool, chunk: int = 512) -> jax.Array:
    """MHA (no rope — whisper uses learned absolute positions)."""
    B, Sq, _ = xq.shape
    Sk = xkv.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bhsk", xq, p["wq"].astype(xq.dtype)) + p["bq"].astype(xq.dtype)[None, :, None, :]
    k = jnp.einsum("bsd,dhk->bhsk", xkv, p["wk"].astype(xq.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", xkv, p["wv"].astype(xq.dtype)) + p["bv"].astype(xq.dtype)[None, :, None, :]
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, Sq)
    n_chunks = Sq // chunk
    qpos_all = jnp.arange(Sq)
    kpos = jnp.arange(Sk)

    def body(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=2)
        scores = jnp.einsum("bhcd,bhsd->bhcs", qi, k) * scale
        if causal:
            qpos = jax.lax.dynamic_slice_in_dim(qpos_all, i * chunk, chunk, axis=0)
            scores = scores + _mask_bias(qpos, kpos, 0, 1)
        probs = _softmax_last(scores).astype(xq.dtype)
        return carry, jnp.einsum("bhcs,bhsd->bhcd", probs, v)

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, Sq, hd)
    return jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(xq.dtype)) + p["bo"].astype(xq.dtype)


def _init_mlp(ini: Initializer, cfg: ModelConfig, path: str) -> Dict[str, Any]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w1": ini.fanin(f"{path}.w1", (d, ff)),
        "b1": ini.zeros(f"{path}.b1", (ff,)),
        "w2": ini.fanin(f"{path}.w2", (ff, d)),
        "b2": ini.zeros(f"{path}.b2", (d,)),
    }


def _mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype)) + p["b1"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(x.dtype)) + p["b2"].astype(x.dtype)


def _ln(ini: Initializer, path: str, d: int) -> Dict[str, Any]:
    return {"scale": ini.ones(f"{path}.scale", (d,)), "bias": ini.zeros(f"{path}.bias", (d,))}


def _apply_ln(p, x):
    return layernorm(x, p["scale"], p["bias"])


# ------------------------------------------------------------ model
class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 4)
        ini = Initializer(keys[0], cfg.pdtype)
        d = cfg.d_model

        def enc_layer(i: Initializer):
            return {
                "ln1": _ln(i, "ln1", d),
                "attn": _init_attn(i, cfg, "attn"),
                "ln2": _ln(i, "ln2", d),
                "mlp": _init_mlp(i, cfg, "mlp"),
            }

        def dec_layer(i: Initializer):
            return {
                "ln1": _ln(i, "ln1", d),
                "self_attn": _init_attn(i, cfg, "self_attn"),
                "ln_x": _ln(i, "ln_x", d),
                "cross_attn": _init_attn(i, cfg, "cross_attn"),
                "ln2": _ln(i, "ln2", d),
                "mlp": _init_mlp(i, cfg, "mlp"),
            }

        return {
            "enc_pos": ini.normal("enc_pos", (cfg.enc_seq, d), scale=0.01),
            "enc_layers": stack_init(cfg.n_enc_layers, enc_layer, keys[1], cfg.pdtype),
            "ln_enc": _ln(ini, "ln_enc", d),
            "embed": ini.normal("embed", (cfg.vocab, d), scale=1.0 / d**0.5),
            "dec_pos": ini.normal("dec_pos", (cfg.max_seq, d), scale=0.01),
            "dec_layers": stack_init(cfg.n_layers, dec_layer, keys[2], cfg.pdtype),
            "ln_dec": _ln(ini, "ln_dec", d),
        }

    # ---- encoder ----------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, S_audio, d) stub conv-frontend output."""
        cfg = self.cfg
        S = frames.shape[1]
        pos = params["enc_pos"]
        if S != pos.shape[0]:  # shape exercise: tile/crop learned positions
            reps = -(-S // pos.shape[0])
            pos = jnp.tile(pos, (reps, 1))[:S]
        x = frames.astype(cfg.cdtype) + pos.astype(cfg.cdtype)[None]
        x = constrain(x, "batch", "seq", "embed")

        def body(carry, p):
            h = carry + _attn(p["attn"], _apply_ln(p["ln1"], carry), _apply_ln(p["ln1"], carry), cfg, causal=False)
            h = h + _mlp(p["mlp"], _apply_ln(p["ln2"], h))
            return constrain(h, "batch", "act_seq", "embed"), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return _apply_ln(params["ln_enc"], x)

    # ---- decoder train ------------------------------------------------------
    def train_loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: frames (B, S_audio, d), tokens (B, S_text)."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, St = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
        x = x + params["dec_pos"][:St].astype(cfg.cdtype)[None]

        def body(carry, p):
            h = carry + _attn(p["self_attn"], _apply_ln(p["ln1"], carry), _apply_ln(p["ln1"], carry), cfg, causal=True)
            h = h + _attn(p["cross_attn"], _apply_ln(p["ln_x"], h), enc, cfg, causal=False)
            h = h + _mlp(p["mlp"], _apply_ln(p["ln2"], h))
            return constrain(h, "batch", "act_seq", "embed"), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        h = _apply_ln(params["ln_dec"], x)
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
        logits = constrain(logits, "batch", "seq", "vocab")
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        loss, acc = cross_entropy_loss(logits, labels, mask)
        return loss, {"loss": loss, "ce": loss, "acc": acc}

    # ---- serving ------------------------------------------------------------
    def prefill(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        """Encode audio + run decoder over the prompt, building caches."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, St = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
        x = x + params["dec_pos"][:St].astype(cfg.cdtype)[None]
        H, hd = cfg.n_heads, cfg.head_dim

        def body(carry, p):
            xq = _apply_ln(p["ln1"], carry)
            k = jnp.einsum("bsd,dhk->bhsk", xq, p["self_attn"]["wk"].astype(xq.dtype))
            v = jnp.einsum("bsd,dhk->bhsk", xq, p["self_attn"]["wv"].astype(xq.dtype)) + p[
                "self_attn"
            ]["bv"].astype(xq.dtype)[None, :, None, :]
            h = carry + _attn(p["self_attn"], xq, xq, cfg, causal=True)
            xc = _apply_ln(p["ln_x"], h)
            ck = jnp.einsum("bsd,dhk->bhsk", enc, p["cross_attn"]["wk"].astype(xq.dtype))
            cv = jnp.einsum("bsd,dhk->bhsk", enc, p["cross_attn"]["wv"].astype(xq.dtype)) + p[
                "cross_attn"
            ]["bv"].astype(xq.dtype)[None, :, None, :]
            h = h + _attn(p["cross_attn"], xc, enc, cfg, causal=False)
            h = h + _mlp(p["mlp"], _apply_ln(p["ln2"], h))
            return h, {"k": k, "v": v, "ck": ck, "cv": cv}

        x, cache = jax.lax.scan(body, x, params["dec_layers"])
        h = _apply_ln(params["ln_dec"], x)
        logits = jnp.einsum("bsd,vd->bsv", h[:, -1:], params["embed"].astype(h.dtype))
        cache["pos"] = jnp.asarray(St, jnp.int32)
        return logits[:, 0], cache

    def empty_cache(self, batch: int, seq: int, dtype=None) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = dtype or cfg.cdtype
        L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        return {
            "k": jnp.zeros((L, batch, H, seq, hd), dtype),
            "v": jnp.zeros((L, batch, H, seq, hd), dtype),
            "ck": jnp.zeros((L, batch, H, cfg.enc_seq, hd), dtype),
            "cv": jnp.zeros((L, batch, H, cfg.enc_seq, hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        pos = cache["pos"]
        H, hd = cfg.n_heads, cfg.head_dim
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
        x = x + jnp.take(params["dec_pos"], pos[None], axis=0).astype(cfg.cdtype)[None]

        def body(carry, inp):
            p, c = inp
            xq = _apply_ln(p["ln1"], carry)  # (B,1,d)
            B = xq.shape[0]
            q = jnp.einsum("bsd,dhk->bhsk", xq, p["self_attn"]["wq"].astype(xq.dtype)) + p[
                "self_attn"
            ]["bq"].astype(xq.dtype)[None, :, None, :]
            k_new = jnp.einsum("bsd,dhk->bhsk", xq, p["self_attn"]["wk"].astype(xq.dtype))
            v_new = jnp.einsum("bsd,dhk->bhsk", xq, p["self_attn"]["wv"].astype(xq.dtype)) + p[
                "self_attn"
            ]["bv"].astype(xq.dtype)[None, :, None, :]
            k = jax.lax.dynamic_update_slice_in_dim(c["k"], k_new.astype(c["k"].dtype), pos, axis=2)
            v = jax.lax.dynamic_update_slice_in_dim(c["v"], v_new.astype(c["v"].dtype), pos, axis=2)
            scale = 1.0 / math.sqrt(hd)
            S = k.shape[2]
            scores = jnp.einsum("bhqd,bhsd->bhqs", q, k.astype(q.dtype)) * scale
            valid = jnp.arange(S) <= pos
            scores = jnp.where(valid[None, None, None, :], scores, -1e30)
            probs = _softmax_last(scores).astype(xq.dtype)
            a = jnp.einsum("bhqs,bhsd->bhqd", probs, v.astype(xq.dtype))
            a = jnp.einsum("bhqk,hkd->bqd", a[:, :, :, :], p["self_attn"]["wo"].astype(xq.dtype)) + p[
                "self_attn"
            ]["bo"].astype(xq.dtype)
            h = carry + a
            # cross attention against cached encoder K/V
            xc = _apply_ln(p["ln_x"], h)
            qc = jnp.einsum("bsd,dhk->bhsk", xc, p["cross_attn"]["wq"].astype(xq.dtype)) + p[
                "cross_attn"
            ]["bq"].astype(xq.dtype)[None, :, None, :]
            scores = jnp.einsum("bhqd,bhsd->bhqs", qc, c["ck"].astype(qc.dtype)) * scale
            probs = _softmax_last(scores).astype(xq.dtype)
            a = jnp.einsum("bhqs,bhsd->bhqd", probs, c["cv"].astype(xq.dtype))
            a = jnp.einsum("bhqk,hkd->bqd", a, p["cross_attn"]["wo"].astype(xq.dtype)) + p[
                "cross_attn"
            ]["bo"].astype(xq.dtype)
            h = h + a
            h = h + _mlp(p["mlp"], _apply_ln(p["ln2"], h))
            return h, {"k": k, "v": v, "ck": c["ck"], "cv": c["cv"]}

        layer_cache = {k: cache[k] for k in ("k", "v", "ck", "cv")}
        x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], layer_cache))
        h = _apply_ln(params["ln_dec"], x)
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
        new_cache["pos"] = pos + 1
        return logits[:, 0], new_cache
