"""Family -> model class dispatch."""

from __future__ import annotations

from .config import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        from .transformer import TransformerLM

        return TransformerLM(cfg)
    if cfg.family == "ssm":
        from .ssm_lm import Mamba2LM

        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        from .ssm_lm import Zamba2LM

        return Zamba2LM(cfg)
    if cfg.family == "encdec":
        from .whisper import WhisperModel

        return WhisperModel(cfg)
    raise ValueError(f"unknown family {cfg.family}")
