"""Mixture-of-Experts FFN (DeepSeek-V3 / Kimi-K2 style).

Routing: sigmoid gate scores → top-k → selected-gate renormalization, plus a
Switch-style auxiliary load-balancing loss (DeepSeek's bias-based aux-free
balancing is noted in DESIGN.md as a simplification).

Dispatch (baseline, pure pjit): capacity-bounded **scatter dispatch** —
tokens are scattered into an (E·C, d) buffer by slot index (expert·C +
position-in-expert, overflow dropped), expert matmuls run dense, results
gather back with gate weighting. This avoids the O(T·E·C) one-hot einsum
entirely while staying GSPMD-shardable; the shard_map expert-parallel
variant lives in `repro.distributed.moe_ep` (perf hillclimb).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed import sharding as _sh
from ..distributed.sharding import constrain
from .common import Initializer, activation
from .config import ModelConfig


def init_moe(ini: Initializer, cfg: ModelConfig, path: str = "moe") -> Dict[str, Any]:
    m = cfg.moe
    d, ffe = cfg.d_model, m.d_ff_expert
    E = m.n_experts
    p = {
        "router": ini.normal(f"{path}.router", (d, E), scale=0.006),
        "w_gate": ini.fanin(f"{path}.w_gate", (E, d, ffe)),
        "w_up": ini.fanin(f"{path}.w_up", (E, d, ffe)),
        "w_down": ini.fanin(f"{path}.w_down", (E, ffe, d)),
    }
    if m.n_shared:
        ffs = ffe * m.n_shared
        p["shared_gate"] = ini.fanin(f"{path}.shared_gate", (d, ffs))
        p["shared_up"] = ini.fanin(f"{path}.shared_up", (d, ffs))
        p["shared_down"] = ini.fanin(f"{path}.shared_down", (ffs, d))
    return p


def route(
    p: Dict[str, Any], x2d: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (top-k expert ids (T,k), gates (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2d, p["router"].astype(x2d.dtype)).astype(jnp.float32)
    logits = constrain(logits, "moe_rows", None)
    if m.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(scores, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch aux loss: mean prob per expert * mean assignment per expert
    probs = scores / jnp.maximum(jnp.sum(scores, axis=-1, keepdims=True), 1e-9)
    assign = jnp.zeros_like(probs).at[jnp.arange(x2d.shape[0])[:, None], idx].add(1.0)
    aux = jnp.mean(jnp.mean(probs, axis=0) * jnp.mean(assign, axis=0)) * (m.n_experts**2)
    return idx, gates.astype(x2d.dtype), aux * m.aux_loss_coef


def moe_ffn(
    p: Dict[str, Any], x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """(B, S, d) -> (B, S, d), aux_loss. Capacity-bounded gather dispatch.

    Memory plan: only INDEX arrays (O(T·K) int32) are built token-major; the
    wide (d-sized) buffers exist solely in expert-major layout (E, C, d),
    sharded experts->model / capacity->data, so nothing wide is replicated.
    With ``dispatch_chunks`` > 1 the whole dispatch/expert/combine pipeline
    is scanned over token chunks, dividing dispatch transients by the chunk
    count (needed to stay under 16 GiB/chip for the trillion-class configs).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    rules = _sh._rules()
    mesh = _sh._mesh()
    if rules and rules.get("_moe_ep") and mesh is not None:
        from ..distributed.moe_ep import moe_ffn_ep

        batch_axes = rules.get("batch") or ("data",)
        if isinstance(batch_axes, str):
            batch_axes = (batch_axes,)
        return moe_ffn_ep(p, x, cfg, mesh, data_axes=batch_axes)
    x2d = constrain(x.reshape(T, d), "moe_rows", "embed")
    nc = m.dispatch_chunks if (m.dispatch_chunks > 1 and T % m.dispatch_chunks == 0) else 1
    if nc > 1:
        xs = constrain(x2d.reshape(nc, T // nc, d), None, "moe_rows", "embed")

        def body(carry, xc):
            yc, auxc = _moe_tokens(p, xc, cfg)
            return carry, (yc, auxc)

        _, (ys, auxes) = jax.lax.scan(body, None, xs)
        return ys.reshape(B, S, d), jnp.mean(auxes)
    y, aux = _moe_tokens(p, x2d, cfg)
    return y.reshape(B, S, d), aux


def _moe_tokens(p: Dict[str, Any], x2d: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    m = cfg.moe
    T, d = x2d.shape
    E, K = m.n_experts, m.top_k
    C = max(1, int(math.ceil(T * K / E * m.capacity_factor)))
    x2d = constrain(x2d, "moe_rows", "embed")

    idx, gates, aux = route(p, x2d, cfg)  # (T,K)

    # position of each (token, k) within its expert queue via a stable sort
    # (avoids any O(T·E) intermediate; standard MoE permute trick).
    flat_e = idx.reshape(-1)  # (T*K,) token-major order
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    pos_sorted = jnp.arange(T * K) - seg_start[sorted_e]
    pos_in_e = jnp.zeros((T * K,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos_in_e < C
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)  # drop -> OOB slot

    # inverse map slot -> token id (T = sentinel row of zeros)
    flat_tok = (jnp.arange(T * K) // K).astype(jnp.int32)
    tok_for_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(flat_tok)[:-1]
    x2d_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    buf = x2d_pad[tok_for_slot]  # gather: (E*C, d)
    buf = constrain(buf.reshape(E, C, d), "experts", "expert_cap", "embed")

    # dense per-expert FFN (EP: experts model-sharded, capacity data-sharded)
    act = activation(cfg.mlp_act)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x2d.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x2d.dtype))
    h = constrain(act(g) * u, "experts", "expert_cap", None)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x2d.dtype))
    y_buf = constrain(y_buf, "experts", "expert_cap", "embed").reshape(E * C, d)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, d), dtype=x2d.dtype)], axis=0)

    # gather back with gate weighting
    yk = constrain(y_buf[slot], "moe_routes", "embed")
    yk = yk * (gates.reshape(-1, 1) * keep[:, None].astype(x2d.dtype))
    y = jnp.sum(yk.reshape(T, K, d), axis=1)
    y = constrain(y, "moe_rows", "embed")

    if m.n_shared:
        sg = jnp.einsum("td,df->tf", x2d, p["shared_gate"].astype(x2d.dtype))
        su = jnp.einsum("td,df->tf", x2d, p["shared_up"].astype(x2d.dtype))
        y = y + jnp.einsum("tf,fd->td", act(sg) * su, p["shared_down"].astype(x2d.dtype))
    return y, aux
