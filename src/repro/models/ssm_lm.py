"""Mamba2 LM (pure SSM) and Zamba2-style hybrid (Mamba2 + shared attention).

Zamba2's signature trick: ONE shared transformer block (attention + MLP),
whose weights are reused at every invocation point (every
``hybrid_attn_every`` SSM layers). Its input is the concatenation of the
current hidden state with the original embedding output (so the shared
block sees both local and global context), projected back to d_model.
Each invocation keeps its own KV cache slot.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .attention import gqa_attention, gqa_decode, gqa_prefill, init_gqa
from .common import Initializer, embed_lookup, make_norm, stack_init
from .config import ModelConfig
from .ffn import init_mlp, mlp
from .mamba import (
    empty_mamba_cache,
    init_mamba,
    mamba_decode,
    mamba_forward,
)
from .transformer import TransformerLM


def _mamba_layer_init(ini: Initializer, cfg: ModelConfig) -> Dict[str, Any]:
    norm_init, _ = make_norm(cfg.norm)
    return {"ln": norm_init(ini, "ln", cfg.d_model), "ssm": init_mamba(ini, cfg)}


class Mamba2LM(TransformerLM):
    """Pure-SSM LM. Reuses TransformerLM's embedding/loss/serving plumbing."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_dense = 0
        self.n_moe = 0

    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 4)
        ini = Initializer(keys[0], cfg.pdtype)
        norm_init, _ = make_norm(cfg.norm)
        params = {
            "embed": ini.normal("embed", (cfg.vocab, cfg.d_model), scale=1.0 / cfg.d_model**0.5),
            "layers": stack_init(cfg.n_layers, lambda i: _mamba_layer_init(i, cfg), keys[1], cfg.pdtype),
            "ln_f": norm_init(ini, "ln_f", cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = ini.normal("lm_head", (cfg.d_model, cfg.vocab), scale=1.0 / cfg.d_model**0.5)
        return params

    def _backbone(self, params, x, positions):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)

        def body(carry, p):
            h = mamba_forward(p["ssm"], norm(p["ln"], carry), cfg)
            return constrain(carry + h, "batch", "act_seq", "embed"), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return norm(params["ln_f"], x), jnp.zeros((), jnp.float32)

    # ---- serving ----------------------------------------------------------
    def prefill(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x, positions = self._embed_inputs(params, batch)

        def body(carry, p):
            h, (ssm, conv) = mamba_forward(p["ssm"], norm(p["ln"], carry), cfg, return_state=True)
            return carry + h, {"ssm": ssm, "conv_x": conv["x"], "conv_B": conv["B"], "conv_C": conv["C"]}

        x, cache = jax.lax.scan(body, x, params["layers"])
        h = norm(params["ln_f"], x)
        logits = self._logits(params, h[:, -1:, :])
        cache["pos"] = jnp.asarray(x.shape[1], jnp.int32)
        return logits[:, 0], cache

    def empty_cache(self, batch: int, seq: int, dtype=None) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = dtype or cfg.cdtype
        one = empty_mamba_cache(cfg, batch, dtype)
        cache = jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one
        )
        cache["pos"] = jnp.zeros((), jnp.int32)
        return cache

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x = embed_lookup(params["embed"], tokens, cfg.embed_scale, cfg.cdtype)

        def body(carry, inp):
            p, c = inp
            h, c2 = mamba_decode(p["ssm"], norm(p["ln"], carry), c, cfg)
            return carry + h, c2

        layer_cache = {k: cache[k] for k in ("ssm", "conv_x", "conv_B", "conv_C")}
        x, new_cache = jax.lax.scan(body, x, (params["layers"], layer_cache))
        h = norm(params["ln_f"], x)
        logits = self._logits(params, h)
        new_cache["pos"] = cache["pos"] + 1
        return logits[:, 0], new_cache


class Zamba2LM(TransformerLM):
    """Mamba2 backbone + one shared attention(+MLP) block every k layers."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_dense = 0
        self.n_moe = 0
        k = cfg.hybrid_attn_every
        # invocation points AFTER layers k-1, 2k-1, ... (0-indexed)
        self.invocations = [i for i in range(cfg.n_layers) if (i + 1) % k == 0]

    @property
    def attn_cfg(self) -> ModelConfig:
        """Shared block attends over concat([x, x0]) => width 2·d_model."""
        c = self.cfg
        return c.with_(d_model=2 * c.d_model, head_dim=2 * c.d_model // c.n_heads,
                       sliding_window=0, global_every=0, qk_norm=False, qkv_bias=False)

    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 4)
        ini = Initializer(keys[0], cfg.pdtype)
        norm_init, _ = make_norm(cfg.norm)
        acfg = self.attn_cfg
        aini = Initializer(keys[2], cfg.pdtype)
        params = {
            "embed": ini.normal("embed", (cfg.vocab, cfg.d_model), scale=1.0 / cfg.d_model**0.5),
            "layers": stack_init(cfg.n_layers, lambda i: _mamba_layer_init(i, cfg), keys[1], cfg.pdtype),
            "shared": {
                "ln_in": norm_init(aini, "shared.ln_in", 2 * cfg.d_model),
                "attn": init_gqa(aini, acfg, "shared.attn"),
                "out_proj": aini.fanin("shared.out_proj", (2 * cfg.d_model, cfg.d_model)),
                "ln_mlp": norm_init(aini, "shared.ln_mlp", cfg.d_model),
                "mlp": init_mlp(aini, cfg, "shared.mlp"),
            },
            "ln_f": norm_init(ini, "ln_f", cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = ini.normal("lm_head", (cfg.d_model, cfg.vocab), scale=1.0 / cfg.d_model**0.5)
        return params

    # ---- shared block -------------------------------------------------------
    def _shared_block(self, p, x, x0, positions, cache=None, pos=None, prefill=False):
        cfg = self.cfg
        acfg = self.attn_cfg
        _, norm = make_norm(cfg.norm)
        u = jnp.concatenate([x, x0], axis=-1)
        u = norm(p["ln_in"], u)
        if cache is not None and not prefill:
            a, cache = gqa_decode(p["attn"], u, cache, pos, acfg)
        elif prefill:
            a, cache = gqa_prefill(p["attn"], u, acfg, positions=positions)
        else:
            a = gqa_attention(p["attn"], u, acfg, positions=positions)
        # a has width 2d (wo maps back to 2d); project to d and residual-add
        y = jnp.einsum("bsk,kd->bsd", a, p["out_proj"].astype(x.dtype))
        x = x + y
        h = mlp(p["mlp"], norm(p["ln_mlp"], x), cfg)
        return x + h, cache

    def _mamba_segment(self, params, x, lo, hi, decode_cache=None):
        """Run SSM layers [lo, hi) (params statically sliced for scan)."""
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], params["layers"])
        if decode_cache is None:
            def body(carry, p):
                h = mamba_forward(p["ssm"], norm(p["ln"], carry), cfg)
                return carry + h, None
            if cfg.remat:
                body = jax.checkpoint(body, prevent_cse=False)
            x, _ = jax.lax.scan(body, x, seg)
            return x, None
        cache_seg = jax.tree_util.tree_map(lambda a: a[lo:hi], decode_cache)
        def body(carry, inp):
            p, c = inp
            h, c2 = mamba_decode(p["ssm"], norm(p["ln"], carry), c, cfg)
            return carry + h, c2
        x, new_seg = jax.lax.scan(body, x, (seg, cache_seg))
        return x, new_seg

    def _mamba_segment_prefill(self, params, x, lo, hi):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], params["layers"])
        def body(carry, p):
            h, (ssm, conv) = mamba_forward(p["ssm"], norm(p["ln"], carry), cfg, return_state=True)
            return carry + h, {"ssm": ssm, "conv_x": conv["x"], "conv_B": conv["B"], "conv_C": conv["C"]}
        return jax.lax.scan(body, x, seg)

    def _segments(self):
        cfg = self.cfg
        pts = self.invocations
        segs, lo = [], 0
        for p in pts:
            segs.append((lo, p + 1))
            lo = p + 1
        if lo < cfg.n_layers:
            segs.append((lo, cfg.n_layers))
        return segs, len(pts)

    def _backbone(self, params, x, positions):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x0 = x
        segs, n_inv = self._segments()
        for i, (lo, hi) in enumerate(segs):
            x, _ = self._mamba_segment(params, x, lo, hi)
            if i < n_inv:
                x, _ = self._shared_block(params["shared"], x, x0, positions)
            x = constrain(x, "batch", "act_seq", "embed")
        return norm(params["ln_f"], x), jnp.zeros((), jnp.float32)

    # ---- serving ------------------------------------------------------------
    def prefill(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x, positions = self._embed_inputs(params, batch)
        x0 = x
        segs, n_inv = self._segments()
        ssm_caches, attn_caches = [], []
        for i, (lo, hi) in enumerate(segs):
            x, c = self._mamba_segment_prefill(params, x, lo, hi)
            ssm_caches.append(c)
            if i < n_inv:
                x, ac = self._shared_block(params["shared"], x, x0, positions, prefill=True)
                attn_caches.append(ac)
        cache = {
            "ssm": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *ssm_caches),
            "attn": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *attn_caches),
            "pos": jnp.asarray(x.shape[1], jnp.int32),
        }
        h = norm(params["ln_f"], x)
        logits = self._logits(params, h[:, -1:, :])
        return logits[:, 0], cache

    def empty_cache(self, batch: int, seq: int, dtype=None) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = dtype or cfg.cdtype
        acfg = self.attn_cfg
        one = empty_mamba_cache(cfg, batch, dtype)
        _, n_inv = self._segments()
        return {
            "ssm": jax.tree_util.tree_map(
                lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one
            ),
            "attn": {
                "k": jnp.zeros((n_inv, batch, acfg.n_kv_heads, seq, acfg.head_dim), dtype),
                "v": jnp.zeros((n_inv, batch, acfg.n_kv_heads, seq, acfg.head_dim), dtype),
            },
            "pos": jnp.zeros((), jnp.int32),
        }

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        pos = cache["pos"]
        x = embed_lookup(params["embed"], tokens, cfg.embed_scale, cfg.cdtype)
        x0 = x
        segs, n_inv = self._segments()
        new_ssm, new_attn = [], []
        for i, (lo, hi) in enumerate(segs):
            x, c = self._mamba_segment(params, x, lo, hi, decode_cache=cache["ssm"])
            new_ssm.append(c)
            if i < n_inv:
                ac = jax.tree_util.tree_map(lambda a: a[i], cache["attn"])
                x, ac2 = self._shared_block(params["shared"], x, x0, None, cache=ac, pos=pos)
                new_attn.append(ac2)
        new_cache = {
            "ssm": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm),
            "attn": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *new_attn),
            "pos": pos + 1,
        }
        h = norm(params["ln_f"], x)
        logits = self._logits(params, h)
        return logits[:, 0], new_cache
