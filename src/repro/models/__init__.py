"""Model zoo."""

from .config import MLAConfig, MoEConfig, ModelConfig, SSMConfig
from .registry import build_model

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "build_model"]
