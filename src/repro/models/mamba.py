"""Mamba2 (SSD — state-space duality) block: chunked train scan + O(1) decode.

Implements the SSD chunked algorithm (arXiv:2405.21060): within a chunk the
quadratic "attention-like" form runs on the MXU; across chunks a small
(H, P, N) state is carried by `lax.scan`. Recurrence convention::

    h_t = exp(dt_t · A_h) · h_{t-1} + B_t ⊗ (dt_t · x_t)
    y_t = C_t · h_t + D_h · x_t

Decode is a constant-time state update — the reason long_500k decode is
trivially cheap for SSM archs (no KV growth).

Sharding note: the canonical fused ``in_proj`` (z|x|B|C|dt) is split into
separate projections here so each output dim can be model-sharded without
resharding at the split boundaries (depthwise conv is per-channel, so
per-component convs are mathematically identical to the fused one).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import Initializer, rmsnorm
from .config import ModelConfig


def init_mamba(ini: Initializer, cfg: ModelConfig, path: str = "ssm") -> Dict[str, Any]:
    s = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.n_ssm_heads
    G, N, W = s.n_groups, s.d_state, s.conv_width
    GN = G * N
    return {
        "w_z": ini.fanin(f"{path}.w_z", (d, di)),
        "w_x": ini.fanin(f"{path}.w_x", (d, di)),
        "w_B": ini.fanin(f"{path}.w_B", (d, GN)),
        "w_C": ini.fanin(f"{path}.w_C", (d, GN)),
        "w_dt": ini.fanin(f"{path}.w_dt", (d, H)),
        "conv_x_w": ini.normal(f"{path}.conv_x_w", (di, W), scale=0.1),
        "conv_x_b": ini.zeros(f"{path}.conv_x_b", (di,)),
        "conv_B_w": ini.normal(f"{path}.conv_B_w", (GN, W), scale=0.1),
        "conv_B_b": ini.zeros(f"{path}.conv_B_b", (GN,)),
        "conv_C_w": ini.normal(f"{path}.conv_C_w", (GN, W), scale=0.1),
        "conv_C_b": ini.zeros(f"{path}.conv_C_b", (GN,)),
        "A_log": ini.value(f"{path}.A_log", jnp.log(jnp.linspace(1.0, 16.0, H))),
        "D": ini.ones(f"{path}.D", (H,)),
        "dt_bias": ini.zeros(f"{path}.dt_bias", (H,)),
        "norm": ini.zeros(f"{path}.norm", (di,)),
        "out_proj": ini.fanin(f"{path}.out_proj", (di, d)),
    }


def _proj(p, x, cfg: ModelConfig):
    """Returns (z, x_in, B_in, C_in, dt) — pre-conv."""
    w = lambda name: p[name].astype(x.dtype)
    z = jnp.einsum("bsd,dk->bsk", x, w("w_z"))
    xi = jnp.einsum("bsd,dk->bsk", x, w("w_x"))
    Bi = jnp.einsum("bsd,dk->bsk", x, w("w_B"))
    Ci = jnp.einsum("bsd,dk->bsk", x, w("w_C"))
    dt = jnp.einsum("bsd,dk->bsk", x, w("w_dt"))
    return z, xi, Bi, Ci, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x (B,S,C), w (C,W)."""
    B, S, C = x.shape
    W = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i : i + S, :] * w[:, i].astype(x.dtype)
    return jax.nn.silu(out + b.astype(x.dtype))


def _conv_step(window: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """window (B, W, C) -> (B, C): one causal-conv output."""
    out = jnp.sum(window * w.T[None].astype(window.dtype), axis=1)
    return jax.nn.silu(out + b.astype(window.dtype))


def ssd_chunked(
    u: jax.Array,     # (B, L, H, P)   inputs already scaled by dt
    dtA: jax.Array,   # (B, L, H)      per-step log decay (dt * A, negative)
    Bm: jax.Array,    # (B, L, N)      input matrix (n_groups=1)
    Cm: jax.Array,    # (B, L, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, P = u.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, f"L={L} not divisible by chunk={Q}"
    nc = L // Q
    ur = u.reshape(B, nc, Q, H, P)
    Ar = dtA.reshape(B, nc, Q, H)
    Br = Bm.reshape(B, nc, Q, N)
    Cr = Cm.reshape(B, nc, Q, N)

    Acs = jnp.cumsum(Ar.astype(jnp.float32), axis=2)  # (B,nc,Q,H)
    # intra-chunk: Y_diag[i] = sum_{j<=i} (C_i·B_j) exp(Acs_i - Acs_j) u_j
    diff = Acs[:, :, :, None, :] - Acs[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    # mask BEFORE exp: upper-triangle diffs are positive and exp overflows to
    # inf, whose 0·inf VJP poisons the whole backward pass
    diff = jnp.where(tri[None, None, :, :, None], diff, -1e9)
    L_mat = jnp.exp(diff).astype(u.dtype)
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # (B,nc,Q,Q)
    Y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L_mat, ur)

    # end-of-chunk states: sum_j exp(Acs_last - Acs_j) B_j u_j
    decay_states = jnp.exp(Acs[:, :, -1:, :] - Acs).astype(u.dtype)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Br, decay_states, ur)

    chunk_decay = jnp.exp(Acs[:, :, -1, :]).astype(u.dtype)  # (B,nc,H)

    def body(s, inp):
        st_c, dec_c = inp  # (B,H,P,N), (B,H)
        prev = s
        s = s * dec_c[:, :, None, None] + st_c
        return s, prev

    s0 = jnp.zeros((B, H, P, N), dtype=u.dtype) if h0 is None else h0.astype(u.dtype)
    final, prev_states = jax.lax.scan(
        body, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,P,N)

    # inter-chunk contribution: Y_off[i] = C_i · (exp(Acs_i) ⊙ h_chunk_start)
    in_decay = jnp.exp(Acs).astype(u.dtype)  # (B,nc,Q,H)
    Y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", Cr, in_decay, prev_states)

    y = (Y_diag + Y_off).reshape(B, L, H, P)
    return y, final


def mamba_forward(
    p: Dict[str, Any],
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    h0: jax.Array | None = None,
    return_state: bool = False,
):
    """Training / prefill pass. Returns (B,S,d) [and final (ssm, conv caches)]."""
    s = cfg.ssm
    di, H, P = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm.headdim
    N = s.d_state
    z, xi, Bi, Ci, dt = _proj(p, x, cfg)
    xs = _causal_conv(xi, p["conv_x_w"], p["conv_x_b"])
    Bm = _causal_conv(Bi, p["conv_B_w"], p["conv_B_b"])
    Cm = _causal_conv(Ci, p["conv_C_w"], p["conv_C_b"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    dtA = dt * A  # (B,S,H)
    xh = xs.reshape(*xs.shape[:2], H, P)
    u = xh * dt[..., None].astype(x.dtype)
    y, final = ssd_chunked(u, dtA, Bm, Cm, s.chunk, h0=h0)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        W = s.conv_width
        # conv tails: last W-1 *pre-conv* inputs, for decode continuation
        tail = x[:, -(W - 1) :, :]
        _, xi_t, Bi_t, Ci_t, _ = _proj(p, tail, cfg)
        return out, (final, {"x": xi_t, "B": Bi_t, "C": Ci_t})
    return out


def mamba_decode(
    p: Dict[str, Any],
    x: jax.Array,  # (B, 1, d)
    cache: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """O(1) recurrent step. cache: ssm (B,H,P,N), conv_{x,B,C} (B,W-1,·)."""
    s = cfg.ssm
    di, H, P, N = cfg.d_inner, cfg.n_ssm_heads, s.headdim, s.d_state
    z, xi, Bi, Ci, dt = _proj(p, x, cfg)
    win_x = jnp.concatenate([cache["conv_x"], xi.astype(cache["conv_x"].dtype)], axis=1)
    win_B = jnp.concatenate([cache["conv_B"], Bi.astype(cache["conv_B"].dtype)], axis=1)
    win_C = jnp.concatenate([cache["conv_C"], Ci.astype(cache["conv_C"].dtype)], axis=1)
    xs = _conv_step(win_x.astype(x.dtype), p["conv_x_w"], p["conv_x_b"])  # (B, di)
    Bm = _conv_step(win_B.astype(x.dtype), p["conv_B_w"], p["conv_B_b"])  # (B, N)
    Cm = _conv_step(win_C.astype(x.dtype), p["conv_C_w"], p["conv_C_b"])
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt1 * A).astype(x.dtype)  # (B,H)
    xh = xs.reshape(-1, H, P)
    u = xh * dt1[..., None].astype(x.dtype)  # (B,H,P)
    state = cache["ssm"].astype(x.dtype) * dec[:, :, None, None] + (
        u[..., None] * Bm[:, None, None, :]
    )
    y = jnp.einsum("bhpn,bn->bhp", state, Cm) + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(-1, 1, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    new_cache = {
        "ssm": state.astype(cache["ssm"].dtype),
        "conv_x": win_x[:, 1:],
        "conv_B": win_B[:, 1:],
        "conv_C": win_C[:, 1:],
    }
    return out, new_cache


def empty_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    s = cfg.ssm
    H, P, N = cfg.n_ssm_heads, s.headdim, s.d_state
    GN = s.n_groups * N
    W = s.conv_width
    return {
        "ssm": jnp.zeros((batch, H, P, N), dtype=dtype),
        "conv_x": jnp.zeros((batch, W - 1, cfg.d_inner), dtype=dtype),
        "conv_B": jnp.zeros((batch, W - 1, GN), dtype=dtype),
        "conv_C": jnp.zeros((batch, W - 1, GN), dtype=dtype),
    }
