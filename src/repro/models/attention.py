"""Attention variants: GQA (chunked-causal / sliding-window / decode) and
DeepSeek-style MLA (train + absorbed latent-cache decode).

Design notes
------------
* Train/prefill attention is **chunked over query blocks** (online per-chunk
  softmax over the full KV with masking) so the S×S score matrix is never
  materialized in HBM — this is both the memory-sane lowering for the
  dry-run and the pure-JAX reference for the Pallas flash kernel.
* Softmax is written with explicit max/sum reductions so that when the KV
  sequence axis is sharded (context parallelism for long_500k decode),
  GSPMD inserts the all-reduces automatically.
* All masks are arithmetic (no boolean control flow), so a scanned layer
  stack can flip local/global behaviour per layer with a traced flag.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Initializer, apply_rope, rmsnorm
from .config import ModelConfig

NEG_INF = -1e30


# ----------------------------------------------------------------- helpers
def _mask_bias(qpos, kpos, window, is_global):
    """(..., Sq, Sk) additive mask. window > 0 limits lookback unless
    is_global (traced scalar 0/1) promotes the layer to full attention."""
    causal = kpos[None, :] <= qpos[:, None]
    ok = causal
    if window:
        in_window = kpos[None, :] > qpos[:, None] - window
        full = jnp.asarray(is_global, dtype=jnp.bool_)
        ok = causal & (in_window | full)
    return jnp.where(ok, 0.0, NEG_INF)


def _softmax_last(scores: jax.Array) -> jax.Array:
    """f32 softmax via explicit max/sum (SP/context-parallel friendly)."""
    s = scores.astype(jnp.float32)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    return e / jnp.sum(e, axis=-1, keepdims=True)



def _pad_seq(x: jax.Array, axis: int, chunk: int) -> jax.Array:
    """Zero-pad ``axis`` up to a multiple of ``chunk`` (query-chunk padding;
    padded rows are sliced off after the scan so values are don't-cares)."""
    S = x.shape[axis]
    pad = (-S) % chunk
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ----------------------------------------------------------------- GQA init
def init_gqa(ini: Initializer, cfg: ModelConfig, path: str = "attn") -> Dict[str, Any]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Hp = H + cfg.head_pad
    wq = ini.fanin(f"{path}.wq", (d, Hp, hd))
    wo = ini.fanin(f"{path}.wo", (Hp, hd, d))
    if cfg.head_pad:
        # zero the padded head slices: padded heads contribute exactly 0 to
        # the output AND receive exactly 0 gradient (wo rows are zero), so
        # the padded model is numerically identical to the unpadded one.
        import jax.numpy as _jnp

        wq = wq.at[:, H:, :].set(0)
        wo = wo.at[H:, :, :].set(0)
    p: Dict[str, Any] = {
        "wq": wq,
        "wk": ini.fanin(f"{path}.wk", (d, KV, hd)),
        "wv": ini.fanin(f"{path}.wv", (d, KV, hd)),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = ini.zeros(f"{path}.bq", (Hp, hd))
        p["bk"] = ini.zeros(f"{path}.bk", (KV, hd))
        p["bv"] = ini.zeros(f"{path}.bv", (KV, hd))
    if cfg.qk_norm:
        p["q_norm"] = ini.zeros(f"{path}.q_norm", (hd,))
        p["k_norm"] = ini.zeros(f"{path}.k_norm", (hd,))
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, rope_theta):
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)[None, :, None, :]
        k = k + p["bk"].astype(x.dtype)[None, :, None, :]
        v = v + p["bv"].astype(x.dtype)[None, :, None, :]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def gqa_attention(
    p: Dict[str, Any],
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # (S,)
    is_global=1,
    rope_theta: Optional[jax.Array] = None,
    chunk: int = 512,
) -> jax.Array:
    """Training / prefill attention. Returns (B, S, d)."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads + cfg.head_pad, cfg.n_kv_heads, cfg.head_dim
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    q, k, v = _project_qkv(p, x, cfg, positions, theta)
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, S)
    q = _pad_seq(q, 2, chunk)
    qpos_all = _pad_seq(positions, 0, chunk)
    Sp = q.shape[2]
    q = q.reshape(B, KV, g, Sp, hd)
    n_chunks = Sp // chunk
    kpos = positions

    def body(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=3)
        qpos = jax.lax.dynamic_slice_in_dim(qpos_all, i * chunk, chunk, axis=0)
        scores = jnp.einsum("bkgcd,bksd->bkgcs", qi, k) * scale
        bias = _mask_bias(qpos, kpos, cfg.sliding_window, is_global)
        probs = _softmax_last(scores + bias).astype(x.dtype)
        out = jnp.einsum("bkgcs,bksd->bkgcd", probs, v)
        return carry, out

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # outs: (n_chunks, B, KV, g, chunk, hd) -> (B, Sp, H, hd) -> slice S
    out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, g, Sp, hd)[:, :, :, :S].reshape(B, H, S, hd)
    out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out


def gqa_prefill(
    p, x, cfg: ModelConfig, *, positions, is_global=1, rope_theta=None, chunk: int = 512
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: same as train attention but also returns the KV cache."""
    B, S, d = x.shape
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    q, k, v = _project_qkv(p, x, cfg, positions, theta)
    H, KV, hd = cfg.n_heads + cfg.head_pad, cfg.n_kv_heads, cfg.head_dim
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, S)
    q = _pad_seq(q, 2, chunk)
    qpos_all = _pad_seq(positions, 0, chunk)
    Sp = q.shape[2]
    q = q.reshape(B, KV, g, Sp, hd)
    n_chunks = Sp // chunk

    def body(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=3)
        qpos = jax.lax.dynamic_slice_in_dim(qpos_all, i * chunk, chunk, axis=0)
        scores = jnp.einsum("bkgcd,bksd->bkgcs", qi, k) * scale
        bias = _mask_bias(qpos, positions, cfg.sliding_window, is_global)
        probs = _softmax_last(scores + bias).astype(x.dtype)
        out = jnp.einsum("bkgcs,bksd->bkgcd", probs, v)
        return carry, out

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, g, Sp, hd)[:, :, :, :S].reshape(B, H, S, hd)
    out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, {"k": k, "v": v}


def gqa_decode(
    p: Dict[str, Any],
    x: jax.Array,  # (B, 1, d)
    cache: Dict[str, jax.Array],  # k/v: (B, KV, S, hd)
    pos: jax.Array,  # scalar current position (tokens < pos are valid)
    cfg: ModelConfig,
    *,
    is_global=1,
    rope_theta: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. Returns (out (B,1,d), updated cache)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads + cfg.head_pad, cfg.n_kv_heads, cfg.head_dim
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    positions = jnp.full((1,), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=2)
    g = H // KV
    q = q.reshape(B, KV, g, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", q, k.astype(q.dtype)) * scale
    S = k.shape[2]
    kpos = jnp.arange(S)
    valid = kpos <= pos
    if cfg.sliding_window:
        in_window = kpos > pos - cfg.sliding_window
        full = jnp.asarray(is_global, dtype=jnp.bool_)
        valid = valid & (in_window | full)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = _softmax_last(scores).astype(x.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v.astype(x.dtype))
    out = out.reshape(B, H, hd)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(x.dtype))[:, None, :]
    return out, {"k": k, "v": v}


# ----------------------------------------------------------------- MLA
def init_mla(ini: Initializer, cfg: ModelConfig, path: str = "attn") -> Dict[str, Any]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ini.fanin(f"{path}.wq_a", (d, m.q_lora_rank)),
        "q_norm": ini.zeros(f"{path}.q_norm", (m.q_lora_rank,)),
        "wq_b": ini.fanin(f"{path}.wq_b", (m.q_lora_rank, H, qk)),
        "wkv_a": ini.fanin(f"{path}.wkv_a", (d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "kv_norm": ini.zeros(f"{path}.kv_norm", (m.kv_lora_rank,)),
        "wkv_b": ini.fanin(
            f"{path}.wkv_b", (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
        ),
        "wo": ini.fanin(f"{path}.wo", (H, m.v_head_dim, d)),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    """Returns q (B,H,S,qk), latent (B,S,r), k_rope (B,1,S,rope)."""
    m = cfg.mla
    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype))
    q = rmsnorm(q, p["q_norm"])
    q = jnp.einsum("bsr,rhk->bhsk", q, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    latent, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    latent = rmsnorm(latent, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # (B,1,S,rope)
    return q_nope, q_rope, latent, k_rope


def mla_attention(
    p, x, cfg: ModelConfig, *, positions, chunk: int = 512, with_cache: bool = False
):
    """Train/prefill MLA attention (expanded form). Optionally returns the
    latent cache (what deepseek decode actually stores)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, x, cfg, positions)
    kv = jnp.einsum("bsr,rhk->bhsk", latent, p["wkv_b"].astype(x.dtype))
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, H, S, m.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    chunk = min(chunk, S)
    q = _pad_seq(q, 2, chunk)
    qpos_all = _pad_seq(positions, 0, chunk)
    Sp = q.shape[2]
    n_chunks = Sp // chunk

    def body(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=2)
        qpos = jax.lax.dynamic_slice_in_dim(qpos_all, i * chunk, chunk, axis=0)
        scores = jnp.einsum("bhcd,bhsd->bhcs", qi, k) * scale
        bias = _mask_bias(qpos, positions, 0, 1)
        probs = _softmax_last(scores + bias).astype(x.dtype)
        return carry, jnp.einsum("bhcs,bhsd->bhcd", probs, v)

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, Sp, m.v_head_dim)[:, :, :S]
    out = jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if with_cache:
        return out, {"latent": latent, "k_rope": k_rope[:, 0]}
    return out


def mla_decode(
    p,
    x: jax.Array,  # (B, 1, d)
    cache: Dict[str, jax.Array],  # latent (B,S,r), k_rope (B,S,rope)
    pos: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-matmul MLA decode: attention runs in the latent space, so the
    per-token cache is only r + rope_dim floats (the paper's MLA win)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    positions = jnp.full((1,), pos, dtype=jnp.int32)
    q_nope, q_rope, latent_new, k_rope_new = _mla_qkv(p, x, cfg, positions)
    latent = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], latent_new.astype(cache["latent"].dtype), pos, axis=1
    )
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, 0].astype(cache["k_rope"].dtype), pos, axis=1
    )
    # absorb W^kv_b (k part) into q: q_lat (B,H,r)
    wkv_k = p["wkv_b"][:, :, : m.qk_nope_head_dim].astype(x.dtype)  # (r,H,nope)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, :, 0], wkv_k)
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, latent.astype(x.dtype))
    scores = scores + jnp.einsum("bhk,bsk->bhs", q_rope[:, :, 0], k_rope.astype(x.dtype))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    S = latent.shape[1]
    valid = jnp.arange(S) <= pos
    scores = jnp.where(valid[None, None, :], scores * scale, NEG_INF)
    probs = _softmax_last(scores).astype(x.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, latent.astype(x.dtype))  # (B,H,r)
    # absorb W^kv_b (v part) then output proj
    wkv_v = p["wkv_b"][:, :, m.qk_nope_head_dim :].astype(x.dtype)  # (r,H,v)
    out = jnp.einsum("bhr,rhv->bhv", ctx, wkv_v)
    out = jnp.einsum("bhv,hvd->bd", out, p["wo"].astype(x.dtype))[:, None, :]
    return out, {"latent": latent, "k_rope": k_rope}
