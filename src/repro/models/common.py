"""Shared building blocks: init helpers, norms, RoPE, embeddings, losses.

Parameters are plain nested dicts of jnp arrays (pytrees), so the whole
model state is transparently compatible with `jax.eval_shape` (abstract
dry-run init), `jax.tree_util` mapping for partition specs, and the
RawArray checkpoint store (one leaf = one .ra file).
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------- init
class Initializer:
    """Deterministic per-path param init: fold the path string into the key
    so layer stacking (vmap over leading axis) stays reproducible."""

    def __init__(self, key: jax.Array, dtype):
        self.key = key
        self.dtype = dtype

    def _fold(self, path: str) -> jax.Array:
        h = jnp.uint32(abs(hash(path)) % (2**31))
        return jax.random.fold_in(self.key, h)

    def normal(self, path: str, shape, scale: float = 0.02) -> jax.Array:
        return (
            jax.random.normal(self._fold(path), shape, dtype=jnp.float32) * scale
        ).astype(self.dtype)

    def fanin(self, path: str, shape) -> jax.Array:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return self.normal(path, shape, scale=1.0 / math.sqrt(fan_in))

    def zeros(self, path: str, shape) -> jax.Array:
        return jnp.zeros(shape, dtype=self.dtype)

    def ones(self, path: str, shape) -> jax.Array:
        return jnp.ones(shape, dtype=self.dtype)

    def value(self, path: str, val) -> jax.Array:
        return jnp.asarray(val, dtype=self.dtype)


def stack_init(n: int, init_fn: Callable[[Initializer], Params], key, dtype) -> Params:
    """Initialize ``n`` layers and stack each leaf on a leading axis, for
    ``lax.scan`` over layers."""
    def one(k):
        return init_fn(Initializer(k, dtype))
    keys = jax.random.split(key, n)
    return jax.vmap(one)(keys)


# --------------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    if weight is not None:
        y = y * (1.0 + weight.astype(jnp.float32))
    return y.astype(dt)


def layernorm(
    x: jax.Array,
    weight: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    eps: float = 1e-5,
) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def make_norm(kind: str):
    """Return (init_fn(ini, path, d) -> params|None, apply_fn(params, x))."""
    if kind == "rmsnorm":
        return (
            lambda ini, path, d: {"scale": ini.zeros(path + ".scale", (d,))},
            lambda p, x: rmsnorm(x, p["scale"]),
        )
    if kind == "layernorm":
        return (
            lambda ini, path, d: {
                "scale": ini.ones(path + ".scale", (d,)),
                "bias": ini.zeros(path + ".bias", (d,)),
            },
            lambda p, x: layernorm(x, p["scale"], p["bias"]),
        )
    if kind == "layernorm_np":  # olmo: non-parametric
        return (lambda ini, path, d: {}, lambda p, x: layernorm(x))
    raise ValueError(f"unknown norm {kind}")


# --------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, head_dim), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope_interleaved_theta(
    x: jax.Array, positions: jax.Array, theta_a: float, theta_b: float, use_b
) -> jax.Array:
    """Select between two RoPE bases per-layer inside a scan (gemma3)."""
    a = apply_rope(x, positions, theta_a)
    b = apply_rope(x, positions, theta_b)
    return jnp.where(use_b, b, a)


# --------------------------------------------------------------------- misc
def activation(kind: str):
    if kind == "silu":
        return jax.nn.silu
    if kind == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind}")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def cross_entropy_loss(
    logits: jax.Array,  # (B, S, V) possibly sharded on V
    labels: jax.Array,  # (B, S) int32
    mask: Optional[jax.Array] = None,  # (B, S) 1.0 = count
    z_loss: float = 0.0,
) -> Tuple[jax.Array, jax.Array]:
    """Numerically stable CE written as explicit max/sum reductions so GSPMD
    inserts all-reduces when the vocab dim is model-sharded (full logits are
    never gathered)."""
    logits32 = logits.astype(jnp.float32)
    m = jnp.max(logits32, axis=-1, keepdims=True)
    shifted = logits32 - jax.lax.stop_gradient(m)
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    lse = jnp.log(sumexp) + m[..., 0]
    label_logit = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / total
    acc = jnp.sum((jnp.argmax(logits32, axis=-1) == labels) * mask) / total
    return loss, acc


def embed_lookup(table: jax.Array, ids: jax.Array, scale: bool, cdtype) -> jax.Array:
    x = jnp.take(table, ids, axis=0).astype(cdtype)
    if scale:
        x = x * jnp.asarray(math.sqrt(table.shape[1]), dtype=cdtype)
    return x
