"""The training loop: RawArray data in, RawArray checkpoints out.

Fault-tolerance contract (DESIGN.md §3):

* periodic async checkpoints (params + optimizer + loader state) via the
  atomic-publish RawArray store;
* SIGTERM/SIGINT → synchronous checkpoint-and-exit (preemption-safe);
* ``train(..., resume=True)`` restores the latest checkpoint INCLUDING the
  data-iterator position (exact-once sample order);
* per-step wall-time EWMA + outlier log = straggler monitor (on a real
  fleet this feeds the scheduler; here it catches host-side data stalls).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager, restore_naive, restore_pipelined
from ..data import DataLoader, LoaderState
from ..distributed import optimizer as optim
from ..models.config import ModelConfig


@dataclass
class TrainLoopConfig:
    steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.5  # step slower than factor x EWMA -> flag
    adamw: optim.AdamWConfig = field(default_factory=optim.AdamWConfig)


def train(
    model,
    loader: DataLoader,
    loop_cfg: TrainLoopConfig,
    *,
    step_fn: Optional[Callable] = None,
    resume: bool = True,
    restore_mode: str = "pipelined",
    init_rng: int = 0,
    hooks: Optional[List[Callable[[int, Dict[str, float]], None]]] = None,
) -> Dict[str, Any]:
    """Single-host training driver (the e2e example path). Returns summary."""
    cfg: ModelConfig = model.cfg
    adamw = loop_cfg.adamw

    params = model.init(jax.random.PRNGKey(init_rng))
    opt_state = optim.init_state(params, adamw)

    if step_fn is None:

        def _step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.train_loss(p, batch), has_aux=True
            )(params)
            params, opt_state, info = optim.apply_updates(params, grads, opt_state, adamw)
            return params, opt_state, {**metrics, **info}

        step_fn = jax.jit(_step, donate_argnums=(0, 1))

    cm = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
    start_step = 0
    if resume and cm.latest() is not None:
        s = cm.latest()
        # overlapped cold-start restore straight to device (DESIGN.md §13);
        # restore_mode="naive" keeps the phase-by-phase baseline reachable
        restore_fn = restore_pipelined if restore_mode == "pipelined" else restore_naive
        params, opt_state, extra = restore_fn(cm.path(s), params, opt_state)
        if "loader" in extra:
            loader.restore(LoaderState.from_dict(extra["loader"]))
        start_step = s
        print(f"[train] resumed from step {s}")

    # --- preemption handling -------------------------------------------------
    preempted = {"flag": False}

    def _on_signal(signum, frame):
        preempted["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        old_handlers[sig] = signal.signal(sig, _on_signal)

    losses: List[float] = []
    ewma = None
    stragglers = 0
    last_state: Optional[LoaderState] = None
    t_train0 = time.perf_counter()
    step = start_step
    try:
        while step < loop_cfg.steps:
            batch = next(loader)
            last_state = batch.pop("_state")
            jbatch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, jbatch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if ewma is None:
                ewma = dt
            elif dt > loop_cfg.straggler_factor * ewma and step > start_step + 3:
                stragglers += 1
                print(f"[straggler] step {step}: {dt*1e3:.1f}ms vs EWMA {ewma*1e3:.1f}ms")
            ewma = 0.9 * (ewma if ewma else dt) + 0.1 * dt
            losses.append(loss)
            step += 1
            if step % loop_cfg.log_every == 0:
                print(
                    f"[train] step {step} loss={loss:.4f} "
                    f"acc={float(metrics.get('acc', 0)):.3f} {dt*1e3:.0f}ms"
                )
            if hooks:
                for h in hooks:
                    h(step, {k: float(v) for k, v in metrics.items()})
            if step % loop_cfg.ckpt_every == 0 or preempted["flag"]:
                cm.save(
                    step, params, opt_state,
                    extra={"loader": last_state.to_dict(), "loss": loss},
                )
            if preempted["flag"]:
                cm.wait()
                print(f"[train] preempted at step {step}; checkpoint flushed")
                break
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
        loader.stop()

    cm.wait()
    wall = time.perf_counter() - t_train0
    if step > start_step and step % loop_cfg.ckpt_every != 0 and not preempted["flag"]:
        cm.save(step, params, opt_state, extra={"loader": last_state.to_dict() if last_state else {}})
        cm.wait()
    return {
        "params": params,
        "opt_state": opt_state,
        "losses": losses,
        "steps": step,
        "wall_s": wall,
        "stragglers": stragglers,
        "loader_stats": loader.stats(),
        "ckpt_save_s": cm.save_s,
        "preempted": preempted["flag"],
    }
