"""Fault-tolerant training loop."""

from .loop import TrainLoopConfig, train

__all__ = ["train", "TrainLoopConfig"]
