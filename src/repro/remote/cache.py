"""Block-aligned LRU byte cache for the remote data plane (DESIGN.md §9).

Sits between ``RemoteReader`` and the sockets: every fetched byte lands in
fixed-size blocks keyed by ``(tag, block_index)`` where ``tag`` identifies
one remote object *version* (URL + ETag), so repeated epoch traversals of a
remote dataset are served from RAM instead of the wire, and a file that
changes on the server can never satisfy hits from its stale bytes.

Knobs (read at construction):

==========================  ====================================  =========
variable                    meaning                               default
==========================  ====================================  =========
``RA_REMOTE_BLOCK``         cache block size in bytes             256 KiB
``RA_REMOTE_CACHE_MB``      total cache capacity in MiB           256
==========================  ====================================  =========

256 KiB blocks keep read amplification low for scattered row gathers
(a sparse row costs one block, not megabytes) while bulk reads coalesce
runs of missing blocks into single large ranged requests anyway.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from ..core.spec import env_int as _env_int


def default_block_bytes() -> int:
    return max(1 << 12, _env_int("RA_REMOTE_BLOCK", 1 << 18))


def default_capacity_bytes() -> int:
    return max(0, _env_int("RA_REMOTE_CACHE_MB", 256)) << 20


class BlockCache:
    """Thread-safe LRU over fixed-size byte blocks with hit/miss/eviction
    counters. A zero capacity disables caching (every ``get`` is a miss and
    ``put`` is a no-op), which keeps call sites branch-free.

    Counter discipline (audited for the fleet tier, DESIGN.md §14): every
    counter mutation happens inside ``self._lock`` — the same lock that
    guards the block map — so concurrent readers (the threaded client pool,
    edge-tier request threads) can never lose increments to a read-modify-
    write race, and ``stats()`` always reports a consistent snapshot.
    External code must treat the bare ``hits``/``misses``/``evictions``
    attributes as read-only observables and go through ``stats()`` for
    anything that needs cross-counter consistency (e.g. ``hit_ratio``)."""

    def __init__(
        self,
        block_bytes: Optional[int] = None,
        capacity_bytes: Optional[int] = None,
    ):
        self.block_bytes = int(block_bytes or default_block_bytes())
        self.capacity_bytes = (
            default_capacity_bytes() if capacity_bytes is None else int(capacity_bytes)
        )
        self._lock = threading.Lock()
        self._blocks: "OrderedDict[Tuple[str, int], bytes]" = OrderedDict()  # guarded-by: _lock
        self._nbytes = 0        # guarded-by: _lock
        self.hits = 0           # guarded-by: _lock
        self.misses = 0         # guarded-by: _lock
        self.evictions = 0      # guarded-by: _lock
        self.invalidations = 0  # guarded-by: _lock

    def __len__(self) -> int:
        return len(self._blocks)

    @property
    def nbytes(self) -> int:
        return self._nbytes

    def get(self, tag: str, block_index: int) -> Optional[bytes]:
        """Return the cached block (bumping it to most-recently-used), or
        ``None`` on a miss."""
        key = (tag, block_index)
        with self._lock:
            data = self._blocks.get(key)
            if data is None:
                self.misses += 1
                return None
            self._blocks.move_to_end(key)
            self.hits += 1
            return data

    def put(self, tag: str, block_index: int, data: bytes) -> None:
        if self.capacity_bytes <= 0 or len(data) > self.capacity_bytes:
            return
        key = (tag, block_index)
        with self._lock:
            old = self._blocks.pop(key, None)
            if old is not None:
                self._nbytes -= len(old)
            self._blocks[key] = data
            self._nbytes += len(data)
            while self._nbytes > self.capacity_bytes:
                _, victim = self._blocks.popitem(last=False)
                self._nbytes -= len(victim)
                self.evictions += 1

    def invalidate(self, tag: str) -> int:
        """Drop every block of one object version (the edge tier calls this
        when a path's origin ETag changes, DESIGN.md §14); returns blocks
        dropped."""
        with self._lock:
            keys = [k for k in self._blocks if k[0] == tag]
            for k in keys:
                self._nbytes -= len(self._blocks.pop(k))
            if keys:
                self.invalidations += 1
            return len(keys)

    def clear(self) -> None:
        with self._lock:
            self._blocks.clear()
            self._nbytes = 0

    def reset_stats(self) -> None:
        """Zero the counters without touching cached blocks (benchmarks:
        isolate one phase's traffic)."""
        with self._lock:
            self.hits = self.misses = self.evictions = self.invalidations = 0

    def stats(self) -> Dict[str, float]:
        """Consistent counter snapshot. ``hit_ratio`` is hits/(hits+misses)
        computed under the lock (0.0 before any traffic), so it can never
        mix a ``hits`` from one instant with a ``misses`` from another."""
        with self._lock:
            total = self.hits + self.misses
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "blocks": len(self._blocks),
                "nbytes": self._nbytes,
                "hit_ratio": (self.hits / total) if total else 0.0,
            }


_shared: Optional[BlockCache] = None  # guarded-by: _shared_lock
_shared_lock = threading.Lock()


def shared_cache() -> BlockCache:
    """Process-wide cache shared by every ``RemoteReader`` by default, so
    readers over many shard files pool one capacity budget."""
    global _shared
    with _shared_lock:
        if _shared is None:
            _shared = BlockCache()
        return _shared


def reset_shared_cache() -> None:
    """Drop the shared cache (tests/benchmarks: guarantee a cold start)."""
    global _shared
    with _shared_lock:
        _shared = None
