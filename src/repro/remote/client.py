"""Parallel byte-range HTTP client for remote RawArray files (DESIGN.md §9).

``RemoteReader`` implements the same positioned-read interface the parallel
I/O engine consumes for local file descriptors — ``pread_into(offset,
view)`` — so every engine-planned slab/gather wave (single files, sharded
stores, datasets, checkpoint restores) works unchanged over the network:
the engine fans slabs out over its thread pool and each slab becomes a
concurrent ranged ``GET`` on a pooled connection.

Between the reader and the sockets sits a block-aligned LRU cache
(``repro.remote.cache``): reads are decomposed into cache blocks, runs of
missing blocks are coalesced into one ranged request, and repeated epoch
traversals are served from RAM.

Module-level helpers mirror ``repro.core.io`` one-for-one: ``remote_read``
/ ``remote_read_into`` / ``remote_header_of`` / ``remote_read_metadata``.

The write direction (DESIGN.md §11) mirrors the local ingest plane:
``upload_bytes`` is one whole-object PUT with server-side atomic publish
(``core.io.write`` dispatches URL writes to it), and ``RemoteWriter`` is
the incremental ``RaWriter`` whose byte sink is the server's
append/patch/commit/abort upload session — identical interface, identical
bytes, streamed over authenticated PUTs (token knob ``RA_REMOTE_TOKEN``).

Failure semantics: a dead server, a mid-transfer disconnect, or a range the
server cannot satisfy raises ``RawArrayError`` after bounded retries on
fresh connections — never a hang (sockets carry a timeout, knob
``RA_REMOTE_TIMEOUT``). Upload *appends* are the exception: they are never
blind-retried (a half-applied append would desynchronize the session and
the server answers 409 with its actual part size instead). Consecutive
connection *refusals* trip a per-host :class:`CircuitBreaker` (DESIGN.md
§14): once open, every call to that host fails in microseconds instead of
re-burning its retry budget — what lets the fleet router fail over to the
next ring node as soon as a replica dies.
"""

from __future__ import annotations

import http.client
import json
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple, Union
from urllib.parse import urlsplit
import zlib

import numpy as np

from ..core import codec as chunked_codec
from ..core import engine
from ..core.header import Header, decode_header
from ..core.io import RaWriter as _io_RaWriter, _read_stats_src, is_url, read_chunked
from ..core.stats import split_stats as _split_stats
from ..core.spec import (
    FLAG_CHUNKED,
    FLAG_CRC32_TRAILER,
    FLAG_ZLIB,
    RawArrayError,
    env_float as _env_float,
    env_int as _env_int,
    env_str as _env_str,
)
from .cache import BlockCache, shared_cache


class RemoteAuthError(RawArrayError):
    """The server refused the request for AUTH reasons (HTTP 401/403,
    the PR token-auth plane of DESIGN.md §11). Deliberately distinct from
    transient transport failures: retrying a rejected credential can never
    succeed, so every retry loop in this module fails fast on it instead of
    burning its retry budget as if the error were transient."""


def _raise_for_auth(status: int, url: str, what: str) -> None:
    """Fail fast on 401/403 — wrong or missing bearer token is permanent."""
    if status in (401, 403):
        raise RemoteAuthError(
            f"{what} {url} refused by server auth: HTTP {status} "
            f"(check the bearer token — RA_REMOTE_TOKEN or token=; "
            f"not retried: credential errors are not transient)"
        )


class CircuitBreaker:
    """Per-host connection-refused circuit breaker (DESIGN.md §14).

    A dead host refuses connections instantly, but a bounded retry loop
    still burns its whole budget (fresh connection per attempt) before
    raising — and every *subsequent* call pays the same budget again. That
    is exactly wrong for fleet failover, where the router needs a dead
    replica to fail in microseconds so it can walk to the next ring node.

    State machine: ``RA_REMOTE_BREAKER_FAILS`` consecutive refusals, each
    within ``RA_REMOTE_BREAKER_WINDOW`` seconds of the previous one, OPEN
    the circuit — :meth:`check` then raises immediately, no socket touched —
    for ``RA_REMOTE_BREAKER_COOLDOWN`` seconds. After the cooldown the
    circuit is half-open: callers flow again, but one more refusal re-opens
    it instantly (the count stays primed), while one success fully closes
    it. Only ``ConnectionRefusedError`` trips it: refusal is the one failure
    mode that is both instant and overwhelmingly likely to persist; slow
    faults (timeouts, resets mid-entity) keep their normal retry budget."""

    def __init__(self, fails: Optional[int] = None, window: Optional[float] = None,
                 cooldown: Optional[float] = None):
        self.fails = max(1, _env_int("RA_REMOTE_BREAKER_FAILS", 3)) if fails is None else int(fails)
        self.window = _env_float("RA_REMOTE_BREAKER_WINDOW", 10.0) if window is None else float(window)
        self.cooldown = _env_float("RA_REMOTE_BREAKER_COOLDOWN", 1.0) if cooldown is None else float(cooldown)
        self._lock = threading.Lock()
        self._count = 0       # guarded-by: _lock
        self._last = 0.0      # guarded-by: _lock
        self._open_until = 0.0  # guarded-by: _lock

    def check(self, what: str = "") -> None:
        """Raise ``RawArrayError`` at once if the circuit is open; a no-op
        (closed or half-open) otherwise. Call before touching a socket."""
        with self._lock:
            if time.monotonic() < self._open_until:
                raise RawArrayError(
                    f"circuit open{f' for {what}' if what else ''}: host refused "
                    f"{self._count} consecutive connections; failing fast for "
                    f"{self.cooldown:g}s (knobs RA_REMOTE_BREAKER_FAILS/"
                    f"WINDOW/COOLDOWN)"
                )

    def record_refusal(self) -> bool:
        """Count one connection refusal; returns True when the circuit is
        (now) open, so retry loops can stop burning their budget."""
        now = time.monotonic()
        with self._lock:
            if now - self._last > self.window:
                self._count = 0  # stale streak: refusals must cluster
            self._last = now
            self._count += 1
            if self._count >= self.fails:
                self._count = self.fails  # stay primed while half-open
                self._open_until = now + self.cooldown
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._count = 0
            self._open_until = 0.0

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {
                "consecutive_refusals": self._count,
                "open": float(time.monotonic() < self._open_until),
            }


_breakers: Dict[Tuple[str, Optional[int]], CircuitBreaker] = {}
_breakers_lock = threading.Lock()


def breaker_for(host: str, port: Optional[int]) -> CircuitBreaker:
    """Process-wide breaker shared by every client of one ``host:port`` —
    a reader pool, ``fetch_bytes``, the upload plane, and the fleet router
    all see (and contribute to) the same host health."""
    key = (host or "", port)
    with _breakers_lock:
        brk = _breakers.get(key)
        if brk is None:
            brk = _breakers[key] = CircuitBreaker()
        return brk


def reset_breakers() -> None:
    """Forget every per-host breaker (tests/benchmarks: cold start)."""
    with _breakers_lock:
        _breakers.clear()


def default_conns() -> int:
    """Connection-pool width per reader (knob ``RA_REMOTE_CONNS``)."""
    return max(1, _env_int("RA_REMOTE_CONNS", 8))


def default_timeout() -> float:
    """Per-socket-operation timeout in seconds (knob ``RA_REMOTE_TIMEOUT``)."""
    return _env_float("RA_REMOTE_TIMEOUT", 30.0)


class _ConnPool:
    """Bounded pool of keep-alive HTTP connections. ``acquire`` blocks when
    ``limit`` connections are in flight, so an arbitrarily wide engine wave
    degrades to queueing, not to unbounded sockets."""

    def __init__(self, scheme: str, host: str, port: Optional[int], limit: int, timeout: float):
        self.scheme = scheme
        self.host = host
        self.port = port
        self.timeout = timeout
        self.limit = limit
        self._sem = threading.BoundedSemaphore(limit)
        self._lock = threading.Lock()
        self._free: List[http.client.HTTPConnection] = []  # guarded-by: _lock
        self._closed = False  # guarded-by: _lock

    def _new_conn(self) -> http.client.HTTPConnection:
        cls = (
            http.client.HTTPSConnection
            if self.scheme == "https"
            else http.client.HTTPConnection
        )
        return cls(self.host, self.port, timeout=self.timeout)

    def acquire(self) -> http.client.HTTPConnection:
        self._sem.acquire()
        with self._lock:
            if self._free:
                return self._free.pop()
        return self._new_conn()

    def release(self, conn: http.client.HTTPConnection) -> None:
        with self._lock:
            if self._closed:
                conn.close()
            else:
                self._free.append(conn)
        self._sem.release()

    def prewarm(self, n: int) -> int:
        """Eagerly open up to ``n`` keep-alive connections (capped at the
        pool limit) and park them in the free list, so the first parallel
        read wave starts with established sockets instead of serializing
        TCP/TLS handshakes inside it (DESIGN.md §13). Connect errors are
        swallowed — the regular acquire path reports them with its usual
        retry/raise contract. Returns the number of sockets opened."""
        with self._lock:
            if self._closed:
                return 0
            want = max(0, min(n, self.limit) - len(self._free))
        made: List[http.client.HTTPConnection] = []
        for _ in range(want):
            c = self._new_conn()
            try:
                c.connect()
            except OSError:
                break
            made.append(c)
        with self._lock:
            if self._closed:
                pass  # close below, outside the lock
            else:
                self._free.extend(made)
                return len(made)
        for c in made:
            try:
                c.close()
            except Exception:
                pass
        return 0

    def discard(self, conn: http.client.HTTPConnection) -> None:
        try:
            conn.close()
        except Exception:
            pass
        self._sem.release()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            free, self._free = self._free, []
        for c in free:
            try:
                c.close()
            except Exception:
                pass


class RemoteReader:
    """Positioned-read view of one remote object.

    Engine-compatible: ``engine.pread_into(reader, offset, view)`` and every
    plan built on it treat a reader exactly like a file descriptor. The
    object's size and ETag are pinned by one ``HEAD`` at construction; a
    response whose ETag no longer matches raises (the file changed under a
    running traversal) rather than silently mixing versions.
    """

    def __init__(
        self,
        url: str,
        *,
        conns: Optional[int] = None,
        timeout: Optional[float] = None,
        retries: int = 2,
        cache: Optional[BlockCache] = None,
        use_cache: bool = True,
        pinned: Optional[Tuple[int, Optional[str]]] = None,
    ):
        if not is_url(url):
            raise RawArrayError(f"not an http(s) URL: {url!r}")
        self.url = url
        parts = urlsplit(url)
        self._path = parts.path or "/"
        if parts.query:
            self._path += "?" + parts.query
        self.retries = max(0, retries)
        self._pool = _ConnPool(
            parts.scheme, parts.hostname or "", parts.port,
            conns or default_conns(), default_timeout() if timeout is None else timeout,
        )
        self._breaker = breaker_for(parts.hostname or "", parts.port)
        self.cache = (cache if cache is not None else shared_cache()) if use_cache else None
        # a caller that already holds the object's (size, etag) — e.g. from
        # one stat_dir() listing covering a whole checkpoint — skips the
        # per-object HEAD; the first ranged response still verifies its
        # ETag against the pin, so a stale listing fails loudly, not late
        self.size, self.etag = self._stat() if pinned is None else (int(pinned[0]), pinned[1])
        # cache tag pins URL + version: a changed ETag can never hit stale blocks
        self._tag = f"{url}@{self.etag or ''}"
        self._closed = False

    # ---- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        self._closed = True
        self._pool.close()

    def prewarm(self, n: Optional[int] = None) -> int:
        """Pre-open up to ``n`` pooled sockets (default: the full pool width,
        knob ``RA_REMOTE_CONNS``) so a following engine wave pays zero
        handshakes. Returns sockets actually opened (0 when already warm)."""
        return self._pool.prewarm(self._pool.limit if n is None else n)

    def __enter__(self) -> "RemoteReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort socket cleanup
        try:
            self.close()
        except Exception:
            pass

    # ---- raw HTTP ----------------------------------------------------------
    def _stat(self) -> Tuple[int, Optional[str]]:
        err: Optional[BaseException] = None
        for _ in range(self.retries + 1):
            self._breaker.check(self.url)
            conn = self._pool.acquire()
            try:
                conn.request("HEAD", self._path)
                resp = conn.getresponse()
                resp.read()  # HEAD has no body; settle the connection state
                self._breaker.record_success()
                if resp.status != 200:
                    self._pool.release(conn)
                    _raise_for_auth(resp.status, self.url, "stat of")
                    raise RawArrayError(
                        f"remote stat failed: HTTP {resp.status} for {self.url}"
                    )
                length = resp.getheader("Content-Length")
                if length is None:
                    self._pool.release(conn)
                    raise RawArrayError(f"no Content-Length from server for {self.url}")
                etag = resp.getheader("ETag")
                self._pool.release(conn)
                return int(length), etag
            except ConnectionRefusedError as e:
                self._pool.discard(conn)
                err = e
                if self._breaker.record_refusal():
                    break  # circuit open: stop burning the retry budget
            except (OSError, http.client.HTTPException) as e:
                self._pool.discard(conn)
                err = e
        raise RawArrayError(f"cannot reach remote server for {self.url}: {err!r}")

    def _ranged_into(self, offset: int, view: memoryview) -> None:
        """One ranged GET filling ``view`` exactly; retries on transport
        errors with a fresh connection, raises ``RawArrayError`` on protocol
        problems (bad status, short entity, version change)."""
        length = view.nbytes
        if length == 0:
            return
        last = offset + length - 1
        err: Optional[BaseException] = None
        for _ in range(self.retries + 1):
            self._breaker.check(self.url)
            conn = self._pool.acquire()
            try:
                conn.request("GET", self._path, headers={"Range": f"bytes={offset}-{last}"})
                resp = conn.getresponse()
                self._breaker.record_success()
                try:
                    whole = resp.status == 200 and offset == 0 and length == self.size
                    if resp.status != 206 and not whole:
                        _raise_for_auth(resp.status, self.url, "ranged read of")
                        raise RawArrayError(
                            f"range [{offset}, {offset + length}) of {self.url} "
                            f"not satisfiable: HTTP {resp.status}"
                        )
                    etag = resp.getheader("ETag")
                    if self.etag and etag and etag != self.etag:
                        raise RawArrayError(
                            f"{self.url} changed on server during read "
                            f"(ETag {self.etag} -> {etag})"
                        )
                    clen = resp.getheader("Content-Length")
                    if clen is not None and int(clen) != length:
                        raise RawArrayError(
                            f"truncated range: wanted {length} bytes at {offset} "
                            f"of {self.url}, server offered {clen}"
                        )
                    got = 0
                    while got < length:
                        n = resp.readinto(view[got:])
                        if not n:
                            # server hung up mid-entity: transport-level, retry
                            raise ConnectionError(
                                f"connection closed after {got}/{length} bytes"
                            )
                        got += n
                except RawArrayError:
                    self._pool.discard(conn)
                    raise
                self._pool.release(conn)
                return
            except ConnectionRefusedError as e:
                self._pool.discard(conn)
                err = e
                if self._breaker.record_refusal():
                    break  # circuit open: stop burning the retry budget
            except (OSError, http.client.HTTPException) as e:
                self._pool.discard(conn)
                err = e
        raise RawArrayError(
            f"remote read of {self.url} [{offset}, {offset + length}) failed "
            f"after {self.retries + 1} attempts: {err!r}"
        )

    # ---- positioned reads (the engine-facing interface) --------------------
    def pread_into(self, offset: int, view) -> int:
        """Fill ``view`` from the remote object at ``offset`` (block-cached).

        This is the method ``engine.pread_into`` dispatches to for non-fd
        sources; thread-safe, so engine slab waves call it concurrently."""
        mv = view if isinstance(view, memoryview) else memoryview(view)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
        n = mv.nbytes
        if n == 0:
            return 0
        if offset < 0 or offset + n > self.size:
            raise RawArrayError(
                f"truncated read: wanted {n} bytes at offset {offset}, "
                f"remote object {self.url} has {self.size}"
            )
        if self.cache is None:
            self._ranged_into(offset, mv)
            return n
        block = self.cache.block_bytes
        b0, b1 = offset // block, (offset + n - 1) // block
        missing: List[int] = []
        for bi in range(b0, b1 + 1):
            data = self.cache.get(self._tag, bi)
            if data is None:
                missing.append(bi)
            else:
                self._copy_cached(bi, data, offset, mv)
        # coalesce consecutive missing blocks into single ranged requests
        i = 0
        while i < len(missing):
            j = i
            while j + 1 < len(missing) and missing[j + 1] == missing[j] + 1:
                j += 1
            self._fetch_blocks(missing[i], missing[j], offset, mv)
            i = j + 1
        return n

    def _copy_cached(self, bi: int, data: bytes, offset: int, mv: memoryview) -> None:
        """Copy the part of cached block ``bi`` that the request covers."""
        blk_off = bi * self.cache.block_bytes
        a = max(offset, blk_off)
        b = min(offset + mv.nbytes, blk_off + len(data))
        if b <= a:
            raise RawArrayError(f"short cache block {bi} of {self.url}: object shrank?")
        mv[a - offset : b - offset] = data[a - blk_off : b - blk_off]

    def _fetch_blocks(self, lo: int, hi: int, offset: int, mv: memoryview) -> None:
        """Fetch missing blocks [lo, hi] for a request at ``offset``.

        Blocks interior to the request stream in one ranged GET *directly
        into the destination* (zero scratch; the cache copy is materialized
        from the destination afterwards). The at-most-two edge blocks that
        stick out of the request are fetched whole through a one-block
        scratch so they are cacheable in full."""
        block = self.cache.block_bytes
        end = offset + mv.nbytes

        def _interior(bi: int) -> bool:
            return bi * block >= offset and min((bi + 1) * block, self.size) <= end

        bi = lo
        while bi <= hi:
            if _interior(bi):
                bj = bi
                while bj + 1 <= hi and _interior(bj + 1):
                    bj += 1
                fa = bi * block
                fb = min((bj + 1) * block, self.size)
                dst = mv[fa - offset : fb - offset]
                self._ranged_into(fa, dst)
                for k in range(bi, bj + 1):
                    ka = k * block - fa
                    kb = min((k + 1) * block, self.size) - fa
                    self.cache.put(self._tag, k, bytes(dst[ka:kb]))
                bi = bj + 1
            else:
                fa = bi * block
                fb = min(fa + block, self.size)
                buf = bytearray(fb - fa)
                self._ranged_into(fa, memoryview(buf))
                data = bytes(buf)
                self.cache.put(self._tag, bi, data)
                self._copy_cached(bi, data, offset, mv)
                bi += 1

    def pread_into_naive(self, offset: int, view) -> int:
        """Single-stream baseline: one block-granular ranged request at a
        time on one connection — no coalescing, no concurrency, no cache
        (the access pattern of a generic block-oriented remote reader).
        Kept (like ``read_slice_naive`` / ``gather_naive``) for equivalence
        tests and as the benchmark baseline the parallel plane is measured
        against."""
        from .cache import default_block_bytes

        mv = view if isinstance(view, memoryview) else memoryview(view)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
        n = mv.nbytes
        if offset < 0 or offset + n > self.size:
            raise RawArrayError(
                f"truncated read: wanted {n} bytes at offset {offset}, "
                f"remote object {self.url} has {self.size}"
            )
        block = self.cache.block_bytes if self.cache else default_block_bytes()
        pos = 0
        while pos < n:
            ln = min(block, n - pos)
            self._ranged_into(offset + pos, mv[pos : pos + ln])
            pos += ln
        return n

    def read_range(self, offset: int, length: int) -> bytes:
        buf = bytearray(length)
        self.pread_into(offset, memoryview(buf))
        return bytes(buf)

    def cache_stats(self) -> Dict[str, int]:
        return self.cache.stats() if self.cache is not None else {}


# ------------------------------------------------------------ reader registry
# One long-lived reader per URL so shard/dataset/checkpoint traversals reuse
# warm connections and one shared block cache across calls. LRU-capped
# (knob ``RA_REMOTE_READERS``) so a many-thousand-file remote tree cannot
# accumulate keep-alive sockets until the process hits EMFILE; an evicted
# reader keeps working, it just opens per-call connections instead of
# pooling them.
_readers: "OrderedDict[str, RemoteReader]" = OrderedDict()
_readers_lock = threading.Lock()


def max_readers() -> int:
    return max(1, _env_int("RA_REMOTE_READERS", 64))


def get_reader(
    url: str,
    *,
    revalidate: bool = False,
    pinned: Optional[Tuple[int, Optional[str]]] = None,
) -> RemoteReader:
    """Pooled reader for ``url``. With ``revalidate=True`` a cached reader is
    re-HEADed first and silently replaced if the object's (size, ETag) moved —
    callers that pin a version set at a point in time (cold-start restore)
    use this so the pin reflects the server's *current* object, not whatever
    generation an earlier traversal happened to cache. ``pinned=(size,
    etag)`` — e.g. one entry of a :func:`stat_dir` listing — plays the same
    role with zero extra round trips: a cached reader is reused only if it
    already matches, and a fresh reader adopts the pin instead of HEADing."""
    stale: Optional[RemoteReader] = None
    with _readers_lock:
        r = _readers.get(url)
        if r is not None and not r._closed:
            _readers.move_to_end(url)
            if pinned is not None:
                if (r.size, r.etag) == (int(pinned[0]), pinned[1]):
                    return r
            elif not revalidate:
                return r
    if r is not None and not r._closed:
        if pinned is None:
            try:
                if r._stat() == (r.size, r.etag):
                    return r
            except Exception:
                pass  # unreachable/changed -> rebuild below, surfacing real errors
        stale = r
        with _readers_lock:
            if _readers.get(url) is stale:
                del _readers[url]
        try:
            stale.close()
        except Exception:
            pass
    r = RemoteReader(url, pinned=pinned)
    evicted: List[RemoteReader] = []
    with _readers_lock:
        cur = _readers.get(url)
        if cur is not None and not cur._closed:
            evicted.append(r)
            r = cur
        else:
            _readers[url] = r
            _readers.move_to_end(url)
            while len(_readers) > max_readers():
                _, old = _readers.popitem(last=False)
                evicted.append(old)
    for old in evicted:
        try:
            old.close()
        except Exception:
            pass
    return r


def close_readers() -> None:
    """Close and forget every pooled reader (tests/benchmarks: cold start)."""
    with _readers_lock:
        readers = list(_readers.values())
        _readers.clear()
    for r in readers:
        try:
            r.close()
        except Exception:
            pass


def fetch_bytes(url: str, *, timeout: Optional[float] = None, retries: int = 2) -> bytes:
    """Full-object GET (manifests, index.json, /header JSON) on an ephemeral
    connection — never pollutes the reader registry or the cache. Same
    failure contract as the reader: bounded retries on a fresh connection
    for transport errors, then ``RawArrayError``."""
    parts = urlsplit(url)
    path = parts.path or "/"
    if parts.query:
        path += "?" + parts.query
    cls = http.client.HTTPSConnection if parts.scheme == "https" else http.client.HTTPConnection
    brk = breaker_for(parts.hostname or "", parts.port)
    err: Optional[BaseException] = None
    for _ in range(max(0, retries) + 1):
        brk.check(url)
        conn = cls(parts.hostname or "", parts.port,
                   timeout=default_timeout() if timeout is None else timeout)
        try:
            conn.request("GET", path)
            resp = conn.getresponse()
            body = resp.read()
            brk.record_success()
            if resp.status != 200:
                _raise_for_auth(resp.status, url, "GET of")
                raise RawArrayError(f"GET {url} failed: HTTP {resp.status}")
            return body
        except ConnectionRefusedError as e:
            err = e
            if brk.record_refusal():
                break  # circuit open: stop burning the retry budget
        except (OSError, http.client.HTTPException) as e:
            err = e
        finally:
            conn.close()
    raise RawArrayError(f"GET {url} failed after {max(0, retries) + 1} attempts: {err!r}")


def stat_dir(dir_url: str, *, timeout: Optional[float] = None) -> Dict[str, Tuple[int, Optional[str]]]:
    """One-round-trip version-set listing: GET ``/stat/<dir>`` and return
    ``{name: (size, etag)}`` for every regular file in the directory. A
    cold-start restore feeds each entry to :func:`get_reader` as ``pinned``,
    replacing one HEAD per leaf with a single listing (the HTTP analogue of
    S3 ListObjectsV2, which also returns ETags). Raises ``RawArrayError`` if
    the server has no ``/stat/`` route (older servers → caller falls back to
    per-leaf HEAD pinning) or the listing is malformed."""
    parts = urlsplit(dir_url)
    stat_url = f"{parts.scheme}://{parts.netloc}/stat{parts.path or '/'}"
    body = fetch_bytes(stat_url, timeout=timeout)
    try:
        files = json.loads(body)["files"]
        return {str(k): (int(v["size"]), v.get("etag")) for k, v in files.items()}
    except (ValueError, KeyError, TypeError) as e:
        raise RawArrayError(f"malformed /stat listing from {stat_url}: {e!r}") from e


# ------------------------------------------------------------- upload plane
def default_token() -> Optional[str]:
    """Upload bearer token (knob ``RA_REMOTE_TOKEN``; DESIGN.md §11)."""
    return _env_str("RA_REMOTE_TOKEN") or None


def _views_of(data) -> Tuple[List[memoryview], int]:
    views = []
    total = 0
    for v in data if isinstance(data, (list, tuple)) else [data]:
        mv = v if isinstance(v, memoryview) else memoryview(v)
        if mv.format != "B" or mv.ndim != 1:
            mv = mv.cast("B")
        if mv.nbytes:
            views.append(mv)
            total += mv.nbytes
    return views, total


def _put(
    url: str,
    data,
    headers: Dict[str, str],
    *,
    token: Optional[str],
    timeout: Optional[float],
    retries: int,
    conn: Optional[http.client.HTTPConnection] = None,
) -> Tuple[int, bytes, Optional[http.client.HTTPConnection]]:
    """One authenticated PUT. ``data`` is bytes / a view / a list of views;
    the body streams piecewise with an explicit Content-Length (the server
    does not decode chunked encoding). With ``conn`` the request reuses a
    keep-alive connection and returns it (or a fresh one) for the next call;
    transport errors retry ``retries`` times on fresh connections."""
    tok = default_token() if token is None else token
    if not tok:
        raise RawArrayError(
            f"upload to {url} needs a bearer token (RA_REMOTE_TOKEN or token=)"
        )
    parts = urlsplit(url)
    path = parts.path or "/"
    if parts.query:
        path += "?" + parts.query
    views, total = _views_of(data)
    hdrs = dict(headers)
    hdrs["Authorization"] = f"Bearer {tok}"
    hdrs["Content-Length"] = str(total)
    cls = http.client.HTTPSConnection if parts.scheme == "https" else http.client.HTTPConnection
    brk = breaker_for(parts.hostname or "", parts.port)
    err: Optional[BaseException] = None
    for attempt in range(max(0, retries) + 1):
        brk.check(url)
        c = conn
        conn = None
        if c is None:
            c = cls(parts.hostname or "", parts.port,
                    timeout=default_timeout() if timeout is None else timeout)
        try:
            c.request("PUT", path, body=iter(views), headers=hdrs)
            resp = c.getresponse()
            body = resp.read()
            brk.record_success()
            if resp.status in (401, 403):
                c.close()
                _raise_for_auth(resp.status, url, "upload to")
            return resp.status, body, c
        except ConnectionRefusedError as e:
            try:
                c.close()
            except Exception:
                pass
            err = e
            if brk.record_refusal() or retries == 0:
                break  # circuit open: stop burning the retry budget
        except (OSError, http.client.HTTPException) as e:
            try:
                c.close()
            except Exception:
                pass
            err = e
            if retries == 0:
                break
    raise RawArrayError(
        f"PUT {url} failed after {max(1, retries + 1)} attempts: {err!r}"
    )


def upload_bytes(
    url: str,
    data,
    *,
    token: Optional[str] = None,
    timeout: Optional[float] = None,
    retries: int = 2,
) -> int:
    """Whole-object authenticated upload with server-side ATOMIC publish
    (body → same-directory temp → fsync → rename; DESIGN.md §11). ``data``
    is bytes or a list of byte views (streamed without concatenation).
    Safe to retry: replaying the PUT just republishes the same bytes.
    Returns bytes uploaded. This is what ``core.io.write`` dispatches
    ``http(s)://`` destinations to."""
    views, total = _views_of(data)
    status, body, conn = _put(url, views, {}, token=token, timeout=timeout, retries=retries)
    if conn is not None:
        conn.close()
    if status not in (200, 201):
        raise RawArrayError(
            f"upload of {url} refused: HTTP {status} {body.decode(errors='replace').strip()}"
        )
    return total


class _UploadSink:
    """Remote byte sink for ``RaWriter`` (DESIGN.md §11): the same
    append/patch/commit/abort surface as the local temp-file sink, spoken
    as authenticated PUTs against the server's ``<path>.part`` upload
    session. Appends ride one keep-alive connection; commit renames the
    part into place server-side (the remote twin of fsync + rename)."""

    def __init__(self, url: str, *, token: Optional[str] = None, timeout: Optional[float] = None):
        if not is_url(url):
            raise RawArrayError(f"not an http(s) URL: {url!r}")
        self.url = url
        self._token = token
        self._timeout = timeout
        self._conn: Optional[http.client.HTTPConnection] = None
        self.size = 0
        # reset the session: a predecessor SIGKILLed mid-stream leaves a
        # stale <path>.part server-side, which would 409 our first append
        # forever (sessions are single-writer; concurrent writers to one
        # path are unsupported and now clobber instead of deadlock)
        self._session_put("abort", b"", retries=1)

    def _session_put(self, mode: str, data, *, offset: Optional[int] = None,
                     retries: int = 0) -> None:
        headers = {"X-RA-Upload": mode}
        if offset is not None:
            headers["X-RA-Offset"] = str(offset)
        status, body, self._conn = _put(
            self.url, data, headers,
            token=self._token, timeout=self._timeout, retries=retries,
            conn=self._conn,
        )
        if status not in (200, 201):
            raise RawArrayError(
                f"upload {mode} of {self.url} at {offset} refused: HTTP {status} "
                f"{body.decode(errors='replace').strip()}"
            )

    def append(self, views) -> int:
        _, total = _views_of(views)
        # appends are NOT blind-retried: a replay after a half-applied body
        # would double bytes; the server's 409 (offset != part size) catches
        # any desync loudly instead
        self._session_put("append", views, offset=self.size)
        self.size += total
        return total

    def patch(self, offset: int, data) -> None:
        self._session_put("patch", data, offset=offset)

    def commit(self) -> None:
        self._session_put("commit", b"")
        self.close()

    def abort(self) -> None:
        try:
            self._session_put("abort", b"", retries=1)
        finally:
            self.close()

    def close(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except Exception:
                pass
            self._conn = None


class RemoteWriter(_io_RaWriter):
    """Incremental RawArray writer streaming to a URL (DESIGN.md §11).

    Exactly ``core.io.RaWriter`` — same row-batch interface, same chunk-
    parallel compression, same finalize patch order, byte-identical output —
    with the byte sink swapped for the server's authenticated upload
    session: bytes accumulate in ``<path>.part`` server-side and the final
    commit atomically renames them into place, so a dropped client never
    publishes a partial object::

        with RemoteWriter(f"{server.url}/out.ra", np.float32, (256,),
                          token=TOKEN, chunked=True) as w:
            for batch in batches:
                w.write_rows(batch)
    """

    def __init__(
        self,
        url: str,
        dtype,
        row_shape: Tuple[int, ...] = (),
        *,
        token: Optional[str] = None,
        timeout: Optional[float] = None,
        crc32: bool = False,
        chunked: bool = False,
        codec: Optional[str] = None,
        chunk_bytes: Optional[int] = None,
        metadata: Optional[bytes] = None,
        stats: bool = False,
    ):
        super().__init__(
            url, dtype, row_shape,
            crc32=crc32, chunked=chunked, codec=codec, chunk_bytes=chunk_bytes,
            metadata=metadata, stats=stats,
            sink=_UploadSink(url, token=token, timeout=timeout),
        )


# ----------------------------------------------------- io.py mirror functions
def _header_url(url: str) -> str:
    parts = urlsplit(url)
    base = f"{parts.scheme}://{parts.netloc}"
    return base + "/header" + (parts.path or "/")


def remote_header_of(url: str, *, strict_flags: bool = True) -> Header:
    """Decode the header of a remote file.

    Fast path: the server's ``/header/<path>`` endpoint returns the decoded
    header as JSON — one small response, no range arithmetic. Foreign
    byte-range servers (no such endpoint) fall back to a ranged read of the
    header bytes."""
    try:
        body = fetch_bytes(_header_url(url))
    except RawArrayError:
        body = None  # foreign server: no /header endpoint; use a ranged read
    if body is not None:
        try:
            d = json.loads(body)
            hdr = Header(
                flags=int(d["flags"]),
                eltype=int(d["eltype"]),
                elbyte=int(d["elbyte"]),
                data_length=int(d["data_length"]),
                shape=tuple(int(x) for x in d["shape"]),
            )
        except (KeyError, TypeError, ValueError):
            hdr = None  # not our endpoint's JSON shape
        if hdr is not None:
            hdr.validate(strict_flags=strict_flags)
            return hdr
    reader = get_reader(url)
    head = reader.read_range(0, min(reader.size, 4096))
    return decode_header(head, strict_flags=strict_flags)


def remote_read(
    url: str,
    *,
    with_metadata: bool = False,
    strict_flags: bool = True,
) -> Union[np.ndarray, Tuple[np.ndarray, bytes]]:
    """``core.io.read`` over HTTP: plain little-endian payloads stream via
    engine-parallel ranged reads straight into the output array; flagged
    payloads (zlib / CRC / big-endian) fetch the remainder and reuse the
    local decode logic."""
    reader = get_reader(url)
    head = reader.read_range(0, min(reader.size, 4096))
    hdr = decode_header(head, strict_flags=strict_flags)
    if hdr.flags & FLAG_CHUNKED:
        # chunk-parallel decode: the table is two small ranged reads, then
        # every chunk fetch is a ranged GET through the block cache (keyed
        # on stored byte ranges) + decompress straight into the output
        return read_chunked(reader, hdr, size=reader.size, with_metadata=with_metadata)
    if hdr.plain and not with_metadata:
        out = np.empty(hdr.shape, dtype=hdr.dtype())
        if hdr.data_length == 0:
            return out
        mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
        engine.parallel_read_into(reader, hdr.nbytes, mv)
        return out
    rest_len = reader.size - hdr.nbytes
    if rest_len < hdr.data_length:
        raise RawArrayError(
            f"truncated data segment: wanted {hdr.data_length}, got {rest_len}"
        )
    blob = bytearray(rest_len)
    if rest_len:
        engine.parallel_read_into(reader, hdr.nbytes, memoryview(blob))
    payload = bytes(blob[: hdr.data_length])
    trailer = bytes(blob[hdr.data_length :])
    meta = trailer
    if hdr.flags & FLAG_CRC32_TRAILER:
        if len(trailer) < 4:
            raise RawArrayError("CRC flag set but trailer missing")
        meta, crc = trailer[:-4], int.from_bytes(trailer[-4:], "little")
        if zlib.crc32(payload) != crc:
            raise RawArrayError("CRC32 mismatch: data segment corrupted")
    if hdr.flags & FLAG_ZLIB:
        payload = zlib.decompress(payload)
        if len(payload) != hdr.logical_nbytes:
            raise RawArrayError(
                f"decompressed payload is {len(payload)} bytes, header shape "
                f"{hdr.shape} x elbyte={hdr.elbyte} wants {hdr.logical_nbytes}"
            )
    dtype = hdr.dtype()
    arr = np.frombuffer(payload, dtype=dtype)
    if hdr.big_endian:
        arr = arr.astype(dtype.newbyteorder("<"))
    arr = arr.reshape(hdr.shape)
    if with_metadata:
        # user metadata follows the rastats block, if any (DESIGN.md §16)
        return arr, _split_stats(meta)[1]
    return arr


def remote_read_into(url: str, out: np.ndarray) -> np.ndarray:
    """``core.io.read_into`` over HTTP: stream the payload straight into a
    caller-owned preallocated array (the warm-epoch fast path — an
    already-faulted destination plus a warm block cache is a pure memcpy)."""
    reader = get_reader(url)
    head = reader.read_range(0, min(reader.size, 4096))
    hdr = decode_header(head)
    if tuple(out.shape) != hdr.shape:
        raise RawArrayError(f"read_into: out.shape {out.shape} != file {hdr.shape}")
    if out.dtype != hdr.dtype().newbyteorder("="):
        raise RawArrayError(f"read_into: out.dtype {out.dtype} != file {hdr.dtype()}")
    if not out.flags.c_contiguous:
        raise RawArrayError("read_into: out must be C-contiguous")
    if hdr.flags & FLAG_CHUNKED and not hdr.big_endian:
        if hdr.logical_nbytes:
            mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
            table = chunked_codec.read_table(reader, hdr)
            chunked_codec.decompress_into(reader, hdr, table, mv)
        return out
    if hdr.plain:
        if hdr.data_length:
            mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
            engine.parallel_read_into(reader, hdr.nbytes, mv)
        return out
    out[...] = remote_read(url)
    return out


def remote_read_metadata(url: str) -> bytes:
    """Trailing user metadata of a remote file: header + one tail range
    (chunked files skip the trailer chunk table first — one more small
    ranged read of the table head)."""
    reader = get_reader(url)
    hdr = remote_header_of(url, strict_flags=False)
    start = hdr.nbytes + hdr.data_length
    if hdr.flags & FLAG_CHUNKED:
        start += chunked_codec.table_nbytes(reader, hdr)
    tail = reader.read_range(start, max(0, reader.size - start))
    if hdr.flags & FLAG_CRC32_TRAILER:
        tail = tail[:-4]
    return _split_stats(tail)[1]


def remote_read_stats(url: str):
    """Per-chunk ``rastats`` statistics of a remote file (DESIGN.md §16):
    header fast path + (for chunked files) the table-head range + two
    small tail ranges. The payload is never fetched, which is what makes
    predicate pushdown selectivity-proportional over HTTP — including
    through the fleet router, which proxies ranges unchanged."""
    reader = get_reader(url)
    hdr = remote_header_of(url, strict_flags=False)
    return _read_stats_src(reader, hdr, size=reader.size)
