"""Remote RawArray data plane (DESIGN.md §9; upload plane §11).

Three layers:

* ``server``  — stdlib threaded HTTP byte-range server (``os.sendfile``
  zero-copy, ETag/304, ``/header/<path>`` JSON fast path) plus the
  authenticated PUT upload plane (append/patch/commit/abort sessions with
  atomic publish, DESIGN.md §11);
* ``client``  — ``RemoteReader``: the engine's positioned-read interface
  over pooled HTTP connections, so slab/gather waves run unchanged over
  the network; ``remote_read`` / ``remote_read_into`` /
  ``remote_header_of`` mirroring ``core.io``; and the write direction —
  ``upload_bytes`` (one atomic PUT) and ``RemoteWriter`` (the incremental
  ``RaWriter`` streaming over the upload session);
* ``cache``   — block-aligned LRU byte cache between client and sockets.

``core.io`` dispatches ``http(s)://`` paths here, which makes the whole
data plane URL-aware: sharded stores, datasets, the loader, checkpoint
restore — and, on the write side, ``write`` / checkpoint save — all
accept URLs.
"""

from .cache import BlockCache, reset_shared_cache, shared_cache
from .client import (
    CircuitBreaker,
    RemoteAuthError,
    RemoteReader,
    RemoteWriter,
    breaker_for,
    close_readers,
    default_token,
    fetch_bytes,
    get_reader,
    is_url,
    remote_header_of,
    remote_read,
    remote_read_into,
    remote_read_metadata,
    remote_read_stats,
    reset_breakers,
    stat_dir,
    upload_bytes,
)
from .server import ArrayServer, ServerMetrics, serve

__all__ = [
    "ArrayServer",
    "BlockCache",
    "CircuitBreaker",
    "RemoteAuthError",
    "RemoteReader",
    "RemoteWriter",
    "ServerMetrics",
    "breaker_for",
    "close_readers",
    "default_token",
    "fetch_bytes",
    "get_reader",
    "is_url",
    "remote_header_of",
    "remote_read",
    "remote_read_into",
    "remote_read_metadata",
    "remote_read_stats",
    "reset_breakers",
    "reset_shared_cache",
    "serve",
    "shared_cache",
    "stat_dir",
    "upload_bytes",
]
