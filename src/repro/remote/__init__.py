"""Remote RawArray data plane (DESIGN.md §9).

Three layers:

* ``server``  — stdlib threaded HTTP byte-range server (``os.sendfile``
  zero-copy, ETag/304, ``/header/<path>`` JSON fast path);
* ``client``  — ``RemoteReader``: the engine's positioned-read interface
  over pooled HTTP connections, so slab/gather waves run unchanged over
  the network; plus ``remote_read`` / ``remote_read_into`` /
  ``remote_header_of`` mirroring ``core.io``;
* ``cache``   — block-aligned LRU byte cache between client and sockets.

``core.io`` dispatches ``http(s)://`` paths here, which makes the whole
data plane URL-aware: sharded stores, datasets, the loader, and checkpoint
restore all accept URLs.
"""

from .cache import BlockCache, reset_shared_cache, shared_cache
from .client import (
    RemoteReader,
    close_readers,
    fetch_bytes,
    get_reader,
    is_url,
    remote_header_of,
    remote_read,
    remote_read_into,
    remote_read_metadata,
)
from .server import ArrayServer, serve

__all__ = [
    "ArrayServer",
    "BlockCache",
    "RemoteReader",
    "close_readers",
    "fetch_bytes",
    "get_reader",
    "is_url",
    "remote_header_of",
    "remote_read",
    "remote_read_into",
    "remote_read_metadata",
    "reset_shared_cache",
    "serve",
    "shared_cache",
]
