"""Stdlib-only threaded HTTP byte-range server for RawArray trees
(DESIGN.md §9; upload plane §11).

Serves a directory of ``.ra`` files — including sharded stores, dataset
directories, and checkpoint directories (their ``index.json`` /
``manifest.json`` are plain files) — with exactly the parts of HTTP a
remote array plane needs:

* ``GET /<path>`` with single-range ``Range: bytes=a-b`` support (``206`` +
  ``Content-Range``); a row slab or engine slab is one request, because the
  RawArray layout makes every sub-range pure offset arithmetic;
* zero-copy responses: entity bytes go socket-ward through ``os.sendfile``
  (graceful buffered fallback where unavailable);
* ``ETag`` from ``(mtime_ns, size)`` and ``If-None-Match`` → ``304``, so
  clients can pin a version and revalidate for free;
* ``GET /header/<path>`` fast path: the decoded RawArray header as JSON —
  one round trip, no range arithmetic on the client;
* ``HEAD`` for size/ETag discovery;
* ``GET /healthz`` liveness probe and ``GET /metrics`` thread-safe counters
  (uptime, request/byte totals, per-path hit counts) — what the fleet
  router (DESIGN.md §14) health-checks and weights replicas with;
* authenticated ``PUT /<path>`` upload plane (DESIGN.md §11): whole-object
  upload with atomic publish (temp + rename), plus an append/patch/commit/
  abort session protocol driven by the ``X-RA-Upload`` header that mirrors
  the local writer's temp-file protocol — streamed bytes accumulate in
  ``<path>.part``, ``commit`` fsyncs and renames, so a dropped client never
  leaves a partial object visible. Uploads are OFF unless the server is
  started with an upload token (``--upload-token`` / ``RA_REMOTE_TOKEN``)
  and every PUT carries it as ``Authorization: Bearer <token>``.

Run standalone::

    PYTHONPATH=src python -m repro.remote.server <root> [--host H] [--port P]
        [--upload-token TOKEN]

or in-process (tests, benchmarks)::

    server = serve(root, port=0)      # ephemeral port, daemon thread
    ...
    server.shutdown()
"""

from __future__ import annotations

import argparse
import http.server
import json
import os
import threading
import time
from typing import Dict, Optional, Tuple
from urllib.parse import unquote, urlsplit

from ..core import io as raio
from ..core.spec import RawArrayError, env_str as _env_str

_COPY_CHUNK = 1 << 20


class ServerMetrics:
    """Thread-safe request/byte counters behind ``GET /metrics`` (DESIGN.md
    §14). Every handler of the threading server runs on its own thread, so
    all mutation happens under one lock — increments can never be lost to a
    read-modify-write race. Per-path hit counts are capped at ``max_paths``
    distinct paths (new paths beyond the cap are counted in the totals but
    not per-path) so a crawler cannot balloon server memory."""

    def __init__(self, max_paths: int = 1024):
        self._lock = threading.Lock()
        self._t0 = time.monotonic()
        self.requests = 0   # guarded-by: _lock
        self.bytes_out = 0  # guarded-by: _lock
        self.bytes_in = 0   # guarded-by: _lock
        self.errors = 0     # guarded-by: _lock
        self._max_paths = max_paths
        self._path_hits: Dict[str, int] = {}  # guarded-by: _lock

    def record(self, path: str, status: int) -> None:
        with self._lock:
            self.requests += 1
            if status >= 400:
                self.errors += 1
            if path in self._path_hits or len(self._path_hits) < self._max_paths:
                self._path_hits[path] = self._path_hits.get(path, 0) + 1

    def add_bytes(self, out: int = 0, in_: int = 0) -> None:
        with self._lock:
            self.bytes_out += out
            self.bytes_in += in_

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "uptime_s": round(time.monotonic() - self._t0, 3),
                "requests": self.requests,
                "bytes_out": self.bytes_out,
                "bytes_in": self.bytes_in,
                "errors": self.errors,
                "paths": dict(self._path_hits),
            }


def file_etag(st: os.stat_result) -> str:
    """Strong-enough validator from (mtime, size) — cheap, no content hash."""
    return f'"{st.st_mtime_ns:x}-{st.st_size:x}"'


class RangeRequestHandler(http.server.BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"  # keep-alive: one conn serves many ranges
    server_version = "RawArrayHTTP/1.0"
    # TCP_NODELAY: responses are written headers-then-body (two sends), and
    # with Nagle on, the body of a mid-size ranged GET sits behind the
    # client's delayed ACK — a flat ~40ms per request that dwarfs the
    # transfer itself on fast links
    disable_nagle_algorithm = True

    def log_message(self, fmt, *args):  # quiet by default; --verbose re-enables
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def log_request(self, code="-", size="-"):
        # every send_response lands here exactly once — the one choke point
        # where request count and status can be recorded consistently
        m = getattr(self.server, "metrics", None)
        if m is not None:
            try:
                status = int(code)
            except (TypeError, ValueError):
                status = 0
            m.record(unquote(urlsplit(self.path).path), status)
        super().log_request(code, size)

    def _send_json(self, obj, status: int = 200, etag: Optional[str] = None) -> None:
        body = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        if etag is not None:
            self.send_header("ETag", etag)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        try:
            self.wfile.write(body)
        except OSError:
            return
        m = getattr(self.server, "metrics", None)
        if m is not None:
            m.add_bytes(out=len(body))

    # ---- helpers -----------------------------------------------------------
    def _resolve(self, relpath: str) -> Optional[str]:
        """Map a URL path onto the served root; ``None`` if it escapes or is
        not a regular file."""
        root = self.server.root  # type: ignore[attr-defined]
        full = os.path.realpath(os.path.join(root, relpath.lstrip("/")))
        if full != root and not full.startswith(root + os.sep):
            return None
        if not os.path.isfile(full):
            return None
        return full

    def _fail(self, status: int, msg: str) -> None:
        # a PUT rejected before its body was consumed would leave the body
        # bytes on the keep-alive socket, where they'd be parsed as the next
        # request line — drain them (bounded) or give up on the connection
        if self.command == "PUT":
            self._drain_body()
        body = (msg + "\n").encode()
        self.send_response(status)
        self.send_header("Content-Type", "text/plain")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        try:
            self.wfile.write(body)
        except OSError:
            pass

    def _drain_body(self) -> None:
        """Read and discard any unread request body so the keep-alive
        connection stays usable; close the connection instead when the
        length is unknown/garbage."""
        try:
            left = int(self.headers.get("Content-Length", "0"))
        except ValueError:
            left = -1
        if left < 0:
            self.close_connection = True
            return
        try:
            while left > 0:
                piece = self.rfile.read(min(_COPY_CHUNK, left))
                if not piece:
                    self.close_connection = True
                    return
                left -= len(piece)
        except OSError:
            self.close_connection = True

    def _parse_range(self, size: int) -> Optional[Tuple[int, int]]:
        """Parse a single-range ``Range`` header into ``(start, stop)``.

        Returns ``None`` for "serve the whole entity"; raises ``ValueError``
        for a syntactically valid but unsatisfiable range (→ 416)."""
        spec = self.headers.get("Range")
        if not spec or not spec.startswith("bytes="):
            return None
        spec = spec[len("bytes="):]
        if "," in spec:  # multipart ranges are overkill for slab reads
            return None
        a, _, b = spec.partition("-")
        if a == "":  # suffix range: last N bytes
            n = int(b)
            if n <= 0:
                raise ValueError("empty suffix range")
            return max(0, size - n), size
        start = int(a)
        stop = int(b) + 1 if b else size
        if start >= size or stop <= start:
            raise ValueError(f"range [{start}, {stop}) outside entity of {size}")
        return start, min(stop, size)

    def _send_entity(self, path: str, head_only: bool) -> None:
        # origin-distance simulation (benchmarks/tests only, DESIGN.md §14):
        # a per-entity-request sleep held under ONE server-wide lock models a
        # far-away origin with a constrained uplink — concurrent misses at an
        # edge replica serialize here exactly like they would on a thin WAN
        # link, which is what makes fleet cache-capacity scaling measurable
        # on a single box
        delay = getattr(self.server, "delay_s", 0.0)
        if delay:
            with self.server._delay_lock:  # type: ignore[attr-defined]
                time.sleep(delay)
        # latency_s models per-request NETWORK latency: concurrent requests
        # overlap their sleeps (no lock), so N parallel clients see ~one RTT
        # per wave — the signal the mesh's host-count scaling benchmark
        # measures (DESIGN.md §15), vs delay_s's serialized-uplink model
        latency = getattr(self.server, "latency_s", 0.0)
        if latency:
            time.sleep(latency)
        try:
            st = os.stat(path)
        except OSError:
            self._fail(404, "not found")
            return
        etag = file_etag(st)
        inm = self.headers.get("If-None-Match")
        if inm and (inm.strip() == "*" or etag in [t.strip() for t in inm.split(",")]):
            self.send_response(304)
            self.send_header("ETag", etag)
            self.send_header("Content-Length", "0")
            self.end_headers()
            return
        size = st.st_size
        try:
            rng = self._parse_range(size)
        except ValueError:
            self.send_response(416)
            self.send_header("Content-Range", f"bytes */{size}")
            self.send_header("Content-Length", "0")
            self.end_headers()
            return
        if rng is None:
            start, stop = 0, size
            self.send_response(200)
        else:
            start, stop = rng
            self.send_response(206)
            self.send_header("Content-Range", f"bytes {start}-{stop - 1}/{size}")
        count = stop - start
        self.send_header("Accept-Ranges", "bytes")
        self.send_header("ETag", etag)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(count))
        self.end_headers()
        if head_only or count == 0:
            return
        with open(path, "rb") as f:
            self.wfile.flush()  # drain buffered headers before raw socket I/O
            sent = self._copy_range(f, start, count)
        m = getattr(self.server, "metrics", None)
        if m is not None:
            m.add_bytes(out=sent)

    def _copy_range(self, f, offset: int, count: int) -> int:
        """Entity bytes to the socket — ``os.sendfile`` zero-copy when the
        platform allows, buffered pread/write otherwise. The fallback resumes
        AFTER whatever sendfile already sent: re-sending from the range start
        would silently corrupt the fixed-Content-Length entity. Returns bytes
        actually put on the wire (for the ``/metrics`` counters)."""
        sock_fd = self.connection.fileno()
        sent_total = 0
        try:
            while sent_total < count:
                sent = os.sendfile(sock_fd, f.fileno(), offset + sent_total,
                                   count - sent_total)
                if sent == 0:
                    return sent_total  # peer went away; nothing more to do
                sent_total += sent
            return sent_total
        except (AttributeError, OSError):
            pass  # not a disk file / platform without sendfile: fall back
        f.seek(offset + sent_total)
        left = count - sent_total
        while left:
            chunk = f.read(min(_COPY_CHUNK, left))
            if not chunk:
                break
            try:
                self.wfile.write(chunk)
            except OSError:
                return sent_total
            left -= len(chunk)
            sent_total += len(chunk)
        return sent_total

    def _send_stat_json(self, relpath: str) -> None:
        """``GET /stat/<dir>``: one-round-trip version pin for every regular
        file directly under a served directory — ``{"files": {name: {size,
        etag}}}`` with the SAME etag values the entity responses carry, so
        a client can pin a whole checkpoint's version set with one request
        instead of a HEAD per leaf (the ranged reads that follow still
        verify each response's ETag against the pin)."""
        root = self.server.root  # type: ignore[attr-defined]
        full = os.path.realpath(os.path.join(root, relpath.lstrip("/")))
        if (full != root and not full.startswith(root + os.sep)) or not os.path.isdir(full):
            self._fail(404, "not found")
            return
        files = {}
        try:
            with os.scandir(full) as it:
                for de in it:
                    if de.is_file(follow_symlinks=True):
                        st = de.stat(follow_symlinks=True)
                        files[de.name] = {"size": st.st_size, "etag": file_etag(st)}
        except OSError as e:
            self._fail(500, f"stat failed: {e}")
            return
        self._send_json({"files": files})

    def _send_header_json(self, relpath: str) -> None:
        path = self._resolve(relpath)
        if path is None:
            self._fail(404, "not found")
            return
        try:
            hdr = raio.header_of(path)
        except RawArrayError as e:
            self._fail(422, f"not a RawArray file: {e}")
            return
        st = os.stat(path)
        self._send_json(
            {
                "flags": hdr.flags,
                "eltype": hdr.eltype,
                "elbyte": hdr.elbyte,
                "data_length": hdr.data_length,
                "ndims": hdr.ndims,
                "shape": list(hdr.shape),
                "header_bytes": hdr.nbytes,
                "dtype": str(hdr.dtype()),
                "file_size": st.st_size,
            },
            etag=file_etag(st),
        )

    # ---- upload plane (DESIGN.md §11) --------------------------------------
    def _resolve_write(self, relpath: str) -> Optional[str]:
        """Map a URL path onto a WRITABLE location under the root; ``None``
        if it escapes the root or names a directory. The file need not
        exist; missing parent directories are created."""
        root = self.server.root  # type: ignore[attr-defined]
        full = os.path.realpath(os.path.join(root, relpath.lstrip("/")))
        if full != root and not full.startswith(root + os.sep):
            return None
        if full == root or os.path.isdir(full):
            return None
        os.makedirs(os.path.dirname(full), exist_ok=True)
        return full

    def _authorized(self) -> bool:
        token = getattr(self.server, "upload_token", None)
        if not token:
            self._fail(403, "server is read-only (start with --upload-token)")
            return False
        got = self.headers.get("Authorization", "")
        if got != f"Bearer {token}":
            self._fail(401, "missing or wrong upload token")
            return False
        return True

    def _read_body_to(self, f, offset: int) -> int:
        """Stream the request body into ``f`` at ``offset``; returns bytes
        written. Requires ``Content-Length`` (chunked encoding is not
        decoded by this server)."""
        length = self.headers.get("Content-Length")
        if length is None:
            self._fail(411, "Content-Length required")
            self.close_connection = True  # body length unknown: can't drain
            return -1
        try:
            left = int(length)
        except ValueError:
            left = -1
        if left < 0:
            self._fail(400, f"bad Content-Length: {length!r}")
            return -1
        f.seek(offset)
        while left:
            piece = self.rfile.read(min(_COPY_CHUNK, left))
            if not piece:
                break
            f.write(piece)
            left -= len(piece)
        if left:
            self._fail(400, "request body shorter than Content-Length")
            return -1
        m = getattr(self.server, "metrics", None)
        if m is not None:
            m.add_bytes(in_=int(length))
        return int(length)

    def _ok(self, status: int, path: Optional[str] = None, **extra) -> None:
        body_d = dict(extra)
        if path is not None and os.path.exists(path):
            st = os.stat(path)
            body_d["etag"] = file_etag(st)
            body_d["size"] = st.st_size
        body = (json.dumps(body_d) + "\n").encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        try:
            self.wfile.write(body)
        except OSError:
            pass

    def do_PUT(self) -> None:
        """Upload plane (DESIGN.md §11). Dispatch on ``X-RA-Upload``:

        =========  ==========================================================
        (absent)   whole-object upload: body → same-dir temp, fsync, rename
        append     body → ``<path>.part`` at ``X-RA-Offset`` (must equal the
                   part's current size; 409 + current size otherwise)
        patch      body overwrites ``[offset, offset+len)`` INSIDE the part
                   (the finalize header patch; 416 if it sticks out)
        commit     fsync ``<path>.part``, atomically rename to ``<path>``
        abort      delete ``<path>.part``
        =========  ==========================================================
        """
        if not self._authorized():
            return
        relpath = unquote(urlsplit(self.path).path)
        full = self._resolve_write(relpath)
        if full is None:
            self._fail(404, "path escapes the served root or is a directory")
            return
        mode = (self.headers.get("X-RA-Upload") or "").strip().lower()
        try:
            if mode == "":
                tmp = f"{full}.upload-{threading.get_ident():x}"
                try:
                    with open(tmp, "wb") as f:
                        if self._read_body_to(f, 0) < 0:
                            return
                        f.flush()
                        os.fsync(f.fileno())
                    os.replace(tmp, full)
                finally:
                    if os.path.exists(tmp):
                        os.unlink(tmp)
                self._ok(201, full)
            elif mode in ("append", "patch"):
                part = full + ".part"
                try:
                    offset = int(self.headers.get("X-RA-Offset", ""))
                except ValueError:
                    self._fail(400, "append/patch need an integer X-RA-Offset")
                    return
                size = os.path.getsize(part) if os.path.exists(part) else 0
                try:
                    length = int(self.headers.get("Content-Length", 0))
                except ValueError:
                    length = -1
                if length < 0:
                    self._fail(400, "bad Content-Length")
                    return
                if mode == "append" and offset != size:
                    self._fail(409, f"append offset {offset} != part size {size}")
                    return
                if mode == "patch" and offset + length > size:
                    self._fail(416, f"patch [{offset}, {offset + length}) outside part of {size}")
                    return
                with open(part, "r+b" if os.path.exists(part) else "w+b") as f:
                    if self._read_body_to(f, offset) < 0:
                        return
                self._ok(200, part)
            elif mode == "commit":
                part = full + ".part"
                if not os.path.exists(part):
                    self._fail(404, "no upload session to commit (missing .part)")
                    return
                with open(part, "rb") as f:
                    os.fsync(f.fileno())
                os.replace(part, full)
                self._ok(201, full)
            elif mode == "abort":
                try:
                    os.unlink(full + ".part")
                except FileNotFoundError:
                    pass
                self._ok(200)
            else:
                self._fail(400, f"unknown X-RA-Upload mode {mode!r}")
        except OSError as e:
            self._fail(500, f"upload failed: {e}")

    # ---- verbs -------------------------------------------------------------
    def _route(self, head_only: bool) -> None:
        path = unquote(urlsplit(self.path).path)
        # a real file under a literal header/ directory wins over the JSON
        # endpoint, so the fast path can never shadow served bytes (the
        # client falls back to a ranged header read when JSON parsing fails)
        full = self._resolve(path)
        if full is None and not head_only:
            if path.startswith("/header/"):
                self._send_header_json(path[len("/header"):])
                return
            if path.startswith("/stat/"):
                self._send_stat_json(path[len("/stat"):])
                return
            if path == "/healthz":
                # liveness probe for the fleet router (DESIGN.md §14): tiny,
                # allocation-free, never touches the disk
                self._send_json({"ok": True, "role": "origin",
                                 "uptime_s": self.server.metrics.snapshot()["uptime_s"]})
                return
            if path == "/metrics":
                snap = self.server.metrics.snapshot()
                snap["role"] = "origin"
                self._send_json(snap)
                return
        if full is None:
            self._fail(404, "not found")
            return
        self._send_entity(full, head_only)

    def do_GET(self) -> None:
        self._route(head_only=False)

    def do_HEAD(self) -> None:
        self._route(head_only=True)


class ArrayServer(http.server.ThreadingHTTPServer):
    """Threaded byte-range server rooted at one directory.

    ``upload_token=None`` (default) keeps the server strictly read-only;
    passing a token enables the PUT upload plane (DESIGN.md §11) for
    requests carrying ``Authorization: Bearer <token>``."""

    daemon_threads = True
    # socketserver's default listen backlog (5) makes connection bursts —
    # a pool prewarm, a parallel read wave from a many-leaf checkpoint —
    # hit kernel SYN drops and 1s retransmit stalls; size it like a real
    # file server instead
    request_queue_size = 128

    def __init__(
        self,
        root: str,
        address=("127.0.0.1", 0),
        *,
        verbose: bool = False,
        upload_token: Optional[str] = None,
        delay_s: float = 0.0,
        latency_s: float = 0.0,
    ):
        self.root = os.path.realpath(root)
        if not os.path.isdir(self.root):
            raise RawArrayError(f"server root is not a directory: {root}")
        self.verbose = verbose
        self.upload_token = upload_token
        self.metrics = ServerMetrics()
        # delay_s > 0 simulates a far origin for fleet benchmarks/tests
        # (DESIGN.md §14): each entity request sleeps this long while holding
        # one server-wide lock, modelling a constrained origin uplink
        self.delay_s = float(delay_s)
        self._delay_lock = threading.Lock()
        # latency_s > 0 sleeps per request WITHOUT the lock — concurrent
        # network latency (requests in flight overlap), for mesh scaling
        self.latency_s = float(latency_s)
        super().__init__(address, RangeRequestHandler)

    @property
    def port(self) -> int:
        return self.server_address[1]

    @property
    def url(self) -> str:
        host = self.server_address[0]
        return f"http://{host}:{self.port}"


def serve(
    root: str,
    host: str = "127.0.0.1",
    port: int = 0,
    *,
    verbose: bool = False,
    upload_token: Optional[str] = None,
    delay_s: float = 0.0,
    latency_s: float = 0.0,
) -> ArrayServer:
    """Start an ``ArrayServer`` on a daemon thread; returns the (already
    listening) server — ``server.url`` is ready immediately, ``port=0``
    picks an ephemeral port. Stop with ``server.shutdown()``. Pass
    ``upload_token`` to enable authenticated uploads (DESIGN.md §11);
    ``delay_s`` simulates origin distance for fleet benchmarks (§14, one
    serialized uplink), ``latency_s`` per-request network latency that
    concurrent requests overlap (mesh scaling, §15)."""
    server = ArrayServer(root, (host, port), verbose=verbose,
                         upload_token=upload_token, delay_s=delay_s,
                         latency_s=latency_s)
    # ralint: allow=thread-lifecycle -- lifetime owned by the returned server;
    # server.shutdown() stops serve_forever and the daemon thread exits with it
    t = threading.Thread(target=server.serve_forever, daemon=True, name="ra-remote-srv")
    t.start()
    return server


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="ra-serve", description=__doc__)
    p.add_argument("root", help="directory of .ra files / shard dirs / checkpoints")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8742)
    p.add_argument("--verbose", action="store_true", help="log each request")
    p.add_argument(
        "--upload-token",
        default=_env_str("RA_REMOTE_TOKEN") or None,
        help="enable authenticated PUT uploads with this bearer token "
        "(default: RA_REMOTE_TOKEN env var; omit for a read-only server)",
    )
    args = p.parse_args(argv)
    server = ArrayServer(
        args.root, (args.host, args.port),
        verbose=args.verbose, upload_token=args.upload_token,
    )
    mode = "read-write" if args.upload_token else "read-only"
    print(f"serving {server.root} at {server.url} [{mode}] (Ctrl-C to stop)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
