"""``racat doctor`` — layout-geometry checks against ``core/layouts.py``
(DESIGN.md §17).

Where ``racat verify`` recomputes *content* integrity (CRCs, rastats
bounds vs the decoded payload), ``doctor`` checks that a file's framing
agrees with the declared layout registry — byte for byte, without ever
decoding the payload:

* fixed header geometry matches ``layouts.HEADER`` (magic, 48-byte head,
  8-byte dims, ``ndims`` within the sanity bound), and the declaring
  module's ``header_nbytes`` agrees with ``layouts.HEADER.nbytes``;
* the on-disk segments tile the file exactly: ``header + data + [chunk
  table] + [rastats] + metadata + [crc trailer] == file size``, using
  the registry's sizes for every block;
* chunk-table framing matches ``layouts.CHUNK_TABLE`` (magic, 32/32
  head/entry bytes, strictly-increasing raw offsets, stored extent ==
  ``data_length``);
* ``rastats`` framing matches ``layouts.RASTATS`` (magic, 40-byte head,
  ``block_bytes == 40 + 32*n``) and the window count is not stale
  relative to the file's geometry (``ceil(logical / chunk_bytes)``).

URLs get the subset of checks the ranged readers support (header,
chunk-table, rastats); local files and directories get everything.
Exit is nonzero on any drift — CI runs it over the test corpus.
"""

from __future__ import annotations

import os
import sys
from typing import Dict, List

from ..core import layouts
from ..core.spec import FLAG_CHUNKED, FLAG_CRC32_TRAILER, MAX_NDIMS, RawArrayError


def _expected_windows(logical_nbytes: int, chunk_bytes: int) -> int:
    if logical_nbytes <= 0 or chunk_bytes <= 0:
        return 0
    return (logical_nbytes + chunk_bytes - 1) // chunk_bytes


def doctor_file(path) -> List[str]:
    """Return a list of geometry problems (empty == healthy)."""
    from ..core import codec as chunked_codec
    from ..core import header as header_mod
    from ..core import io as ra_io
    from ..core import stats as stats_mod

    problems: List[str] = []
    H = layouts.HEADER

    # --- registry vs declaring modules (catches drift in either place)
    if header_mod.header_nbytes(0) != H.head_bytes:
        problems.append(
            f"core.header.header_nbytes(0)={header_mod.header_nbytes(0)} "
            f"disagrees with layouts.HEADER.head_bytes={H.head_bytes}"
        )
    if stats_mod.HEAD_BYTES != layouts.RASTATS.head_bytes:
        problems.append("core.stats head size disagrees with layouts.RASTATS")

    # --- header
    try:
        hdr = ra_io.header_of(path)
    except (RawArrayError, OSError) as e:
        return problems + [f"header: {e}"]
    if hdr.ndims > MAX_NDIMS:
        problems.append(f"header: ndims={hdr.ndims} exceeds bound {MAX_NDIMS}")
    hdr_nbytes = H.nbytes(hdr.ndims)
    if hdr.nbytes != hdr_nbytes:
        problems.append(
            f"header: declared size {hdr.nbytes} != layouts geometry {hdr_nbytes}"
        )

    is_remote = ra_io.is_url(path)

    # --- chunk table (decode validates monotonic offsets + stored extent
    # against data_length; re-framed here through the registry sizes)
    table = None
    table_nbytes = 0
    if hdr.flags & FLAG_CHUNKED:
        try:
            if is_remote:
                rdr = ra_io._remote().RemoteReader(path)
                try:
                    table = chunked_codec.read_table(rdr, hdr)
                finally:
                    rdr.close()
            else:
                with open(path, "rb") as f:
                    table = chunked_codec.read_table(f.fileno(), hdr)
        except (RawArrayError, OSError) as e:
            problems.append(f"chunk table: {e}")
        if table is not None:
            table_nbytes = (
                layouts.CHUNK_TABLE.nbytes(table.nchunks)
            )
            if table.nbytes != table_nbytes:
                problems.append(
                    f"chunk table: declared size {table.nbytes} != "
                    f"layouts geometry {table_nbytes}"
                )

    # --- rastats framing + staleness.  read_stats is deliberately lenient
    # (damaged block -> warn + full scan); doctor decodes strictly so a
    # truncated or misframed block is drift, not a shrug.
    st = None
    if is_remote:
        import warnings

        try:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                st = ra_io.read_stats(path)
            for w in caught:
                problems.append(f"rastats: {w.message}")
        except (RawArrayError, OSError) as e:
            problems.append(f"rastats: {e}")
    else:
        try:
            with open(path, "rb") as f:
                f.seek(hdr_nbytes + hdr.data_length + table_nbytes)
                tail = f.read()
            if hdr.flags & FLAG_CRC32_TRAILER:
                tail = tail[: -layouts.CRC32.head_bytes] or b""
            st = stats_mod.split_stats(tail, strict=True)[0]
        except (RawArrayError, OSError) as e:
            msg = str(e)
            problems.append(msg if msg.startswith("rastats") else f"rastats: {msg}")
    if st is not None:
        if st.nbytes != layouts.RASTATS.nbytes(st.nchunks):
            problems.append(
                f"rastats: block size {st.nbytes} != layouts geometry "
                f"{layouts.RASTATS.nbytes(st.nchunks)}"
            )
        want = _expected_windows(hdr.logical_nbytes, st.chunk_bytes)
        if st.nchunks != want:
            problems.append(
                f"rastats: {st.nchunks} windows but geometry implies {want} "
                f"({hdr.logical_nbytes} bytes / {st.chunk_bytes}-byte windows) "
                "— stale statistics block?"
            )

    # --- whole-file tiling (local only: needs the true size)
    if not is_remote:
        try:
            size = os.stat(path).st_size
            with open(path, "rb") as f:
                f.seek(hdr_nbytes + hdr.data_length + table_nbytes)
                tail = f.read()
        except OSError as e:
            return problems + [f"tail: {e}"]
        crc_bytes = layouts.CRC32.head_bytes if hdr.flags & FLAG_CRC32_TRAILER else 0
        if len(tail) < crc_bytes:
            problems.append(
                "crc trailer: flag set but file too short for the "
                f"{layouts.CRC32.head_bytes}-byte trailer"
            )
        stats_bytes = st.nbytes if st is not None else 0
        meta_start = hdr_nbytes + hdr.data_length + table_nbytes + stats_bytes
        if meta_start + crc_bytes > size:
            problems.append(
                f"tiling: header({hdr_nbytes}) + data({hdr.data_length}) + "
                f"table({table_nbytes}) + rastats({stats_bytes}) + "
                f"crc({crc_bytes}) = {meta_start + crc_bytes} "
                f"overruns file size {size}"
            )
    return problems


def doctor_paths(paths) -> Dict[str, List[str]]:
    """Expand directories to ``*.ra`` files and doctor each target."""
    from ..core.io import is_url

    out: Dict[str, List[str]] = {}
    for p in paths:
        if not is_url(p) and os.path.isdir(p):
            hit = False
            for dirpath, _dirs, files in sorted(os.walk(p)):
                for name in sorted(files):
                    if name.endswith(".ra"):
                        full = os.path.join(dirpath, name)
                        out[full] = doctor_file(full)
                        hit = True
            if not hit:
                out[str(p)] = [f"no .ra files under directory {p}"]
        else:
            out[str(p)] = doctor_file(p)
    return out


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: racat doctor FILE|DIR [...]", file=sys.stderr)
        return 2
    results = doctor_paths(argv)
    bad = 0
    for path, problems in results.items():
        if problems:
            bad += 1
            for msg in problems:
                print(f"DRIFT {path}: {msg}", file=sys.stderr)
        else:
            print(f"OK {path}")
    return 1 if bad else 0
