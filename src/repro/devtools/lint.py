"""ralint — AST linter for this repo's threading + format invariants (DESIGN.md §17).

Five rules, each born from a bug class PRs 5–9 fixed by hand:

``guarded-by``
    A field whose initializing assignment carries a ``# guarded-by: <lock>``
    comment may only be mutated inside a ``with <...>.<lock>:`` block (matched
    by the *terminal* name, so ``with self._lock:``, ``with st.lock:`` and
    ``with _stats_lock:`` all work).  ``__init__`` is exempt (single-threaded
    construction), as are methods whose name ends in ``_locked`` (the caller
    holds the lock — the suffix is the contract).  Works for instance
    attributes and module-level globals.

``thread-lifecycle``
    Every ``threading.Thread(...)`` must belong to a class that can actually
    retire it: some ``stop``/``shutdown``/``close``/``wait`` method joins a
    thread, and the class either owns a stop ``threading.Event``, passes
    ``daemon=False``, or delegates to a ``.shutdown()``.  PR 5's zombie
    prefetch ring is the canonical violation.

``sleep-loop``
    No ``time.sleep`` inside a loop in ``src/`` — condition variables and
    ``Event.wait(timeout)`` exist; polling loops burn latency budget.

``struct-layout``
    Any literal ``struct`` format string in the data plane must be one of the
    formats registered in ``core/layouts.py`` — the single source of truth
    for on-disk geometry.  ``formats/`` (foreign-format adapters) is exempt.

``env-knob`` / ``env-doc``
    ``RA_*`` environment variables are read only through ``spec.env_*`` (so
    every knob has one parse + fallback path), and every knob read in the
    scanned tree must appear in the README's knob table.

Waivers: a ``# ralint: allow=<rule> -- <reason>`` comment on the flagged
line or the line above suppresses that rule there; the reason is mandatory
culture, not syntax.  Fixture-friendly: ``lint_source`` lints a string.
"""

from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

from ..core import layouts

RULES = (
    "guarded-by",
    "thread-lifecycle",
    "sleep-loop",
    "struct-layout",
    "env-knob",
    "env-doc",
)

GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
ALLOW_RE = re.compile(r"#\s*ralint:\s*allow=([a-z-]+)")
KNOB_RE = re.compile(r"\bRA_[A-Z][A-Z0-9_]*\b")
TABLE_ROW_RE = re.compile(r"^\|\s*`(RA_[A-Z0-9_]+)`", re.M)

#: method names that mutate their receiver in place
MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "update", "setdefault", "move_to_end", "add", "discard",
    "appendleft", "popleft", "sort", "reverse",
})

#: method names that count as a "retire the thread" entry point
STOPISH = frozenset({"stop", "shutdown", "close", "wait", "join", "stop_all"})

#: struct.* entry points whose first argument is a format string
STRUCT_FNS = frozenset({
    "Struct", "pack", "unpack", "pack_into", "unpack_from", "calcsize",
    "iter_unpack",
})

#: spec helpers that are the sanctioned way to read RA_* knobs
ENV_HELPERS = frozenset({"env_int", "env_float", "env_str"})


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


# ---------------------------------------------------------------- helpers
def _terminal_name(expr: ast.expr) -> Optional[str]:
    """The last dotted component of an expression (``a.b._lock`` -> ``_lock``)."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _base_name(expr: ast.expr) -> Optional[str]:
    """The first dotted component (``self._blocks`` -> ``self``)."""
    while isinstance(expr, ast.Attribute):
        expr = expr.value
    return expr.id if isinstance(expr, ast.Name) else None


def _is_call_to(node: ast.expr, modname: str, attr: str) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr == attr
        and isinstance(node.value, ast.Name)
        and node.value.id == modname
    )


class FileInfo:
    """Parsed source + the comment-carried metadata the AST can't see."""

    def __init__(self, path: str, src: str):
        self.path = path
        self.src = src
        self.lines = src.splitlines()
        self.tree = ast.parse(src, filename=path)
        # line -> lock name from "# guarded-by: <lock>" comments
        self.guard_lines: Dict[int, str] = {}
        # line -> set of rules waived by "# ralint: allow=<rule>" comments
        self.allow_lines: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = GUARDED_RE.search(text)
            if m:
                self.guard_lines[i] = m.group(1)
            for rule in ALLOW_RE.findall(text):
                self.allow_lines.setdefault(i, set()).add(rule)

    def allowed(self, rule: str, line: int) -> bool:
        """Waived when the flagged line, or the contiguous comment block
        immediately above it, carries ``# ralint: allow=<rule>``."""
        if rule in self.allow_lines.get(line, set()):
            return True
        ln = line - 1
        while 1 <= ln <= len(self.lines) and self.lines[ln - 1].lstrip().startswith("#"):
            if rule in self.allow_lines.get(ln, set()):
                return True
            ln -= 1
        return False


def _collect_guards(info: FileInfo) -> Tuple[
    Dict[str, Dict[str, str]],  # class name -> {attr: lock}
    Dict[str, str],             # module-level global -> lock
]:
    """Attach ``# guarded-by`` comments to the assignments on their lines."""
    class_guards: Dict[str, Dict[str, str]] = {}
    module_guards: Dict[str, str] = {}

    for node in info.tree.body:
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            lock = info.guard_lines.get(node.lineno)
            if lock:
                targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                for t in targets:
                    if isinstance(t, ast.Name):
                        module_guards[t.id] = lock
        elif isinstance(node, ast.ClassDef):
            guards = class_guards.setdefault(node.name, {})
            for sub in ast.walk(node):
                if not isinstance(sub, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                    continue
                lock = info.guard_lines.get(sub.lineno)
                if not lock:
                    continue
                targets = (
                    sub.targets if isinstance(sub, ast.Assign) else [sub.target]
                )
                for t in targets:
                    if (
                        isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                    ):
                        guards[t.attr] = lock
                    elif isinstance(t, ast.Name):
                        # annotated slot-style class body assignment
                        guards[t.id] = lock
    class_guards = {k: v for k, v in class_guards.items() if v}
    return class_guards, module_guards


def collect_guards(path: str) -> Dict[str, Dict[str, str]]:
    """Public: ``# guarded-by`` map of one file (used by the tsan tracer)."""
    with open(path, "r", encoding="utf-8") as f:
        info = FileInfo(path, f.read())
    return _collect_guards(info)[0]


# ---------------------------------------------------------------- the linter
class _Linter:
    def __init__(self, info: FileInfo, readme_knobs: Optional[Set[str]]):
        self.info = info
        self.readme_knobs = readme_knobs
        self.out: List[Violation] = []
        self.class_guards, self.module_guards = _collect_guards(info)
        # attr name -> every lock any class in this module guards it with
        self.attr_guards: Dict[str, Set[str]] = {}
        for guards in self.class_guards.values():
            for attr, lock in guards.items():
                self.attr_guards.setdefault(attr, set()).add(lock)
        self.knobs_read: Set[str] = set()
        self.struct_exempt = (
            os.sep + "formats" + os.sep in info.path or "/formats/" in info.path
        )

    def report(self, rule: str, line: int, msg: str) -> None:
        if not self.info.allowed(rule, line):
            self.out.append(Violation(rule, self.info.path, line, msg))

    def run(self) -> List[Violation]:
        for node in self.info.tree.body:
            self._toplevel(node)
        self._whole_file_rules()
        return self.out

    # ------------------------------------------------------------ dispatch
    def _toplevel(self, node: ast.stmt) -> None:
        if isinstance(node, ast.ClassDef):
            self._check_class(node)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._check_function(node, cls=None)

    def _check_class(self, cls: ast.ClassDef) -> None:
        self._thread_rule_class(cls)
        for node in cls.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_function(node, cls=cls.name)
            elif isinstance(node, ast.ClassDef):
                self._check_class(node)

    def _check_function(self, fn, cls: Optional[str]) -> None:
        exempt = (cls is not None and fn.name in ("__init__", "__new__")) or (
            fn.name.endswith("_locked")
        )
        self._stmts(fn.body, frozenset(), cls, exempt)

    def _stmts(
        self,
        stmts: Sequence[ast.stmt],
        held: FrozenSet[str],
        cls: Optional[str],
        exempt: bool,
    ) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested function: may run on another thread, lock context
                # does not transfer, and the __init__ exemption ends here
                self._check_function(st, cls)
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                names = {
                    n
                    for item in st.items
                    if (n := _terminal_name(item.context_expr)) is not None
                }
                self._stmts(st.body, held | names, cls, exempt)
            elif isinstance(st, (ast.If, ast.For, ast.AsyncFor, ast.While)):
                self._simple(st, held, cls, exempt, header_only=True)
                self._stmts(st.body, held, cls, exempt)
                self._stmts(st.orelse, held, cls, exempt)
            elif isinstance(st, ast.Try):
                self._stmts(st.body, held, cls, exempt)
                for h in st.handlers:
                    self._stmts(h.body, held, cls, exempt)
                self._stmts(st.orelse, held, cls, exempt)
                self._stmts(st.finalbody, held, cls, exempt)
            elif isinstance(st, ast.Match):
                for case in st.cases:
                    self._stmts(case.body, held, cls, exempt)
            elif isinstance(st, ast.ClassDef):
                self._check_class(st)
            else:
                self._simple(st, held, cls, exempt)

    # ------------------------------------------------- guarded-by mechanics
    def _simple(self, st, held, cls, exempt, header_only: bool = False) -> None:
        """Check one simple statement (or a compound statement's header)."""
        if not header_only:
            if isinstance(st, ast.Assign):
                for t in st.targets:
                    self._target(t, held, cls, exempt)
            elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
                if not (isinstance(st, ast.AnnAssign) and st.value is None):
                    self._target(st.target, held, cls, exempt)
            elif isinstance(st, ast.Delete):
                for t in st.targets:
                    self._target(t, held, cls, exempt)
        # in-place mutator calls anywhere in the statement (incl. headers,
        # returns, and right-hand sides): self._blocks.pop(k), _free.append(x)
        scan = [st.test] if header_only and hasattr(st, "test") else (
            [st.iter] if header_only and hasattr(st, "iter") else ([] if header_only else [st])
        )
        for root in scan:
            for node in ast.walk(root):
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in MUTATORS
                ):
                    recv = node.func.value
                    if isinstance(recv, ast.Attribute):
                        self._attr_mutation(
                            recv.value, recv.attr, node.lineno, held, cls, exempt
                        )
                    elif isinstance(recv, ast.Name):
                        self._global_mutation(recv.id, node.lineno, held, exempt)

    def _target(self, t: ast.expr, held, cls, exempt) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                self._target(el, held, cls, exempt)
        elif isinstance(t, ast.Starred):
            self._target(t.value, held, cls, exempt)
        elif isinstance(t, ast.Attribute):
            self._attr_mutation(t.value, t.attr, t.lineno, held, cls, exempt)
        elif isinstance(t, ast.Name):
            self._global_mutation(t.id, t.lineno, held, exempt)
        elif isinstance(t, ast.Subscript):
            base = t.value
            if isinstance(base, ast.Attribute):
                self._attr_mutation(base.value, base.attr, t.lineno, held, cls, exempt)
            elif isinstance(base, ast.Name):
                self._global_mutation(base.id, t.lineno, held, exempt)

    def _attr_mutation(self, base, attr, line, held, cls, exempt) -> None:
        if exempt:
            return
        base_name = _base_name(base)
        if base_name in ("self", "cls") and cls is not None:
            locks = (
                {self.class_guards.get(cls, {}).get(attr)}
                if attr in self.class_guards.get(cls, {})
                else set()
            )
        else:
            # foreign object: enforceable only when the attr name is
            # annotated somewhere in this module (e.g. rep.down inside
            # Router, st.size inside EdgeServer)
            locks = self.attr_guards.get(attr, set())
        locks.discard(None)
        if not locks or locks & held:
            return
        lock_desc = " or ".join(sorted(locks))
        self.report(
            "guarded-by",
            line,
            f"write to guarded field {attr!r} outside `with ...{lock_desc}:` "
            f"(held here: {sorted(held) or 'none'})",
        )

    def _global_mutation(self, name, line, held, exempt) -> None:
        lock = self.module_guards.get(name)
        if lock is None or lock in held or exempt:
            return
        self.report(
            "guarded-by",
            line,
            f"write to guarded global {name!r} outside `with {lock}:` "
            f"(held here: {sorted(held) or 'none'})",
        )

    # --------------------------------------------------- class thread rule
    def _thread_rule_class(self, cls: ast.ClassDef) -> None:
        sites = [
            node
            for node in ast.walk(cls)
            if isinstance(node, ast.Call) and _is_call_to(node.func, "threading", "Thread")
        ]
        if not sites:
            return
        has_event = any(
            isinstance(n, ast.Call) and _is_call_to(n.func, "threading", "Event")
            for n in ast.walk(cls)
        )
        stop_joins = stop_shutdowns = False
        for node in cls.body:
            if (
                isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name in STOPISH
            ):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute):
                        if sub.func.attr == "join":
                            stop_joins = True
                        if sub.func.attr == "shutdown":
                            stop_shutdowns = True
        for site in sites:
            nondaemon = any(
                kw.arg == "daemon"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is False
                for kw in site.keywords
            )
            ok = stop_joins and (has_event or nondaemon or stop_shutdowns)
            if not ok:
                self.report(
                    "thread-lifecycle",
                    site.lineno,
                    f"threading.Thread in class {cls.name!r} without a "
                    "stop-Event + joining stop()/shutdown() "
                    "(PR 5's zombie-ring lesson; waive with "
                    "`# ralint: allow=thread-lifecycle -- <why>` if the "
                    "lifetime is externally managed)",
                )

    # ------------------------------------------------- whole-file sweeps
    def _whole_file_rules(self) -> None:
        self._sleep_rule(self.info.tree, in_loop=False)
        is_spec = self.info.path.endswith("spec.py")
        for node in ast.walk(self.info.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            # ---- module-level bare Thread (classes handled above)
            if _is_call_to(fn, "threading", "Thread"):
                if not self._enclosing_class_has(node):
                    self.report(
                        "thread-lifecycle",
                        node.lineno,
                        "bare threading.Thread outside any class that joins it",
                    )
            # ---- struct format literals
            if (
                not self.struct_exempt
                and isinstance(fn, ast.Attribute)
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "struct"
                and fn.attr in STRUCT_FNS
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)
            ):
                fmt = node.args[0].value
                if fmt not in layouts.REGISTERED_FORMATS:
                    self.report(
                        "struct-layout",
                        node.lineno,
                        f"struct format {fmt!r} is not registered in "
                        "core/layouts.py — declare the layout there (or waive "
                        "for genuinely local scratch formats)",
                    )
            # ---- env reads
            knob = self._env_read_knob(node)
            if knob:
                self.knobs_read.add(knob)
                if not is_spec and self._raw_environ(node):
                    self.report(
                        "env-knob",
                        node.lineno,
                        f"raw os.environ read of {knob!r} — route through "
                        "spec.env_int/env_float/env_str",
                    )
        # subscript reads: os.environ[<knob literal>]
        for node in ast.walk(self.info.tree):
            if (
                isinstance(node, ast.Subscript)
                and _is_call_to(node.value, "os", "environ")
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, str)
                and node.slice.value.startswith("RA_")
            ):
                self.knobs_read.add(node.slice.value)
                if not is_spec:
                    self.report(
                        "env-knob",
                        node.lineno,
                        f"raw os.environ[{node.slice.value!r}] — route through "
                        "spec.env_int/env_float/env_str",
                    )

    def _enclosing_class_has(self, call: ast.Call) -> bool:
        for node in ast.walk(self.info.tree):
            if isinstance(node, ast.ClassDef):
                if (
                    node.lineno <= call.lineno
                    and call.lineno <= max(
                        getattr(node, "end_lineno", node.lineno), node.lineno
                    )
                ):
                    return True
        return False

    def _sleep_rule(self, node: ast.AST, in_loop: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                self._sleep_rule(child, in_loop=False)
            elif isinstance(child, (ast.While, ast.For, ast.AsyncFor)):
                self._sleep_rule(child, in_loop=True)
            else:
                if (
                    in_loop
                    and isinstance(child, ast.Call)
                    and _is_call_to(child.func, "time", "sleep")
                ):
                    self.report(
                        "sleep-loop",
                        child.lineno,
                        "time.sleep inside a loop — use Event.wait(timeout) / "
                        "a Condition, or waive with a reason for paced "
                        "simulation or bounded backoff",
                    )
                self._sleep_rule(child, in_loop)

    @staticmethod
    def _raw_environ(node: ast.Call) -> bool:
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in ("get", "__getitem__"):
            return _is_call_to(fn.value, "os", "environ")
        if _is_call_to(fn, "os", "getenv"):
            return True
        return False

    def _env_read_knob(self, node: ast.Call) -> Optional[str]:
        """RA_* knob name when ``node`` reads an env var (any mechanism)."""
        fn = node.func
        is_helper = (
            isinstance(fn, ast.Name) and fn.id in ENV_HELPERS
        ) or (
            isinstance(fn, ast.Attribute) and fn.attr in ENV_HELPERS
        ) or (
            isinstance(fn, ast.Name) and fn.id.lstrip("_") in ENV_HELPERS
        ) or (
            isinstance(fn, ast.Attribute) and fn.attr.lstrip("_") in ENV_HELPERS
        )
        if is_helper or self._raw_environ(node):
            if node.args and isinstance(node.args[0], ast.Constant):
                v = node.args[0].value
                if isinstance(v, str) and v.startswith("RA_"):
                    return v
        return None


# ---------------------------------------------------------------- public API
def lint_source(
    src: str,
    path: str = "<fixture>",
    readme_knobs: Optional[Set[str]] = None,
) -> List[Violation]:
    """Lint one source string (unit-test / fixture entry point)."""
    info = FileInfo(path, src)
    linter = _Linter(info, readme_knobs)
    violations = linter.run()
    if readme_knobs is not None:
        for knob in sorted(linter.knobs_read - readme_knobs):
            violations.append(
                Violation(
                    "env-doc",
                    path,
                    1,
                    f"env knob {knob!r} is read here but missing from the "
                    "README knob table",
                )
            )
    return violations


def readme_knob_table(readme_path: str) -> Set[str]:
    """RA_* names documented in the README's knob table."""
    with open(readme_path, "r", encoding="utf-8") as f:
        return set(TABLE_ROW_RE.findall(f.read()))


def iter_py(root: str) -> Iterable[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in ("__pycache__", ".git")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def lint_paths(
    paths: Sequence[str], readme: Optional[str] = None
) -> List[Violation]:
    """Lint every .py under ``paths``; knob-table check when ``readme`` given."""
    readme_knobs = readme_knob_table(readme) if readme else None
    violations: List[Violation] = []
    all_knobs: Dict[str, Tuple[str, int]] = {}
    for root in paths:
        for path in iter_py(root):
            with open(path, "r", encoding="utf-8") as f:
                src = f.read()
            try:
                info = FileInfo(path, src)
            except SyntaxError as e:
                violations.append(
                    Violation("syntax", path, e.lineno or 1, f"does not parse: {e.msg}")
                )
                continue
            linter = _Linter(info, readme_knobs)
            violations.extend(linter.run())
            for knob in linter.knobs_read:
                all_knobs.setdefault(knob, (path, 1))
    if readme_knobs is not None:
        for knob, (path, line) in sorted(all_knobs.items()):
            if knob not in readme_knobs:
                violations.append(
                    Violation(
                        "env-doc",
                        path,
                        line,
                        f"env knob {knob!r} is read in the tree but missing "
                        "from the README knob table",
                    )
                )
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return violations


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="ralint",
        description="codebase-invariant linter (lock discipline, thread "
        "lifecycle, struct layouts, env knobs) — DESIGN.md §17",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument(
        "--readme",
        default=None,
        help="README.md whose knob table documents every RA_* knob "
        "(default: auto-discover next to the first path; --no-readme skips)",
    )
    ap.add_argument(
        "--no-readme", action="store_true", help="skip the env-doc knob-table rule"
    )
    ap.add_argument("-q", "--quiet", action="store_true", help="exit code only")
    ns = ap.parse_args(argv)

    readme = None
    if not ns.no_readme:
        if ns.readme:
            readme = ns.readme
        else:
            probe = os.path.abspath(ns.paths[0])
            for _ in range(6):
                cand = os.path.join(probe, "README.md")
                if os.path.isfile(cand):
                    readme = cand
                    break
                parent = os.path.dirname(probe)
                if parent == probe:
                    break
                probe = parent
    violations = lint_paths(ns.paths, readme=readme)
    if not ns.quiet:
        for v in violations:
            print(v)
        n = len(violations)
        print(f"ralint: {n} violation{'s' if n != 1 else ''}"
              + (f" in {len({v.path for v in violations})} file(s)" if n else ""))
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
