"""Runtime concurrency sanitizer for the threaded data plane (DESIGN.md §17).

Two instruments, both activated under pytest with ``--ra-sanitize``:

1. **Instrumented locks.**  :func:`install` replaces ``threading.Lock`` /
   ``RLock`` / ``Condition`` with drop-in wrappers — but only for locks
   *created from this repo's source files* (the creating frame's filename
   is checked), so stdlib machinery (queues, socketserver, executors) stays
   raw and the overhead stays bounded.  Every wrapper records its creation
   site (``file:line``); acquisitions feed a process-global acquisition
   graph keyed by site.  Detected:

   * **lock-order inversion** — acquiring B while holding A after the
     graph already established B →* A (a potential deadlock even if this
     run never interleaved badly);
   * **long hold** — a lock held longer than ``RA_TSAN_HOLD_MS``
     (warning, not error: the edge tier deliberately holds a path lock
     across an origin revalidation);
   * **acquire-after-finalize** — taking a lock whose owner declared the
     protected object dead (:meth:`finalize`); PR 5's zombie ring writer
     is exactly a finalized-lock acquirer.

   Same-site edges are ignored: two instances of one class (e.g. two
   ``BlockCache``\\ s) share a site, and ordering within a site class is
   the owning module's business.

2. **Guarded-field write tracer.**  :func:`watch_class` patches a class's
   ``__setattr__`` to check every write of a ``# guarded-by:`` annotated
   field (maps come from ``repro.devtools.lint``'s comment scanner via
   :func:`watch_module`): if the field was already initialized, the named
   lock exists and is *not held by the writing thread*, and the writer is
   not the thread that constructed the object, an **unguarded-write**
   error is recorded.  PR 7's cache-counter race (``cache.hits += 1``
   outside ``_lock``) is the canonical catch.

Reports accumulate in a global list; :func:`drain` empties it (the pytest
plugin fails any test that leaves error-severity reports behind).
"""

from __future__ import annotations

import os
import sys
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from ..core.spec import env_float as _env_float

# Real primitives, captured before any patching can occur.
_real_lock = threading.Lock
_real_rlock = threading.RLock
_real_condition = threading.Condition

# ------------------------------------------------------------------ reports
@dataclass(frozen=True)
class Report:
    kind: str       # lock-order-inversion | long-hold | acquire-after-finalize | unguarded-write
    severity: str   # "error" | "warn"
    message: str
    where: str      # site or object description
    thread: str

    def __str__(self) -> str:
        return f"[{self.kind}/{self.severity}] {self.where}: {self.message} (thread {self.thread})"


_reports: List[Report] = []
_reports_lock = _real_lock()


def record(kind: str, severity: str, message: str, where: str) -> None:
    rep = Report(kind, severity, message, where, threading.current_thread().name)
    with _reports_lock:
        _reports.append(rep)


def reports(errors_only: bool = False) -> List[Report]:
    with _reports_lock:
        out = list(_reports)
    return [r for r in out if r.severity == "error"] if errors_only else out


def drain() -> List[Report]:
    """Return all accumulated reports and clear the buffer."""
    with _reports_lock:
        out = list(_reports)
        _reports.clear()
    return out


# ------------------------------------------------- per-thread held-lock stack
_tls = threading.local()


def _held() -> list:
    h = getattr(_tls, "held", None)
    if h is None:
        h = _tls.held = []
    return h


# ------------------------------------------------------- acquisition graph
_graph: Dict[str, Set[str]] = {}
_graph_lock = _real_lock()
_reported_pairs: Set[Tuple[str, str]] = set()


def _reaches(a: str, b: str) -> bool:
    """True when the graph has a path a ->* b (callers hold _graph_lock)."""
    seen = {a}
    stack = [a]
    while stack:
        n = stack.pop()
        if n == b:
            return True
        for m in _graph.get(n, ()):
            if m not in seen:
                seen.add(m)
                stack.append(m)
    return False


def _note_acquire_edges(lock: "_TsanLockBase") -> None:
    held_sites = {e[0].site for e in _held() if e[0] is not lock}
    held_sites.discard(lock.site)  # same-site: ordering is the class's business
    if not held_sites:
        return
    tgt = lock.site
    with _graph_lock:
        for s in held_sites:
            _graph.setdefault(s, set()).add(tgt)
        for s in held_sites:
            if (s, tgt) not in _reported_pairs and _reaches(tgt, s):
                _reported_pairs.add((s, tgt))
                record(
                    "lock-order-inversion",
                    "error",
                    f"acquiring {tgt} while holding {s}, but the order "
                    f"{tgt} -> ... -> {s} was already established elsewhere "
                    "(potential deadlock)",
                    tgt,
                )


def acquisition_graph() -> Dict[str, Set[str]]:
    """Snapshot of the site-level lock-order graph (DESIGN.md §17 catalog)."""
    with _graph_lock:
        return {k: set(v) for k, v in _graph.items()}


# ---------------------------------------------------------- lock wrappers
class _TsanLockBase:
    _reentrant = False

    def __init__(self, raw, site: str):
        self._raw = raw
        self.site = site
        self._finalized = False

    # -- bookkeeping helpers
    def _held_by_current(self) -> bool:
        return any(e[0] is self for e in _held())

    # -- the Lock protocol
    def acquire(self, blocking: bool = True, timeout: float = -1):
        if self._finalized:
            record(
                "acquire-after-finalize",
                "error",
                "lock acquired after finalize() declared its protected "
                "state dead (zombie thread still running?)",
                self.site,
            )
        if not (self._reentrant and self._held_by_current()):
            _note_acquire_edges(self)
        ok = self._raw.acquire(blocking, timeout)
        if ok:
            _held().append((self, time.monotonic()))
        return ok

    def release(self):
        self._raw.release()
        held = _held()
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] is self:
                _, t0 = held.pop(i)
                dt_ms = (time.monotonic() - t0) * 1000.0
                if dt_ms > _hold_ms():
                    record(
                        "long-hold",
                        "warn",
                        f"lock held {dt_ms:.0f} ms "
                        f"(> RA_TSAN_HOLD_MS={_hold_ms():g})",
                        self.site,
                    )
                return
        # release without a matching acquire record (e.g. lock taken before
        # install): delegate silently

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self) -> bool:
        try:
            return self._raw.locked()
        except AttributeError:  # pragma: no cover - old RLock without .locked
            return self._held_by_current()

    def finalize(self) -> None:
        """Declare the protected state dead; later acquires are errors."""
        self._finalized = True

    def __repr__(self):
        return f"<tsan {type(self).__name__} site={self.site}>"


class _TsanLock(_TsanLockBase):
    """Instrumented non-reentrant lock (wraps ``threading.Lock``)."""

    # Condition-protocol delegation: keep our bookkeeping exact instead of
    # letting Condition fall back to acquire(False) probes (which would
    # pollute the acquisition graph with probe edges).
    def _release_save(self):
        self.release()

    def _acquire_restore(self, _state):
        self.acquire()

    def _is_owned(self):
        return self._held_by_current()


class _TsanRLock(_TsanLockBase):
    """Instrumented reentrant lock (wraps ``threading.RLock``)."""

    _reentrant = True

    def _release_save(self):
        held = _held()
        mine = [i for i, e in enumerate(held) if e[0] is self]
        for i in reversed(mine):
            held.pop(i)
        return (self._raw._release_save(), len(mine))

    def _acquire_restore(self, state_n):
        state, n = state_n
        self._raw._acquire_restore(state)
        now = time.monotonic()
        _held().extend([(self, now)] * max(1, n))

    def _is_owned(self):
        return self._raw._is_owned()


# ------------------------------------------------------------- installation
_installed = False
_scope: Tuple[str, ...] = ()
_hold_ms_override: Optional[float] = None


def _hold_ms() -> float:
    if _hold_ms_override is not None:
        return _hold_ms_override
    return _env_float("RA_TSAN_HOLD_MS", 500.0)


def _default_scope() -> Tuple[str, ...]:
    sep = os.sep
    return (f"{sep}repro{sep}", f"{sep}tests{sep}", f"{sep}benchmarks{sep}")


def _site_of(depth: int) -> Optional[str]:
    """Creation site of the caller ``depth`` frames up, or None if out of
    scope (stdlib, third-party) — out-of-scope callers get raw locks."""
    fr = sys._getframe(depth)
    fn = fr.f_code.co_filename
    if not any(p in fn for p in _scope):
        return None
    parts = fn.replace(os.sep, "/").rsplit("/", 2)
    short = "/".join(parts[-2:])
    return f"{short}:{fr.f_lineno}"


def _make_lock():
    site = _site_of(2)
    raw = _real_lock()
    return raw if site is None else _TsanLock(raw, site)


def _make_rlock(_depth: int = 2):
    site = _site_of(_depth)
    raw = _real_rlock()
    return raw if site is None else _TsanRLock(raw, site)


def _make_condition(lock=None):
    if lock is None:
        lock = _make_rlock(_depth=3)  # attribute the site to Condition()'s caller
    return _real_condition(lock)


def install(scope: Optional[Tuple[str, ...]] = None, hold_ms: Optional[float] = None) -> None:
    """Patch ``threading.Lock``/``RLock``/``Condition`` with the wrappers.

    Idempotent.  ``scope`` is a tuple of path fragments; only locks created
    from matching files are instrumented (default: this repo's ``src``,
    ``tests`` and ``benchmarks`` trees).
    """
    global _installed, _scope, _hold_ms_override
    _scope = tuple(scope) if scope else _default_scope()
    _hold_ms_override = hold_ms
    if _installed:
        return
    threading.Lock = _make_lock
    threading.RLock = _make_rlock
    threading.Condition = _make_condition
    _installed = True


def uninstall() -> None:
    """Restore the real primitives and forget graph state (reports stay
    until :func:`drain`)."""
    global _installed
    threading.Lock = _real_lock
    threading.RLock = _real_rlock
    threading.Condition = _real_condition
    _installed = False
    with _graph_lock:
        _graph.clear()
        _reported_pairs.clear()


def installed() -> bool:
    return _installed


# ------------------------------------------------------ guarded-field tracer
# class -> (original __setattr__, {field: lock attr name})
_watched: Dict[type, Tuple[object, Dict[str, str]]] = {}
# id(obj) -> creating thread ident (id-keyed: works for __slots__ classes
# too; only populated while watching, cleared by unwatch_all)
_creators: Dict[int, int] = {}
_creators_lock = _real_lock()


def _lock_held_by_me(lock) -> Optional[bool]:
    """True/False when ownership is decidable, None when it is not."""
    if isinstance(lock, _TsanLockBase):
        return lock._held_by_current()
    is_owned = getattr(lock, "_is_owned", None)
    if is_owned is not None:
        try:
            return bool(is_owned())
        except Exception:  # pragma: no cover - exotic lock
            return None
    locked = getattr(lock, "locked", None)
    if locked is not None:
        try:
            # raw Lock: held by *someone* -> can't attribute, assume ok;
            # not held at all -> definitely unguarded
            return None if locked() else False
        except Exception:  # pragma: no cover
            return None
    return None


def _check_guarded_write(obj, name: str, lockname: str, cls: type) -> None:
    if not hasattr(obj, name):
        # first write = construction; remember who built the object
        with _creators_lock:
            _creators.setdefault(id(obj), threading.get_ident())
        return
    lock = getattr(obj, lockname, None)
    if lock is None:
        return  # lock lives elsewhere (e.g. on the owning Router) — static rule covers it
    held = _lock_held_by_me(lock)
    if held is not False:
        return
    me = threading.get_ident()
    with _creators_lock:
        creator = _creators.get(id(obj))
    if creator == me:
        # single-owner mutation by the constructing thread is the loader
        # ring idiom; cross-thread writes are what race
        return
    record(
        "unguarded-write",
        "error",
        f"write to {cls.__name__}.{name} (guarded-by: {lockname}) without "
        f"holding the lock, from a thread that did not construct the object",
        f"{cls.__module__}.{cls.__name__}.{name}",
    )


def watch_class(cls: type, fields: Dict[str, str]) -> None:
    """Trace writes to ``fields`` (``{field: lock_attr}``) on ``cls``."""
    if not fields:
        return
    if cls in _watched:
        _watched[cls][1].update(fields)
        return
    orig = cls.__setattr__
    fmap = dict(fields)

    def traced_setattr(self, name, value, _orig=orig, _fmap=fmap, _cls=cls):
        lockname = _fmap.get(name)
        if lockname is not None:
            _check_guarded_write(self, name, lockname, _cls)
        _orig(self, name, value)

    cls.__setattr__ = traced_setattr
    _watched[cls] = (orig, fmap)


def watch_module(module) -> List[str]:
    """Watch every ``# guarded-by:`` annotated class of ``module`` (the
    map comes from ralint's comment scanner). Returns watched class names."""
    from .lint import collect_guards

    path = getattr(module, "__file__", None)
    if not path or not os.path.isfile(path):
        return []
    watched = []
    for clsname, fields in collect_guards(path).items():
        cls = getattr(module, clsname, None)
        if isinstance(cls, type):
            watch_class(cls, fields)
            watched.append(clsname)
    return watched


#: the threaded modules the pytest plugin traces under --ra-sanitize
DEFAULT_WATCH_MODULES = (
    "repro.remote.cache",
    "repro.remote.client",
    "repro.remote.server",
    "repro.fleet.edge",
    "repro.fleet.router",
    "repro.data.loader",
    "repro.data.device_loader",
    "repro.checkpoint.coldstart",
)


def watch_all(modules: Tuple[str, ...] = DEFAULT_WATCH_MODULES) -> List[str]:
    import importlib

    watched = []
    for name in modules:
        mod = importlib.import_module(name)
        for cls in watch_module(mod):
            watched.append(f"{name}.{cls}")
    return watched


def unwatch_all() -> None:
    for cls, (orig, _fields) in _watched.items():
        cls.__setattr__ = orig
    _watched.clear()
    with _creators_lock:
        _creators.clear()
