"""Correctness tooling for the threaded data plane (DESIGN.md §17).

Three tools, one invariant catalog:

* ``repro.devtools.lint`` — *ralint*, an AST-based linter enforcing the
  codebase-specific rules that PRs 5–9 kept re-learning by hand: lock
  discipline via ``# guarded-by:`` annotations, thread lifecycle
  (stop-Event + joined stop), no sleep-polling loops, struct format
  literals matching ``core/layouts.py``, and env knobs routed through
  ``spec.env_*`` + documented in the README.  CLI: ``python
  tools/ralint.py src/``.
* ``repro.devtools.tsan`` — a runtime concurrency sanitizer: drop-in
  instrumented ``Lock``/``RLock``/``Condition`` recording a global
  acquisition graph (lock-order inversions, long holds,
  acquire-after-finalize) plus a guarded-field write tracer that flags
  unguarded cross-thread mutation.  Activated under pytest with
  ``--ra-sanitize``.
* ``repro.devtools.doctor`` — checks real ``.ra`` files against the
  layout registry (``racat doctor FILE|DIR``), nonzero exit on drift.

Import is lazy so ``repro.core`` never pays for devtools.
"""

from __future__ import annotations

__all__ = ["lint", "tsan", "doctor"]


def __getattr__(name):
    if name in __all__:
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(name)
