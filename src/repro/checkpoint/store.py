"""Checkpointing on the RawArray format — the paper's archival story as the
framework's fault-tolerance plane.

A checkpoint is a directory::

    step_000420/
      manifest.json        tree structure, leaf -> file, dtypes/shapes,
                           loader state, adamw step, user metadata
      param__embed.ra      one RawArray file per pytree leaf
      param__dense_layers__attn__wq.ra
      opt__m__....ra
      ...

Design properties (DESIGN.md §2):

* every leaf file is independently memory-mappable → restore streams
  straight into device buffers; a *sharded* restore reads only each host's
  row slice via ``ra.memmap_slice`` (elastic resharding: the mesh that
  restores may differ from the mesh that saved);
* **atomic publish**: writes land in ``<dir>.tmp`` and are renamed only
  after fsync — a killed job never leaves a half-written "latest";
* **async save**: leaves are snapshotted to host RAM (np.asarray) and
  written by a background thread while training continues;
* keep-last-k garbage collection;
* **remote restore** (DESIGN.md §9): ``load_checkpoint`` /
  ``restore_resharded`` accept an ``http(s)://`` checkpoint-directory URL —
  a fresh host cold-starts a model straight from a byte-range server, the
  manifest over HTTP and every leaf streamed by the same one-wave engine
  plan as local restore;
* **remote save** (DESIGN.md §11): ``save_checkpoint`` (and the manager)
  also accept a checkpoint-directory URL — each leaf is one authenticated
  atomic PUT and the manifest uploads last, so a remote checkpoint becomes
  visible only once complete (checkpoint-to-object-store without touching
  local disk).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from .. import core as ra

MANIFEST = "manifest.json"
_SEP = "__"

_join = ra.join_path


def _load_manifest(path: str) -> Dict[str, Any]:
    if ra.is_url(path):
        from .. import remote

        return json.loads(remote.fetch_bytes(_join(path, MANIFEST)))
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)


def _leaf_name(path: Any, prefix: str) -> str:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
        else:
            keys.append(str(k))
    return prefix + _SEP + _SEP.join(keys) if keys else prefix


def _flatten(tree: Any, prefix: str) -> Dict[str, np.ndarray]:
    return {
        _leaf_name(path, prefix): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }


def _leaf_to_numpy(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def save_checkpoint(
    directory: str,
    step: int,
    params: Any,
    opt_state: Any = None,
    *,
    extra: Optional[Dict[str, Any]] = None,
    crc32: bool = False,
    chunked: bool = False,
    codec: Optional[str] = None,
    chunk_bytes: Optional[int] = None,
    quantize: Optional[str] = None,
) -> str:
    """Synchronous atomic save. Returns the final checkpoint path.

    ``chunked=True`` writes every leaf chunk-compressed (DESIGN.md §10):
    leaves compress concurrently on the shared engine pool (within one leaf
    the chunks compress serially — the leaf writes already occupy the pool;
    a single-leaf save chunk-parallelizes instead), and restore folds every
    leaf's chunk decodes into the one restore wave.

    ``quantize="u8"`` (DESIGN.md §12/§13) stores every float leaf as uint8
    codes with data-driven per-channel calibration; the schema rides BOTH in
    each leaf's trailing metadata (any RawArray reader can decode the file
    standalone) and in the manifest (so restore resolves dequant parameters
    without a per-leaf metadata round trip). Non-float and 0-d leaves are
    stored verbatim. Composes with ``chunked``/``codec``.

    ``directory`` may be an ``http(s)://`` URL of a write-enabled byte-range
    server (DESIGN.md §11): every leaf ships as one authenticated PUT with
    server-side atomic publish (engine-pool-parallel across leaves, token
    knob ``RA_REMOTE_TOKEN``), and the manifest is uploaded LAST — readers
    resolve a checkpoint through its manifest, so the checkpoint does not
    exist remotely until the final PUT lands (the remote twin of the local
    temp-dir + rename publish)."""
    remote_save = ra.is_url(directory)
    final = _join(directory, f"step_{step:08d}")
    if remote_save:
        tmp = final  # leaf PUTs are individually atomic; manifest-last publishes
    else:
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)

    leaves: Dict[str, np.ndarray] = {}
    leaves.update(_flatten(params, "param"))
    if opt_state is not None:
        leaves.update(_flatten(opt_state, "opt"))

    manifest: Dict[str, Any] = {
        "format": "rawarray-checkpoint-v1",
        "step": step,
        "leaves": {},
        "extra": extra or {},
        "time": time.time(),
    }
    # leaf writes go wide over the shared engine pool (DESIGN.md §8); each
    # write falls back to sequential I/O internally while on a pool thread
    write_tasks = []
    for name, leaf in leaves.items():
        arr = _leaf_to_numpy(leaf)
        fname = name + ".ra"
        fpath = _join(tmp, fname)
        entry: Dict[str, Any] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype) if arr.dtype.names is None else "void",
        }
        meta: Optional[bytes] = None
        if (
            quantize is not None
            and arr.dtype.names is None
            and np.issubdtype(arr.dtype, np.floating)
            and arr.ndim >= 1
        ):
            # calibrate on the save thread (cheap vs compression) so the
            # schema can land in the manifest; the engine tasks then write
            # plain uint8 payloads
            info = ra.quant.quant_params(arr, quantize)
            arr = info.quantize(arr)
            meta = info.encode()
            entry["quant"] = info.to_dict()
            entry["stored_dtype"] = str(arr.dtype)
        write_tasks.append(
            lambda p=fpath, a=arr, m=meta: ra.write(
                p, a, metadata=m, crc32=crc32,
                chunked=chunked, codec=codec, chunk_bytes=chunk_bytes,
            )
        )
        manifest["leaves"][name] = entry
    ra.engine.run_tasks(write_tasks)
    body = json.dumps(manifest, indent=1).encode()
    if remote_save:
        from .. import remote

        remote.upload_bytes(_join(final, MANIFEST), body)  # publish: manifest LAST
        return final
    with open(os.path.join(tmp, MANIFEST), "wb") as f:
        f.write(body)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _entry_quant(entry: Dict[str, Any], fpath: str, hdr) -> Optional["ra.quant.QuantInfo"]:
    """The leaf's dequantization schema, or None for a verbatim leaf.

    Fast path is the manifest (recorded at save time, zero extra I/O); the
    fallback reads the file's trailing metadata so checkpoints whose leaves
    were quantized by other writers (plain ``ra.write(quantize=)``) still
    restore to logical floats."""
    q = entry.get("quant")
    if q is not None:
        return ra.quant.QuantInfo.from_dict(q)
    want = entry.get("dtype")
    if hdr.dtype() == np.uint8 and want not in (None, "uint8", "void"):
        return ra.read_quant_metadata(fpath)
    return None


def _read_leaves_parallel(
    path: str,
    manifest: Dict[str, Any],
    names: List[str],
    quants_out: Optional[Dict[str, Any]] = None,
) -> Dict[str, np.ndarray]:
    """Stream many leaf files into preallocated arrays in ONE engine wave:
    cross-file and intra-file slab parallelism share the pool (DESIGN.md §8).
    Chunked-compressed leaves (DESIGN.md §10) join the wave too — one
    fetch+decompress task per chunk across all leaves. Quantized-u8 leaves
    (DESIGN.md §12) are dequantized host-side in a follow-up parallel wave —
    unless the caller passes ``quants_out``, which receives each quantized
    leaf's ``QuantInfo`` and leaves the stored u8 codes untouched (the
    cold-start paths decode on device instead; DESIGN.md §13)."""
    arrays: Dict[str, np.ndarray] = {}
    jobs = []
    chunk_tasks = []
    fds: List[int] = []
    fallback: List[Tuple[str, str]] = []
    # resolve every leaf's (header, source, chunk table, quant schema)
    # concurrently first: remotely each resolution costs 1-2 HTTP round
    # trips, and a serial loop over hundreds of leaves would dominate
    # cold-start latency
    metas: Dict[str, Tuple[str, Any, Any, Any]] = {}
    quants: Dict[str, Any] = {}

    def _resolve(name: str) -> None:
        entry = manifest["leaves"][name]
        fpath = _join(path, entry["file"])
        hdr = ra.header_of(fpath)
        src = None
        table = None
        chunked = bool(hdr.flags & ra.FLAG_CHUNKED) and not hdr.big_endian
        if hdr.data_length and (hdr.plain or chunked):
            if ra.is_url(fpath):
                from .. import remote

                src = remote.get_reader(fpath)
            elif chunked:
                src = os.open(fpath, os.O_RDONLY)
                fds.append(src)
        if chunked and src is not None:
            table = ra.codec.read_table(src, hdr)
        q = _entry_quant(entry, fpath, hdr)
        if q is not None:
            quants[name] = q
        metas[name] = (fpath, hdr, src, table)

    try:
        ra.engine.run_tasks([(lambda n=n: _resolve(n)) for n in names])
        for name in names:
            fpath, hdr, src, table = metas[name]
            if table is not None:
                arr = np.empty(hdr.shape, hdr.dtype())
                arrays[name] = arr
                if hdr.logical_nbytes:
                    mv = memoryview(arr.reshape(-1).view(np.uint8)).cast("B")
                    chunk_tasks += ra.codec.chunk_read_tasks(
                        src, hdr, table, 0, hdr.logical_nbytes, mv
                    )
                continue
            if not hdr.plain:
                fallback.append((name, fpath))
                continue
            arr = np.empty(hdr.shape, hdr.dtype())
            arrays[name] = arr
            if hdr.data_length:
                if src is None:
                    src = os.open(fpath, os.O_RDONLY)
                    fds.append(src)
                mv = memoryview(arr.reshape(-1).view(np.uint8)).cast("B")
                jobs.append((src, hdr.nbytes, mv))
        if chunk_tasks:  # one wave: slab preads + chunk decodes share the pool
            ra.engine.run_tasks(ra.engine.span_read_tasks(jobs) + chunk_tasks)
        else:
            ra.engine.parallel_read_spans(jobs)
    finally:
        for fd in fds:
            os.close(fd)
    for name, fpath in fallback:
        arrays[name] = np.asarray(ra.read(fpath))
    if quants_out is not None:
        quants_out.update(quants)
    elif quants:  # host dequant, parallel across leaves (numpy drops the GIL)
        def _dq(name: str) -> None:
            arrays[name] = quants[name].dequantize(arrays[name])

        ra.engine.run_tasks([(lambda n=n: _dq(n)) for n in quants])
    return arrays


def load_checkpoint(
    path: str,
    params_like: Any,
    opt_like: Any = None,
    *,
    mmap: bool = True,
) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore into the structure of ``params_like`` (shape tree or pytree).

    With ``mmap=True`` (default) every leaf is streamed into a preallocated
    array by one parallel engine wave over all leaf files; ``mmap=False``
    keeps the simple per-leaf ``ra.read`` path. ``path`` may be an
    ``http(s)://`` checkpoint URL — same wave plan, ranged reads."""
    manifest = _load_manifest(path)

    def restore(tree: Any, prefix: str) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names = [_leaf_name(pth, prefix) for pth, _ in flat]
        if mmap:
            arrays = _read_leaves_parallel(path, manifest, names)
        else:
            arrays = {
                n: np.asarray(
                    ra.read(_join(path, manifest["leaves"][n]["file"]), dequantize=True)
                )
                for n in names
            }
        out = []
        for name, (pth, like) in zip(names, flat):
            arr = arrays[name]
            want = tuple(like.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{name}: checkpoint {arr.shape} vs model {want}")
            out.append(arr)
        return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), out)

    params = restore(params_like, "param")
    opt = restore(opt_like, "opt") if opt_like is not None else None
    return params, opt, manifest.get("extra", {})


def restore_resharded(
    path: str,
    name: str,
    *,
    row_start: int,
    row_stop: int,
    dequantize: bool = False,
) -> np.ndarray:
    """Elastic restore: read only rows [start, stop) of one leaf — offset
    arithmetic on the .ra file, no full-array read (a different mesh's host
    reads exactly its slice). Works on a checkpoint URL too (the row slab
    becomes ranged requests) and on chunked-compressed leaves (DESIGN.md
    §10): only the chunks overlapping the row slab are fetched + decoded.

    ``dequantize=True`` reconstructs logical floats from a quantized-u8
    leaf; row slicing composes with the quant schema because calibration is
    per-channel over the LAST axis (every row carries all channels)."""
    manifest = _load_manifest(path)
    entry = manifest["leaves"][name]
    fpath = _join(path, entry["file"])
    hdr = ra.header_of(fpath)
    quant = _entry_quant(entry, fpath, hdr) if dequantize else None

    def _dq(a: np.ndarray) -> np.ndarray:
        return quant.dequantize(a) if quant is not None else a

    chunked = bool(hdr.flags & ra.FLAG_CHUNKED)
    if not ra.is_url(fpath) and not chunked:
        return _dq(np.asarray(ra.memmap_slice(fpath, row_start, row_stop)))
    if hdr.compressed and not chunked:
        raise ra.RawArrayError(
            "cannot row-slice a whole-file-compressed payload; "
            "save the checkpoint with chunked=True"
        )
    if not hdr.shape:
        raise ra.RawArrayError("cannot row-slice a 0-d array")
    n = hdr.shape[0]
    row_start, row_stop = max(0, row_start), min(row_stop, n)
    if row_stop < row_start:
        raise ra.RawArrayError(f"bad slice [{row_start}, {row_stop})")
    row = hdr.elbyte
    for d in hdr.shape[1:]:
        row *= d
    out = np.empty((row_stop - row_start,) + hdr.shape[1:], hdr.dtype())
    if out.nbytes:
        fd = None
        if ra.is_url(fpath):
            from .. import remote

            src: object = remote.get_reader(fpath)
        else:
            src = fd = os.open(fpath, os.O_RDONLY)
        try:
            mv = memoryview(out.reshape(-1).view(np.uint8)).cast("B")
            if chunked:
                table = ra.codec.read_table(src, hdr)
                ra.engine.run_tasks(ra.codec.chunk_read_tasks(
                    src, hdr, table, row_start * row, row_stop * row, mv
                ))
            else:
                ra.engine.parallel_read_into(src, hdr.nbytes + row_start * row, mv)
        finally:
            if fd is not None:
                os.close(fd)
    return _dq(out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d[5:]))
            except ValueError:
                pass
    return max(steps) if steps else None


class CheckpointManager:
    """Async, keep-last-k checkpoint driver for the training loop."""

    def __init__(
        self,
        directory: str,
        *,
        keep: int = 3,
        async_save: bool = True,
        chunked: bool = False,
        codec: Optional[str] = None,
        chunk_bytes: Optional[int] = None,
        quantize: Optional[str] = None,
    ):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self.chunked = chunked
        self.codec = codec
        self.chunk_bytes = chunk_bytes
        self.quantize = quantize
        self._thread: Optional[threading.Thread] = None
        self.save_s = 0.0
        if not ra.is_url(directory):
            os.makedirs(directory, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, params: Any, opt_state: Any = None, extra: Optional[Dict] = None) -> None:
        self.wait()  # one in flight at a time
        # snapshot to host BEFORE returning control (params may mutate next step)
        host_params = jax.tree_util.tree_map(_leaf_to_numpy, params)
        host_opt = (
            jax.tree_util.tree_map(_leaf_to_numpy, opt_state) if opt_state is not None else None
        )

        def run():
            t0 = time.perf_counter()
            save_checkpoint(
                self.directory, step, host_params, host_opt, extra=extra,
                chunked=self.chunked, codec=self.codec, chunk_bytes=self.chunk_bytes,
                quantize=self.quantize,
            )
            self._gc()
            self.save_s += time.perf_counter() - t0

        if self.async_save:
            self._thread = threading.Thread(target=run, daemon=False, name="ra-ckpt")
            self._thread.start()
        else:
            run()

    def _gc(self) -> None:
        if ra.is_url(self.directory):
            return  # remote stores garbage-collect server-side, not from here
        steps = sorted(
            int(d[5:])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")
