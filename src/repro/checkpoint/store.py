"""Checkpointing on the RawArray format — the paper's archival story as the
framework's fault-tolerance plane.

A checkpoint is a directory::

    step_000420/
      manifest.json        tree structure, leaf -> file, dtypes/shapes,
                           loader state, adamw step, user metadata
      param__embed.ra      one RawArray file per pytree leaf
      param__dense_layers__attn__wq.ra
      opt__m__....ra
      ...

Design properties (DESIGN.md §2):

* every leaf file is independently memory-mappable → restore streams
  straight into device buffers; a *sharded* restore reads only each host's
  row slice via ``ra.memmap_slice`` (elastic resharding: the mesh that
  restores may differ from the mesh that saved);
* **atomic publish**: writes land in ``<dir>.tmp`` and are renamed only
  after fsync — a killed job never leaves a half-written "latest";
* **async save**: leaves are snapshotted to host RAM (np.asarray) and
  written by a background thread while training continues;
* keep-last-k garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from .. import core as ra

MANIFEST = "manifest.json"
_SEP = "__"


def _leaf_name(path: Any, prefix: str) -> str:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
        else:
            keys.append(str(k))
    return prefix + _SEP + _SEP.join(keys) if keys else prefix


def _flatten(tree: Any, prefix: str) -> Dict[str, np.ndarray]:
    return {
        _leaf_name(path, prefix): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }


def _leaf_to_numpy(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def save_checkpoint(
    directory: str,
    step: int,
    params: Any,
    opt_state: Any = None,
    *,
    extra: Optional[Dict[str, Any]] = None,
    crc32: bool = False,
) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves: Dict[str, np.ndarray] = {}
    leaves.update(_flatten(params, "param"))
    if opt_state is not None:
        leaves.update(_flatten(opt_state, "opt"))

    manifest: Dict[str, Any] = {
        "format": "rawarray-checkpoint-v1",
        "step": step,
        "leaves": {},
        "extra": extra or {},
        "time": time.time(),
    }
    # leaf writes go wide over the shared engine pool (DESIGN.md §8); each
    # write falls back to sequential I/O internally while on a pool thread
    write_tasks = []
    for name, leaf in leaves.items():
        arr = _leaf_to_numpy(leaf)
        fname = name + ".ra"
        fpath = os.path.join(tmp, fname)
        write_tasks.append(lambda p=fpath, a=arr: ra.write(p, a, crc32=crc32))
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype) if arr.dtype.names is None else "void",
        }
    ra.engine.run_tasks(write_tasks)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _read_leaves_parallel(path: str, manifest: Dict[str, Any], names: List[str]) -> Dict[str, np.ndarray]:
    """Stream many leaf files into preallocated arrays in ONE engine wave:
    cross-file and intra-file slab parallelism share the pool (DESIGN.md §8)."""
    arrays: Dict[str, np.ndarray] = {}
    jobs = []
    fds: List[int] = []
    fallback: List[Tuple[str, str]] = []
    try:
        for name in names:
            entry = manifest["leaves"][name]
            fpath = os.path.join(path, entry["file"])
            hdr = ra.header_of(fpath)
            plain = not (hdr.flags & (ra.FLAG_ZLIB | ra.FLAG_CRC32_TRAILER)) and not hdr.big_endian
            if not plain:
                fallback.append((name, fpath))
                continue
            arr = np.empty(hdr.shape, hdr.dtype())
            arrays[name] = arr
            if hdr.data_length:
                fd = os.open(fpath, os.O_RDONLY)
                fds.append(fd)
                mv = memoryview(arr.reshape(-1).view(np.uint8)).cast("B")
                jobs.append((fd, hdr.nbytes, mv))
        ra.engine.parallel_read_spans(jobs)
    finally:
        for fd in fds:
            os.close(fd)
    for name, fpath in fallback:
        arrays[name] = np.asarray(ra.read(fpath))
    return arrays


def load_checkpoint(
    path: str,
    params_like: Any,
    opt_like: Any = None,
    *,
    mmap: bool = True,
) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore into the structure of ``params_like`` (shape tree or pytree).

    With ``mmap=True`` (default) every leaf is streamed into a preallocated
    array by one parallel engine wave over all leaf files; ``mmap=False``
    keeps the simple per-leaf ``ra.read`` path."""
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)

    def restore(tree: Any, prefix: str) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names = [_leaf_name(pth, prefix) for pth, _ in flat]
        if mmap:
            arrays = _read_leaves_parallel(path, manifest, names)
        else:
            arrays = {
                n: np.asarray(ra.read(os.path.join(path, manifest["leaves"][n]["file"])))
                for n in names
            }
        out = []
        for name, (pth, like) in zip(names, flat):
            arr = arrays[name]
            want = tuple(like.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{name}: checkpoint {arr.shape} vs model {want}")
            out.append(arr)
        return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), out)

    params = restore(params_like, "param")
    opt = restore(opt_like, "opt") if opt_like is not None else None
    return params, opt, manifest.get("extra", {})


def restore_resharded(
    path: str,
    name: str,
    *,
    row_start: int,
    row_stop: int,
) -> np.ndarray:
    """Elastic restore: read only rows [start, stop) of one leaf — offset
    arithmetic on the .ra file, no full-array read (a different mesh's host
    reads exactly its slice)."""
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    entry = manifest["leaves"][name]
    return np.asarray(
        ra.memmap_slice(os.path.join(path, entry["file"]), row_start, row_stop)
    )


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d[5:]))
            except ValueError:
                pass
    return max(steps) if steps else None


class CheckpointManager:
    """Async, keep-last-k checkpoint driver for the training loop."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.save_s = 0.0
        os.makedirs(directory, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, params: Any, opt_state: Any = None, extra: Optional[Dict] = None) -> None:
        self.wait()  # one in flight at a time
        # snapshot to host BEFORE returning control (params may mutate next step)
        host_params = jax.tree_util.tree_map(_leaf_to_numpy, params)
        host_opt = (
            jax.tree_util.tree_map(_leaf_to_numpy, opt_state) if opt_state is not None else None
        )

        def run():
            t0 = time.perf_counter()
            save_checkpoint(self.directory, step, host_params, host_opt, extra=extra)
            self._gc()
            self.save_s += time.perf_counter() - t0

        if self.async_save:
            self._thread = threading.Thread(target=run, daemon=False, name="ra-ckpt")
            self._thread.start()
        else:
            run()

    def _gc(self) -> None:
        steps = sorted(
            int(d[5:])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")
