"""Cold-start restore engine: checkpoint (path or URL) → device buffers in
one overlapped pipeline (DESIGN.md §13).

``load_checkpoint`` reads phase by phase: resolve every leaf, fetch every
byte, decode every chunk, dequantize, and only then does the caller
``device_put`` — time-to-weights-resident is the SUM of the phases. This
module overlaps them so the total approaches the MAX:

1. **pin wave** — the whole remote version set pins in ONE ``/stat``
   listing round trip per checkpoint directory (sizes + ETags, the HTTP
   analogue of S3 ListObjectsV2; servers without the route fall back to
   per-leaf HEADs), local leaves by inode stat + held fd — a checkpoint
   overwritten mid-restore fails fast instead of silently mixing
   generations — and a bounded number of keep-alive sockets pre-warm for
   the fetch wave to come;
2. **bounded streaming** — leaves are admitted largest-first under an
   in-flight byte budget (knob ``RA_COLDSTART_INFLIGHT``); each admitted
   leaf's driver task resolves its header / chunk table / quant schema and
   fans its slab reads or chunk fetch+decode tasks onto the shared engine
   pool, so resolution round-trips, fetch, and decompress of MANY leaves
   interleave instead of serializing into phases;
3. **overlapped device upload** — whichever pool thread completes a leaf
   dispatches its ``jax.device_put`` (and, for quantized-u8 leaves
   restoring onto a single device, the fused Pallas ``dequant_rows`` —
   uint8 crosses the link, floats materialize device-side exactly as the
   device feed plane does for batches) WITHOUT blocking, while later
   leaves are still being fetched/decoded; one quiet barrier at the end
   waits for every transfer at once.

The phase-by-phase path survives as :func:`restore_naive` — the benchmark
baseline (`benchmarks/bench_coldstart.py`) and the escape hatch
(`--restore naive`).
"""

from __future__ import annotations

import os
import sys
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .. import core as ra
from ..core.spec import env_int
from .store import _entry_quant, _join, _leaf_name, _load_manifest


def default_inflight_bytes() -> int:
    """In-flight decode-buffer budget (knob ``RA_COLDSTART_INFLIGHT``,
    default 1 GiB): peak host bytes held by leaves that are fetched or
    decoding but not yet resident on device. Quantized leaves count their
    logical (post-dequant) size when dequantization must happen host-side."""
    return max(1, env_int("RA_COLDSTART_INFLIGHT", 1 << 30))


@dataclass
class ColdStartStats:
    """Filled in by :func:`restore_pipelined` (pass one in to collect)."""

    leaves: int = 0
    logical_bytes: int = 0         # sum of restored (post-dequant) leaf bytes
    stored_bytes: int = 0          # sum of on-disk/wire payload bytes
    resolve_s: float = 0.0         # wave 1: version pins + socket pre-warm
    restore_s: float = 0.0         # total time to all-weights-resident
    h2d_s: float = 0.0             # time inside device_put + dequant dispatch
    h2d_bytes: int = 0             # bytes crossing the host->device boundary
    dequant_leaves: int = 0        # leaves decoded from u8 (device or host)
    prewarmed_conns: int = 0       # sockets opened by pool pre-warm
    peak_inflight_bytes: int = 0   # observed max of the scheduler's budget
    inflight_cap: int = 0          # the budget it ran under


@dataclass
class _LeafPlan:
    name: str
    fpath: str
    entry: Dict[str, Any]
    want: Tuple[int, ...] = ()     # model-side shape (from the like tree)
    hdr: Any = None
    src: Any = None                # int fd, RemoteReader, or None
    fd: Optional[int] = None       # owned fd (closed by the scheduler)
    table: Any = None
    quant: Any = None              # QuantInfo or None
    pin: Any = None                # (mtime_ns, size) local | ETag str remote
    pinned: Any = None             # Event: version pin landed (or failed)
    pin_err: Any = None            # pin-task failure, re-raised by the driver
    fallback: bool = False         # non-plain non-chunked: one ra.read task
    cost: int = 0                  # budget charge while in flight
    sharding: Any = None           # per-leaf device_put target (or None)
    out: Any = None                # the restored jax.Array


def shardings_from_specs(mesh, tree: Any) -> Any:
    """Map a pytree of ``PartitionSpec``s (or None) to ``NamedSharding``s on
    ``mesh`` — the bridge from ``distributed.sharding.spec_for`` rule specs
    to the per-leaf placement :func:`restore_pipelined` consumes."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    def one(spec):
        if spec is None:
            spec = PartitionSpec()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, tree, is_leaf=lambda x: x is None or isinstance(x, PartitionSpec))


def _local_pin(fpath: str) -> Tuple[int, int]:
    st = os.stat(fpath)
    return (st.st_mtime_ns, st.st_size)


class _Budget:
    """In-flight byte accounting: admit (blocking), release, peak tracking."""

    def __init__(self, cap: int):
        self.cap = cap
        self.used = 0   # guarded-by: _cond
        self.peak = 0   # guarded-by: _cond
        self._cond = threading.Condition()
        self._aborted = False  # guarded-by: _cond

    def admit(self, cost: int) -> bool:
        """Block until ``cost`` fits (a single over-budget leaf is admitted
        alone — the cap bounds concurrency, it must never deadlock a leaf
        larger than itself). Returns False if the restore aborted."""
        with self._cond:
            while not self._aborted and self.used > 0 and self.used + cost > self.cap:
                self._cond.wait(timeout=0.5)
            if self._aborted:
                return False
            self.used += cost
            self.peak = max(self.peak, self.used)
            return True

    def release(self, cost: int) -> None:
        with self._cond:
            self.used -= cost
            self._cond.notify_all()

    def abort(self) -> None:
        with self._cond:
            self._aborted = True
            self._cond.notify_all()


def _pin_leaf(plan: _LeafPlan, stat_pins: Optional[Dict[str, Tuple[int, Optional[str]]]] = None) -> None:
    """Pin one leaf's version — local leaves by inode identity (mtime+size,
    plus a held fd), remote leaves by ETag: from the checkpoint directory's
    one-shot ``/stat`` listing when available (zero per-leaf round trips),
    else a revalidating HEAD. Touches no payload bytes: header/table
    resolution rides inside the streaming drivers where its round trips
    overlap fetch and decode."""
    if ra.is_url(plan.fpath):
        from .. import remote

        ent = stat_pins.get(plan.fpath) if stat_pins else None
        if ent is not None:
            # a stale listing cannot slip through: every ranged response's
            # ETag is checked against this pin, so a leaf replaced between
            # listing and read fails loudly on its first byte
            reader = remote.get_reader(plan.fpath, pinned=ent)
        else:
            # revalidate: the pin must be the server's CURRENT generation,
            # not whatever an earlier traversal cached
            reader = remote.get_reader(plan.fpath, revalidate=True)
        plan.src = reader
        plan.pin = reader.etag
    else:
        plan.pin = _local_pin(plan.fpath)
        plan.fd = plan.src = os.open(plan.fpath, os.O_RDONLY)


def _stat_pins(plans: List[_LeafPlan]) -> Dict[str, Tuple[int, Optional[str]]]:
    """Version-set pinning in one round trip per checkpoint directory: a
    ``/stat`` listing returns (size, ETag) for every file, the HTTP
    analogue of S3's ListObjectsV2. Per-leaf HEADs dominate the pin wave
    on many-leaf checkpoints (one request each against a request-bound
    server), so the listing collapses that whole wave; servers without the
    route just leave the map empty and leaves HEAD-pin individually."""
    from .. import remote

    pins: Dict[str, Tuple[int, Optional[str]]] = {}
    for d in sorted({p.fpath.rsplit("/", 1)[0] for p in plans if ra.is_url(p.fpath)}):
        try:
            listing = remote.stat_dir(d)
        except remote.RemoteAuthError:
            raise  # denial is authoritative — don't retry it once per leaf
        except ra.RawArrayError:
            continue  # no /stat route (older server) — fall back per leaf
        for name, ent in listing.items():
            pins[f"{d}/{name}"] = ent
    return pins


def _prewarm_alloc(plans: List[_LeafPlan]) -> Dict[str, int]:
    """Socket pre-warm budget, per leaf. The fetch wave runs at most
    ``engine.workers()`` tasks at once, so that is the total number of
    sockets worth holding open ACROSS all leaves — each leaf URL has its
    own pooled ``RemoteReader``, so a naive per-leaf prewarm multiplies
    into hundreds of sockets that mostly sit idle (and, worse, burst past
    server accept backlogs). Spend the budget largest-first: those leaves
    are admitted first and are the only ones whose chunk fetches fan out
    over several connections. Each reader's construction HEAD already
    parks one socket, which ``prewarm`` counts, so most small leaves cost
    nothing. Computable from manifest-derived costs alone, so each leaf's
    pin task opens its own share without a whole-checkpoint barrier."""
    alloc: Dict[str, int] = {}
    left = ra.engine.workers()
    for p in sorted(plans, key=lambda p: p.cost, reverse=True):
        if left <= 0:
            break
        if not ra.is_url(p.fpath):
            continue
        # chunk fetches are the only per-leaf fan-out; estimate their count
        # from the in-flight cost at the engine's chunking granularity
        est = max(1, min(-(-p.cost // max(1, ra.engine.chunk_bytes())), left))
        n = min(est, left)  # RemoteReader.prewarm re-caps at RA_REMOTE_CONNS
        alloc[p.name] = n
        left -= n
    return alloc


def _check_local_pin(plan: _LeafPlan) -> None:
    """Fail fast when a local leaf file was replaced mid-restore (the
    remote twin is the per-response ETag check inside ``RemoteReader``)."""
    if isinstance(plan.pin, tuple):
        try:
            now = _local_pin(plan.fpath)
        except OSError as e:
            raise ra.RawArrayError(
                f"{plan.name}: checkpoint leaf {plan.fpath} vanished "
                f"during restore ({e})"
            ) from None
        if now != plan.pin:
            raise ra.RawArrayError(
                f"{plan.name}: checkpoint leaf {plan.fpath} changed during "
                "restore (checkpoint overwritten?); restart the restore"
            )


def _resolve_leaf(plan: _LeafPlan) -> None:
    """Per-leaf resolution, run INSIDE the leaf's streaming driver so its
    round trips (header, chunk table, quant metadata) overlap other leaves'
    fetch/decode instead of forming a whole-checkpoint barrier."""
    if plan.src is not None and ra.is_url(plan.fpath):
        # pooled ranged read instead of header_of's per-call connection; the
        # block cache keeps the fetched prefix for the payload reads to come
        from ..core.header import decode_header

        head = plan.src.read_range(0, min(plan.src.size, 4096))
        hdr = plan.hdr = decode_header(head)
    else:
        hdr = plan.hdr = ra.header_of(plan.fpath)
    if tuple(hdr.shape) != plan.want:
        raise ValueError(f"{plan.name}: checkpoint {tuple(hdr.shape)} vs model {plan.want}")
    chunked = bool(hdr.flags & ra.FLAG_CHUNKED) and not hdr.big_endian
    plan.fallback = not (hdr.plain or chunked)
    if chunked and plan.src is not None and hdr.data_length:
        plan.table = ra.codec.read_table(plan.src, hdr)
    plan.quant = _entry_quant(plan.entry, plan.fpath, hdr)


def _leaf_tasks(plan: _LeafPlan, arr: np.ndarray) -> List[Callable[[], None]]:
    """The engine tasks that fill ``arr`` with the leaf's stored payload."""
    hdr = plan.hdr
    if plan.fallback:
        def _whole() -> None:
            a = np.asarray(ra.read(plan.fpath))
            np.copyto(arr, a, casting="equiv")  # equiv: byte-order fixups ok

        return [_whole]
    if not hdr.data_length:
        return []
    mv = memoryview(arr.reshape(-1).view(np.uint8)).cast("B")
    if plan.table is not None:
        return ra.codec.chunk_read_tasks(plan.src, hdr, plan.table, 0, hdr.logical_nbytes, mv)
    return ra.engine.span_read_tasks([(plan.src, hdr.nbytes, mv)])


def _entry_quant_hint(entry: Dict[str, Any]) -> Any:
    """QuantInfo from the manifest alone (no leaf I/O) — enough for budget
    costs and kernel warm-up; drivers re-derive authoritatively (with the
    metadata fallback for foreign u8 files) once the header is in hand."""
    q = entry.get("quant")
    if q is None:
        return None
    try:
        return ra.quant.QuantInfo.from_dict(q)
    except Exception:
        return None


def _start_warmup(plans: List[_LeafPlan], interpret: Optional[bool]) -> Optional[threading.Thread]:
    """Populate the jit cache for every unique quantized (shape, dtype)
    OVERLAPPED with the first fetches: interpret-mode Pallas compiles cost
    real time, and paying them inside the upload thread would serialize
    them behind the pipeline instead of hiding them under I/O. The caller
    must join the returned thread before returning (a compile torn down
    mid-flight at interpreter exit aborts the process)."""
    shapes = {}
    for p in plans:
        if p.quant is not None and p.sharding is None and p.want:
            shapes[(p.want, str(p.quant.orig_dtype))] = None
    if not shapes:
        return None

    def run() -> None:
        try:
            import jax
            import jax.numpy as jnp

            from ..kernels import ops

            for shape, dt in shapes:
                c = int(shape[-1])
                rows = 1
                for d in shape[:-1]:
                    rows *= int(d)
                br = max(256, -(-max(rows, 1) // 8))  # dequant_rows' sizing
                # AOT lower+compile only: executing a full-size dummy would
                # burn a leaf's worth of CPU and park this thread in
                # block_until_ready, GIL-convoying against the fetch wave
                ops.dequant_u8.lower(
                    jax.ShapeDtypeStruct(shape, jnp.uint8),
                    jax.ShapeDtypeStruct((c,), jnp.float32),
                    jax.ShapeDtypeStruct((c,), jnp.float32),
                    out_dtype=jnp.dtype(dt), block_rows=br, interpret=interpret,
                ).compile()
        except Exception:
            pass  # warmup is best-effort; the real call surfaces errors

    # ralint: allow=thread-lifecycle -- returned to restore_pipelined, which
    # joins it in its finally block; best-effort warmup with a bounded body
    t = threading.Thread(target=run, daemon=True, name="ra-coldstart-warm")
    t.start()
    return t


def restore_pipelined(
    path: str,
    params_like: Any,
    opt_like: Any = None,
    *,
    device: Any = None,
    shardings: Any = None,
    opt_shardings: Any = None,
    inflight_bytes: Optional[int] = None,
    interpret: Optional[bool] = None,
    prewarm: bool = True,
    stats: Optional[ColdStartStats] = None,
    _after_resolve: Optional[Callable[[], None]] = None,
) -> Tuple[Any, Any, Dict[str, Any]]:
    """Restore a checkpoint with fetch, decode, dequant, and H2D overlapped.

    Same contract as ``load_checkpoint(path, params_like, opt_like)`` except
    the returned leaves are device-resident ``jax.Array``s:

    * ``device`` — explicit target device (default: jax's default);
    * ``shardings``/``opt_shardings`` — optional pytrees (matching
      ``params_like``/``opt_like``) of ``jax.sharding.Sharding`` per leaf
      for resharded restore onto a live mesh (see
      :func:`shardings_from_specs`); sharded quantized leaves dequantize
      host-side (the fused kernel path needs a single addressable target);
    * ``inflight_bytes`` — override the ``RA_COLDSTART_INFLIGHT`` budget;
    * ``stats`` — a :class:`ColdStartStats` to fill in;
    * ``_after_resolve`` — test hook, called between the pin wave and
      streaming (mutating the checkpoint here must trip the pins).

    Raises ``RawArrayError`` when any leaf's pinned version (local
    mtime+size, remote ETag) changes mid-restore, and propagates auth/
    transport errors unchanged (fail fast — never a silently mixed
    checkpoint)."""
    import jax

    st = stats if stats is not None else ColdStartStats()
    st.inflight_cap = cap = max(1, inflight_bytes if inflight_bytes is not None else default_inflight_bytes())
    t_all = time.perf_counter()
    manifest = _load_manifest(path)

    # ---- plan construction (tree order preserved for reassembly) ----------
    trees: List[Tuple[str, Any, Any]] = [("param", params_like, shardings)]
    if opt_like is not None:
        trees.append(("opt", opt_like, opt_shardings))
    plans: List[_LeafPlan] = []
    tree_meta = []  # (prefix, treedef, leaf names in tree order)
    for prefix, tree, shtree in trees:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names = [_leaf_name(pth, prefix) for pth, _ in flat]
        shards: List[Any] = [None] * len(flat)
        if shtree is not None:
            sflat = jax.tree_util.tree_flatten(shtree, is_leaf=lambda x: x is None)[0]
            if len(sflat) != len(flat):
                raise ValueError(
                    f"shardings tree has {len(sflat)} leaves, {prefix} tree has {len(flat)}"
                )
            shards = list(sflat)
        for name, (pth, like), sh in zip(names, flat, shards):
            entry = manifest["leaves"].get(name)
            if entry is None:
                raise ra.RawArrayError(f"{name}: missing from checkpoint manifest")
            want = tuple(like.shape)
            if "shape" in entry and tuple(entry["shape"]) != want:
                raise ValueError(f"{name}: checkpoint {tuple(entry['shape'])} vs model {want}")
            plan = _LeafPlan(
                name=name, fpath=_join(path, entry["file"]), entry=entry,
                want=want, sharding=sh, quant=_entry_quant_hint(entry),
            )
            # budget/scheduling cost is knowable from the manifest alone:
            # leaves hold their STORED element width host-side (u8 for
            # quantized), except sharded quantized leaves which dequantize
            # on the host and so hold the logical float footprint
            elems = int(np.prod(want, dtype=np.int64)) if want else 1
            if plan.quant is not None:
                out_itemsize = np.dtype(plan.quant.orig_dtype).itemsize
                st.logical_bytes += elems * out_itemsize
                plan.cost = elems * (out_itemsize if sh is not None else 1)
            else:
                logical = int(getattr(like, "nbytes", elems))
                st.logical_bytes += logical
                plan.cost = max(logical, 1)
            plans.append(plan)
        tree_meta.append((prefix, treedef, names))

    by_name = {p.name: p for p in plans}
    st.leaves = len(plans)

    # ---- wave 1: pin versions + prewarm sockets (overlapped) --------------
    t0 = time.perf_counter()
    warmup: Optional[threading.Thread] = None
    # a thread that finishes a leaf wakes the scheduler / dispatches H2D
    # through the GIL, and CPython's default 5ms switch interval is the
    # latency of every such wake while the pool grinds task wrappers — at
    # hundreds of cross-thread wakes per restore that convoy tax rivals
    # the transfers themselves. Tighten it for the restore window only.
    prev_switch = sys.getswitchinterval()
    sys.setswitchinterval(min(prev_switch, 0.001))
    try:
        # warmup needs only manifest-derived facts (want shape + quant hint),
        # so its jit compiles overlap the pin wave's round trips
        warmup = _start_warmup(plans, interpret)
        # one listing per checkpoint dir pins the whole remote version set
        stat_pins = _stat_pins(plans)

        order = sorted(plans, key=lambda p: p.cost, reverse=True)
        budget = _Budget(cap)
        first_err: List[BaseException] = []
        err_lock = threading.Lock()
        stats_lock = threading.Lock()
        all_done = threading.Event()
        done_count = [0]
        pins_done = threading.Event()
        pins_left = [len(order)]
        alloc = _prewarm_alloc(plans) if prewarm else {}

        def _fail(e: BaseException) -> None:
            with err_lock:
                if not first_err:
                    first_err.append(e)
            budget.abort()
            all_done.set()  # wake the waiting scheduler

        def _count_done() -> None:
            with stats_lock:
                done_count[0] += 1
                if done_count[0] == len(order):
                    all_done.set()

        def _pin_task(plan: _LeafPlan) -> None:
            """Version pin (+ this leaf's socket-prewarm share). All pin
            tasks are queued BEFORE any payload driver, so the pin set is
            established at restore start — but payload streaming of already
            -pinned leaves runs concurrently instead of waiting for the
            slowest HEAD of the whole checkpoint."""
            try:
                _pin_leaf(plan, stat_pins)
                n = alloc.get(plan.name, 0)
                if n and plan.src is not None and ra.is_url(plan.fpath):
                    got = plan.src.prewarm(n)
                    with stats_lock:
                        st.prewarmed_conns += got
            except BaseException as e:  # noqa: BLE001 — re-raised by driver
                plan.pin_err = e
            finally:
                plan.pinned.set()
                with stats_lock:
                    pins_left[0] -= 1
                    if pins_left[0] == 0:
                        st.resolve_s = time.perf_counter() - t0
                        pins_done.set()

        inline = (
            ra.engine.workers() == 1
            or ra.engine.sequential_forced()
            or ra.engine.on_engine_thread()
        )
        pool = None if inline else ra.engine.get_pool()

        for plan in order:
            plan.pinned = threading.Event()
        if pool is None:
            for plan in order:
                _pin_task(plan)
        else:
            for plan in order:
                pool.submit(_pin_task, plan)

        if _after_resolve is not None:
            # test hook: act as a barrier so a harness can mutate the
            # checkpoint strictly between "pins taken" and "payload read"
            pins_done.wait()
            _after_resolve()

        def _finish_leaf(plan: _LeafPlan, arr: np.ndarray) -> None:
            """Pin check + device_put (+ fused dequant) DISPATCH for one
            completed leaf. Runs on whichever pool thread finished the
            leaf's last payload task: a dedicated upload thread would
            re-acquire the GIL for every handoff while the pool grinds
            task wrappers, and those handoffs cost more than the uploads.
            Deliberately does NOT block on the transfer — a thread parked
            in ``block_until_ready`` re-enters the GIL convoy on every
            wakeup (measured ~10-40x inflation under pool churn); the
            enqueue is cheap, jax pins the source buffer until the copy
            lands, and one quiet ``block_until_ready`` over the whole tree
            runs after the wave drains."""
            try:
                _check_local_pin(plan)
                t0 = time.perf_counter()
                if plan.quant is not None and plan.sharding is not None:
                    # multi-target leaf: host dequant, then shard-put
                    arr = plan.quant.dequantize(arr)
                    out = jax.device_put(arr, plan.sharding)
                    dequant = True
                elif plan.quant is not None and plan.hdr.shape:
                    # u8 over the link, fused dequant on device
                    from ..kernels import ops  # deferred: pallas is heavy

                    moved = jax.device_put(arr, device)
                    c = int(plan.hdr.shape[-1])
                    scale, bias = plan.quant.channel_params(c)
                    if device is not None:
                        # jit places uncommitted args on the DEFAULT device;
                        # an explicit target needs explicit puts
                        scale = jax.device_put(scale, device)
                        bias = jax.device_put(bias, device)
                    out = ops.dequant_rows(
                        moved, scale, bias,
                        out_dtype=np.dtype(plan.quant.orig_dtype), interpret=interpret,
                    )
                    dequant = True
                else:
                    dequant = plan.quant is not None
                    if dequant:  # 0-d quantized: host decode
                        arr = plan.quant.dequantize(arr)
                    out = jax.device_put(arr, plan.sharding if plan.sharding is not None else device)
                dt = time.perf_counter() - t0
                plan.out = out
                with stats_lock:
                    st.h2d_s += dt
                    st.h2d_bytes += int(arr.nbytes)
                    if dequant:
                        st.dequant_leaves += 1
            except BaseException as e:  # noqa: BLE001 — forwarded
                _fail(e)
            finally:
                # the ledger tracks decode-side residency; the source
                # buffer may outlive the release by the (short) tail of an
                # async copy jax is still draining
                budget.release(plan.cost)
                _count_done()

        def _drive_leaf(plan: _LeafPlan) -> None:
            """Resolve header/table/quant, then fan out the payload tasks —
            runs on the pool, so many leaves resolve concurrently and their
            round trips hide under other leaves' fetch/decode."""
            try:
                # FIFO guarantees this leaf's pin task was dequeued before
                # this driver, so the wait is at most one in-flight HEAD
                plan.pinned.wait()
                if plan.pin_err is not None:
                    raise plan.pin_err
                _resolve_leaf(plan)
                with stats_lock:
                    st.stored_bytes += int(plan.hdr.data_length)
                arr = np.empty(plan.hdr.shape, plan.hdr.dtype())
                tasks = _leaf_tasks(plan, arr)
            except BaseException as e:  # noqa: BLE001 — forwarded
                budget.release(plan.cost)
                _fail(e)
                _count_done()
                return
            if not tasks:
                _finish_leaf(plan, arr)
                return
            remaining = [len(tasks)]
            rlock = threading.Lock()

            def _wrap(t: Callable[[], None]) -> None:
                try:
                    if not first_err:
                        t()
                except BaseException as e:  # noqa: BLE001 — forwarded
                    _fail(e)
                finally:
                    with rlock:
                        remaining[0] -= 1
                        last = remaining[0] == 0
                if last and not first_err:
                    _finish_leaf(plan, arr)
                elif last:
                    budget.release(plan.cost)
                    _count_done()

            if pool is None:
                for t in tasks:
                    _wrap(t)
            else:
                for t in tasks:
                    pool.submit(_wrap, t)

        for plan in order:
            if not budget.admit(plan.cost):
                _count_done()  # never scheduled; keep the ledger whole
                continue
            if first_err:
                budget.release(plan.cost)
                _count_done()
                continue
            if pool is None:
                _drive_leaf(plan)
            else:
                pool.submit(_drive_leaf, plan)
        all_done.wait()
        # an abort can fire while payload tasks are still draining; their
        # buffers stay alive via the closures, and the pool is process-wide
        # so nothing here tears it down underneath them

        if first_err:
            e = first_err[0]
            if isinstance(e, ra.RawArrayError) and "changed on server during read" in str(e):
                raise ra.RawArrayError(
                    f"checkpoint overwritten during restore: {e}"
                ) from e
            raise e

        # one quiet barrier for every async transfer/dequant the completion
        # threads enqueued — the pool is drained, so this wait runs without
        # GIL competition and finishes at memcpy speed
        t0 = time.perf_counter()
        jax.block_until_ready([p.out for p in plans])
        st.h2d_s += time.perf_counter() - t0
    finally:
        sys.setswitchinterval(prev_switch)
        if warmup is not None:
            warmup.join()
        for p in plans:
            if p.fd is not None:
                try:
                    os.close(p.fd)
                except OSError:
                    pass

    st.peak_inflight_bytes = budget.peak
    st.restore_s = time.perf_counter() - t_all

    # ---- reassemble trees in original leaf order --------------------------
    outs: List[Any] = []
    for prefix, treedef, names in tree_meta:
        leaves = [by_name[n].out for n in names]
        outs.append(jax.tree_util.tree_unflatten(treedef, leaves))
    params = outs[0]
    opt = outs[1] if opt_like is not None else None
    return params, opt, manifest.get("extra", {})


def restore_naive(
    path: str,
    params_like: Any,
    opt_like: Any = None,
    *,
    device: Any = None,
    shardings: Any = None,
    opt_shardings: Any = None,
    interpret: Optional[bool] = None,
    stats: Optional[ColdStartStats] = None,
) -> Tuple[Any, Any, Dict[str, Any]]:
    """Phase-by-phase restore: fetch + decode EVERY leaf to host first, THEN
    device_put (+ on-device dequant) leaf by leaf. Runs the exact same
    per-leaf decode as :func:`restore_pipelined` — quantized leaves go
    through the same fused device kernel — so the two paths are bit-exact
    by construction and their difference is pure overlap. The benchmark
    baseline and the escape hatch (``--restore naive``)."""
    import jax

    from .store import _read_leaves_parallel

    st = stats if stats is not None else ColdStartStats()
    t_all = time.perf_counter()
    manifest = _load_manifest(path)

    trees: List[Tuple[str, Any, Any]] = [("param", params_like, shardings)]
    if opt_like is not None:
        trees.append(("opt", opt_like, opt_shardings))

    outs: List[Any] = []
    for prefix, tree, shtree in trees:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names = [_leaf_name(pth, prefix) for pth, _ in flat]
        shards: List[Any] = [None] * len(flat)
        if shtree is not None:
            shards = list(jax.tree_util.tree_flatten(shtree, is_leaf=lambda x: x is None)[0])
        # phase 1+2: fetch + decode everything to host (stored form)
        quants: Dict[str, Any] = {}
        arrays = _read_leaves_parallel(path, manifest, names, quants_out=quants)
        moved: List[Any] = []
        # phase 3: sequential per-leaf H2D + device dequant
        for name, (pth, like), sh in zip(names, flat, shards):
            arr = arrays[name]
            want = tuple(like.shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{name}: checkpoint {arr.shape} vs model {want}")
            quant = quants.get(name)
            st.leaves += 1
            st.logical_bytes += (
                arr.nbytes * np.dtype(quant.orig_dtype).itemsize if quant is not None else arr.nbytes
            )
            t0 = time.perf_counter()
            if quant is not None and sh is not None:
                out = jax.device_put(quant.dequantize(arr), sh)
                st.dequant_leaves += 1
            elif quant is not None and arr.shape:
                from ..kernels import ops  # deferred: pallas is heavy

                scale, bias = quant.channel_params(int(arr.shape[-1]))
                out = ops.dequant_rows(
                    jax.device_put(arr, device),
                    jax.device_put(scale, device), jax.device_put(bias, device),
                    out_dtype=np.dtype(quant.orig_dtype), interpret=interpret,
                )
                st.dequant_leaves += 1
            else:
                if quant is not None:  # 0-d quantized: host decode
                    arr = quant.dequantize(arr)
                    st.dequant_leaves += 1
                out = jax.device_put(arr, sh if sh is not None else device)
            jax.block_until_ready(out)
            st.h2d_s += time.perf_counter() - t0
            st.h2d_bytes += int(arr.nbytes)
            moved.append(out)
        outs.append(jax.tree_util.tree_unflatten(treedef, moved))

    st.restore_s = time.perf_counter() - t_all
    params = outs[0]
    opt = outs[1] if opt_like is not None else None
    return params, opt, manifest.get("extra", {})
