"""Checkpoints as RawArray tensor stores."""

from .coldstart import (
    ColdStartStats,
    default_inflight_bytes,
    restore_naive,
    restore_pipelined,
    shardings_from_specs,
)
from .store import (
    CheckpointManager,
    load_checkpoint,
    restore_resharded,
    save_checkpoint,
)

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "restore_resharded",
    "restore_pipelined",
    "restore_naive",
    "ColdStartStats",
    "default_inflight_bytes",
    "shardings_from_specs",
    "CheckpointManager",
]
