"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8 per assignment
sheet; real K2 uses MLA — we follow the sheet, deviation noted in DESIGN.md)
d_ff=2048(expert) vocab=163840; 1 shared + 384 routed top-8.
[arXiv:2501.kimi2; unverified]"""

from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,            # 7168 / 64
    d_ff=18432,              # dense-prefix hidden
    vocab=163840,
    max_seq=131072,
    attn_type="gqa",
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1, d_ff_expert=2048,
                  capacity_factor=1.25, router="sigmoid", dispatch_chunks=8, first_dense=1),
    norm="rmsnorm",
    mlp_act="silu",
    mlp_gated=True,
    rope_theta=50_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    attn_chunk=128,          # bound f32 score transients (128H x S)
    remat=True,
    opt_moment_dtype="int8",
)
