"""olmo-1b [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm, SwiGLU, no biases. [arXiv:2402.00838; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    max_seq=4096,
    norm="layernorm_np",     # OLMo's non-parametric LN
    mlp_act="silu",
    mlp_gated=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
)
