"""whisper-medium [audio]: enc-dec 24+24L d_model=1024 16H d_ff=4096
vocab=51865. Conv frontend is a STUB (input_specs provides precomputed
frame embeddings). [arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,             # decoder layers
    n_enc_layers=24,
    enc_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    max_seq=32768,           # shape-exercise decoder cache (real max is 448)
    norm="layernorm",
    mlp_act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
)
