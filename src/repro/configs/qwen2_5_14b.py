"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias. 40 heads is the deliberately TP-awkward case
(not divisible by model=16). [hf:Qwen/Qwen2.5-0.5B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_pad=8,              # zero-padded to 48 heads: EXACT no-op numerically,
                             # 105x less prefill collective traffic (EXPERIMENTS §Perf)
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    max_seq=131072,
    qkv_bias=True,
    norm="rmsnorm",
    mlp_act="silu",
    mlp_gated=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
)
