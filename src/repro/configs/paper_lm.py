"""paper_lm: the end-to-end example model — a small dense LM whose training
run demonstrates the paper's contribution (the RawArray data pipeline +
checkpoint plane) on CPU. ~5M params (d=256, 4L) trains a few
hundred steps in minutes on this 1-core CPU container (~2.4 s/step at 75
GFLOP/s); scale n_layers/d_model up for the ~100M variant on real hardware."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper_lm",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=1024,
    vocab=4096,
    max_seq=256,
    norm="rmsnorm",
    mlp_act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
