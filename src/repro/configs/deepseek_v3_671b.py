"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280; MLA (q_lora 1536, kv_lora 512, rope 64); 1 shared + 256
routed experts top-8, sigmoid router; 3 dense prefix layers; MTP depth-1.
[arXiv:2412.19437; hf]"""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,              # dense-prefix layer hidden
    vocab=129280,
    max_seq=131072,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, d_ff_expert=2048,
                  capacity_factor=1.25, router="sigmoid", dispatch_chunks=8, first_dense=3),
    mtp=True,
    norm="rmsnorm",
    mlp_act="silu",
    mlp_gated=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    attn_chunk=128,          # bound f32 score transients (128H x S)
    remat=True,
    opt_moment_dtype="int8",  # 8-bit Adam moments to fit 16GiB/chip HBM
)
