"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local:global attention (sliding window 1024), head_dim 256 (explicit),
QK-norm, sandwich norms, RoPE theta 10k local / 1M global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    max_seq=131072,
    sliding_window=1024,
    global_every=6,          # 5 local : 1 global
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    sandwich_norm=True,
    norm="rmsnorm",
    mlp_act="gelu",
    mlp_gated=True,          # GeGLU
    embed_scale=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
)
