"""mamba2-780m [ssm]: 48L d_model=1536, attn-free, vocab=50280,
ssm_state=128, headdim 64 (d_inner 3072 => 48 SSD heads), SSD chunked scan.
[arXiv:2405.21060; unverified]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab=50280,
    max_seq=1048576,
    attn_type="none",
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=128),
    norm="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
)
