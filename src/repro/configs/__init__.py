"""Assigned-architecture configs (exact values from the assignment sheet)
plus the paper-scale LM used by the end-to-end example.

Each ``<id>.py`` exports ``CONFIG``; the registry maps ``--arch <id>``.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "gemma3_12b",
    "olmo_1b",
    "internlm2_1_8b",
    "qwen2_5_14b",
    "llava_next_mistral_7b",
    "deepseek_v3_671b",
    "kimi_k2_1t",
    "whisper_medium",
    "mamba2_780m",
    "zamba2_1_2b",
    "paper_lm",
]

# assignment-sheet id -> module id
ALIASES: Dict[str, str] = {
    "gemma3-12b": "gemma3_12b",
    "olmo-1b": "olmo_1b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "whisper-medium": "whisper_medium",
    "mamba2-780m": "mamba2_780m",
    "zamba2-1.2b": "zamba2_1_2b",
}


def get_config(arch: str) -> ModelConfig:
    mod_id = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if mod_id not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch}'; known: {sorted(ALIASES) + ['paper_lm']}")
    mod = importlib.import_module(f"repro.configs.{mod_id}")
    return mod.CONFIG


def all_arch_ids(include_paper: bool = False) -> List[str]:
    ids = [a for a in ARCH_IDS if a != "paper_lm"]
    return ids + (["paper_lm"] if include_paper else [])
