"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d_model=2048 (ssm_state=64) + ONE
shared attention(+MLP) block (32H MHA kv=32, d_ff=8192) invoked every 6
layers over concat([x, x0]). vocab=32000. [arXiv:2411.15242; hf]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,            # attends over 2*d_model=4096 => 4096/32
    d_ff=8192,
    vocab=32000,
    max_seq=1048576,
    hybrid_attn_every=6,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=128),
    norm="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
)
