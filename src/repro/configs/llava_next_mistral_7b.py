"""llava-next-mistral-7b [vlm]: Mistral-7B backbone (32L d=4096 32H GQA kv=8
d_ff=14336 vocab=32000, SWA 4096) + anyres vision frontend STUB: input_specs
provides precomputed patch embeddings (B, n_patches, d).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    max_seq=32768,
    sliding_window=4096,
    global_every=0,          # all layers sliding-window (mistral)
    norm="rmsnorm",
    mlp_act="silu",
    mlp_gated=True,
    rope_theta=10_000.0,
    n_patches=1152,          # anyres: 2 tiles x 576 patches (stubbed)
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
)
