"""Mamba2 SSD chunk-scan kernel.

Per (batch, head) the chunked dual form is three MXU matmuls per chunk plus
an O(P·N) state update; the (P, N) state lives in VMEM scratch carried over
the sequential chunk grid dimension. Shapes per instance (Q = chunk):

    x     (Q, P)   input (already dt-scaled)
    dtA   (Q, 1)   per-step log decay (column vector for 2D iota friendliness)
    B, C  (Q, N)   input/output projections (n_groups=1: shared over heads)
    y     (Q, P)

    L     (Q, Q)   intra-chunk decay mask  exp(Acs_i - Acs_j) · (j<=i)
    y_diag = ((C Bᵀ) ⊙ L) x
    y_off  = (C ⊙ exp(Acs)) · state_in
    state  = state_in · exp(Acs_Q) + (B ⊙ decay)ᵀ x
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x exposes this as TPUCompilerParams; newer jax as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, dta_ref, b_ref, c_ref, y_ref, state_ref, *, n_chunks, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dta = dta_ref[0, 0].astype(jnp.float32)  # (Q, 1)
    Bm = b_ref[0, 0].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)     # (Q, N)

    acs = jnp.cumsum(dta[:, 0])[:, None]     # (Q, 1) inclusive cumsum
    # intra-chunk decay matrix
    diff = acs - acs.T                        # (Q, Q): Acs_i - Acs_j
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    L = jnp.exp(jnp.where(tri, diff, -1e9))  # mask pre-exp (no inf)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (Q, Q)
    y = jnp.dot((scores * L).astype(x.dtype), x, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    in_decay = jnp.exp(acs)                  # (Q, 1)
    state = state_ref[...]                    # (P, N)
    y += (jnp.dot(Cm, state.T, preferred_element_type=jnp.float32)) * in_decay

    # state update
    last = acs[chunk - 1, 0]
    decay_states = jnp.exp(last - acs)       # (Q, 1)
    state_ref[...] = state * jnp.exp(last) + jnp.dot(
        (x * decay_states).T, Bm, preferred_element_type=jnp.float32
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan_fwd(
    x: jax.Array,    # (B, H, L, P) dt-scaled inputs
    dtA: jax.Array,  # (B, H, L)
    Bm: jax.Array,   # (B, L, N)
    Cm: jax.Array,   # (B, L, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, L, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, f"L={L} % chunk={chunk}"
    n_chunks = L // chunk
    dtA2 = dtA[..., None]  # (B, H, L, 1)
    Bm4 = Bm[:, None]      # (B, 1, L, N)
    Cm4 = Cm[:, None]

    kernel = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, 0, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, 0, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dtA2, Bm4, Cm4)
