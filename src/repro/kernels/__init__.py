"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three artifacts (per the kernel contract):

* ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
  VMEM tiling (TPU is the TARGET; validated on CPU via interpret=True);
* ``ops.py``    — jit'd public wrappers (interpret switch, shape plumbing);
* ``ref.py``    — pure-jnp oracles the tests assert against.

TPU adaptation notes (DESIGN.md §6): all tiles are (8,128)-aligned for the
VPU/MXU; flash attention keeps the online-softmax state in VMEM scratch
carried across the sequential KV-block grid dimension; the SSD kernel maps
Mamba2's chunked dual form onto per-(batch, head) MXU matmuls with the
(P, N) state carried in scratch across the chunk grid dimension.
"""

from .ops import (
    decode_attention,
    dequant_u8,
    flash_attention,
    ssd_scan,
)

__all__ = ["flash_attention", "decode_attention", "ssd_scan", "dequant_u8"]
