"""Flash-attention forward kernel (causal / sliding-window, GQA-aware).

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks); the KV-block dimension is
minor-most ("arbitrary" semantics ⇒ sequential on TPU), so the online-
softmax state (m, l, acc) lives in VMEM scratch carried across KV blocks.
Block shapes: q (Bq, hd), k/v (Bk, hd) — hd padded to a multiple of 128 by
the wrapper, Bq/Bk default 128 ⇒ MXU-shaped (128, hd)x(hd, 128) matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x exposes this as TPUCompilerParams; newer jax as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, window, block_q, block_k, n_kv_blocks, seq_q, seq_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (Bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (Bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)  # (Bk, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (Bq, Bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = kpos < seq_k
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]  # (Bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    scale = scale if scale is not None else hd**-0.5
    n_q = pl.cdiv(Sq, block_q)
    n_k = pl.cdiv(Sk, block_k)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_k,
        seq_q=Sq,
        seq_k=Sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
