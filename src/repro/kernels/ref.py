"""Pure-jnp oracles for every kernel (independent implementations — no
shared code with the kernels, so tests catch transcription bugs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q (B,H,Sq,hd), k/v (B,KV,Sk,hd) -> (B,H,Sq,hd). Materializes SxS."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else hd**-0.5
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, pos, *, window=0, scale=None):
    """q (B,KV,g,hd), k/v (B,KV,S,hd), pos scalar -> (B,KV,g,hd)."""
    B, KV, g, hd = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else hd**-0.5
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    kpos = jnp.arange(S)
    ok = kpos <= pos
    if window > 0:
        ok &= kpos > pos - window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dtA, Bm, Cm):
    """Exact sequential recurrence (no chunking — the ground truth).

    x (B,H,L,P), dtA (B,H,L), Bm/Cm (B,L,N) -> y (B,H,L,P)
        h_t = exp(dtA_t) h_{t-1} + B_t ⊗ x_t ;  y_t = C_t · h_t
    """
    B, H, L, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, at, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        h = h * jnp.exp(at.astype(jnp.float32))[..., None, None] + (
            xt.astype(jnp.float32)[..., None] * bt.astype(jnp.float32)[:, None, None, :]
        )
        y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(jnp.float32))
        return h, y

    xs = (
        jnp.moveaxis(x, 2, 0),
        jnp.moveaxis(dtA, 2, 0),
        jnp.moveaxis(Bm, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)


def dequant_u8_ref(x, scale, bias, out_dtype=jnp.float32):
    return (x.astype(jnp.float32) * scale[None, :] + bias[None, :]).astype(out_dtype)
