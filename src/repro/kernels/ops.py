"""jit'd public wrappers for the Pallas kernels.

``interpret=None`` (default) auto-selects: real Mosaic lowering on TPU,
interpret mode elsewhere (this container is CPU-only — TPU is the target,
interpret mode is the validation vehicle).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_fwd
from .dequant_u8 import dequant_u8_fwd
from .flash_attention import flash_attention_fwd
from .ssd_scan import ssd_scan_fwd


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    block_q: int = 128, block_k: int = 128, interpret: Optional[bool] = None,
):
    """q (B,H,Sq,hd), k/v (B,KV,Sk,hd) -> (B,H,Sq,hd). GQA via KV broadcast."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        block_q=min(block_q, q.shape[2]), block_k=min(block_k, k.shape[2]),
        interpret=_auto_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def decode_attention(
    q, k, v, pos, *, window: int = 0, block_s: int = 512, interpret: Optional[bool] = None
):
    """q (B,H,hd) with H = KV*group, k/v (B,KV,S,hd) -> (B,H,hd)."""
    B, H, hd = q.shape
    KV = k.shape[1]
    g = H // KV
    out = decode_attention_fwd(
        q.reshape(B, KV, g, hd), k, v, pos,
        window=window, block_s=min(block_s, k.shape[2]),
        interpret=_auto_interpret(interpret),
    )
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dtA, Bm, Cm, *, chunk: int = 128, interpret: Optional[bool] = None):
    """x (B,H,L,P), dtA (B,H,L), Bm/Cm (B,L,N) -> y (B,H,L,P)."""
    return ssd_scan_fwd(
        x, dtA, Bm, Cm, chunk=min(chunk, x.shape[2]), interpret=_auto_interpret(interpret)
    )


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_rows", "interpret"))
def dequant_u8(x, scale, bias, *, out_dtype=jnp.float32, block_rows: int = 256, interpret: Optional[bool] = None):
    """x (..., C) uint8 -> (..., C) float, fused (x*scale + bias)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = dequant_u8_fwd(
        x2, scale, bias, out_dtype=out_dtype,
        block_rows=min(block_rows, x2.shape[0]), interpret=_auto_interpret(interpret)
    )
    return out.reshape(shape)


def dequant_rows(x, scale, bias, *, out_dtype=jnp.float32, block_rows: Optional[int] = None, interpret: Optional[bool] = None):
    """``dequant_u8`` with an auto-sized grid: when ``block_rows`` is None
    the row blocks are sized so the grid has ~8 tiles — fewer, larger tiles
    amortize per-block overhead (interpret mode especially). Shared by the
    device feed plane and the cold-start restore engine so both pick
    identical kernel variants (one jit cache entry per shape family)."""
    rows = 1
    for d in x.shape[:-1]:
        rows *= int(d)
    br = block_rows or max(256, -(-max(rows, 1) // 8))
    return dequant_u8(x, scale, bias, out_dtype=out_dtype, block_rows=br, interpret=interpret)
