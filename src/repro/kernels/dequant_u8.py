"""Fused u8 -> float dequantize + normalize kernel (the image ingest path).

The paper's data plane delivers raw uint8 pixels by mmap; the first on-chip
op is dequantization + normalization ((x*scale + bias), e.g. scale=1/255).
Fusing them keeps the u8 bytes as the only HBM read (4x less traffic than
convert-then-normalize materializing f32 in between).

Grid: row blocks of a flattened (rows, C) view; (block, C) tiles in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x exposes this as TPUCompilerParams; newer jax as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(x_ref, scale_ref, bias_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)  # (1, C) broadcast over rows
    bias = bias_ref[...].astype(jnp.float32)
    o_ref[...] = (x * scale + bias).astype(o_ref.dtype)


def dequant_u8_fwd(
    x: jax.Array,      # (rows, C) uint8
    scale: jax.Array,  # (C,) f32 — per-channel scale
    bias: jax.Array,   # (C,) f32
    *,
    out_dtype=jnp.float32,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, C = x.shape
    n = pl.cdiv(rows, block_rows)
    return pl.pallas_call(
        _kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, C), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, scale[None, :], bias[None, :])
