"""GQA decode attention kernel (flash-decoding style).

One query token per sequence attends a long KV cache — purely memory-bound
on TPU (roofline: cache bytes / HBM bw). Grid: (batch, kv_heads,
n_s_blocks); the S-block dimension is sequential, with online-softmax state
(m, l, acc) for the whole q-head *group* in VMEM scratch. Masking uses the
scalar-prefetched current position so cache slots beyond ``pos`` are dead.

q is reshaped to (B, KV, group, hd) by the wrapper; output (B, KV, group, hd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x exposes this as TPUCompilerParams; newer jax as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, block_s, n_s_blocks, window):
    si = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (g, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (Bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (g, Bs)
    kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = kpos <= pos
    if window > 0:
        ok &= kpos > pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(si == n_s_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jax.Array,    # (B, KV, group, hd)
    k: jax.Array,    # (B, KV, S, hd)
    v: jax.Array,
    pos: jax.Array,  # scalar int32: positions <= pos are live
    *,
    window: int = 0,
    scale: float | None = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, KV, g, hd = q.shape
    S = k.shape[2]
    scale = scale if scale is not None else hd**-0.5
    n_s = pl.cdiv(S, block_s)

    kernel = functools.partial(
        _kernel, scale=scale, block_s=block_s, n_s_blocks=n_s, window=window
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, si, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, si, *_: (b, h, si, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, si, *_: (b, h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, si, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), q, k, v)
