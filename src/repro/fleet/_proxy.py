"""Shared HTTP plumbing for the fleet tier (DESIGN.md §14).

Small pieces both fleet servers need and ``http.server`` does not provide:
a JSON/problem-response mixin for handlers, single-range parsing with the
same semantics as the origin server, a per-thread keep-alive connection
cache (a ``ThreadingHTTPServer`` dedicates one thread to one downstream
connection, so thread-local upstream connections give 1:1 keep-alive
chains through the proxy with zero locking), and a bounded reader that
lets a request body stream upstream without buffering it in RAM.
"""

from __future__ import annotations

import http.client
import threading
import time
from typing import Dict, Optional, Tuple
from urllib.parse import urlsplit

from ..remote.client import breaker_for, default_timeout

_COPY_CHUNK = 1 << 20

# request headers a proxy hop forwards verbatim; everything else is
# hop-by-hop or regenerated
FORWARD_HEADERS = ("Range", "If-None-Match", "Authorization", "X-RA-Upload",
                   "X-RA-Offset", "Content-Length")
# response headers relayed back to the client; Content-Length is handled
# separately because the relay must guarantee it matches the body it sends
RELAY_HEADERS = ("ETag", "Content-Range", "Content-Type", "Accept-Ranges")


def parse_range(spec: Optional[str], size: int) -> Optional[Tuple[int, int]]:
    """Single-range ``Range`` header → ``(start, stop)``; ``None`` means the
    whole entity; raises ``ValueError`` for a syntactically valid but
    unsatisfiable range (→ 416). Same semantics as the origin server's
    parser, so byte behavior through the fleet is identical to direct."""
    if not spec or not spec.startswith("bytes="):
        return None
    spec = spec[len("bytes="):]
    if "," in spec:
        return None
    a, _, b = spec.partition("-")
    if a == "":
        n = int(b)
        if n <= 0:
            raise ValueError("empty suffix range")
        return max(0, size - n), size
    start = int(a)
    stop = int(b) + 1 if b else size
    if start >= size or stop <= start:
        raise ValueError(f"range [{start}, {stop}) outside entity of {size}")
    return start, min(stop, size)


class JsonResponderMixin:
    """``_send_json`` / ``_fail`` for ``BaseHTTPRequestHandler`` subclasses,
    mirroring the origin server's responses (Content-Length always set, so
    keep-alive survives every status)."""

    def _send_json(self, obj, status: int = 200, etag: Optional[str] = None) -> None:
        import json

        body = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        if etag is not None:
            self.send_header("ETag", etag)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        try:
            self.wfile.write(body)
        except OSError:
            pass

    def _fail(self, status: int, msg: str) -> None:
        body = (msg + "\n").encode()
        self.send_response(status)
        self.send_header("Content-Type", "text/plain")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        try:
            self.wfile.write(body)
        except OSError:
            pass


class _BoundedReader:
    """File-like over exactly ``length`` bytes of ``raw`` — what lets a PUT
    body stream through the proxy hop without ever reading past the request
    (the client connection is keep-alive; overreading would eat the next
    request line)."""

    def __init__(self, raw, length: int):
        self._raw = raw
        self._left = int(length)

    def read(self, n: int = -1) -> bytes:
        if self._left <= 0:
            return b""
        want = self._left if n is None or n < 0 else min(n, self._left)
        data = self._raw.read(min(want, _COPY_CHUNK))
        self._left -= len(data)
        return data


_tls = threading.local()


def conn_for(base_url: str, timeout: Optional[float] = None) -> http.client.HTTPConnection:
    """Thread-local keep-alive connection to ``base_url``. One proxy handler
    thread serves one downstream connection for its whole life, so caching
    upstream connections per (thread, base) turns an N-request client
    session into N requests over ONE upstream socket — no locks, no pool."""
    conns: Dict[str, http.client.HTTPConnection] = getattr(_tls, "conns", None)
    if conns is None:
        conns = _tls.conns = {}
    c = conns.get(base_url)
    if c is None:
        parts = urlsplit(base_url)
        cls = (http.client.HTTPSConnection if parts.scheme == "https"
               else http.client.HTTPConnection)
        c = cls(parts.hostname or "", parts.port,
                timeout=default_timeout() if timeout is None else timeout)
        conns[base_url] = c
    return c


def drop_conn(base_url: str) -> None:
    """Close and forget this thread's cached connection to ``base_url``
    (after any transport error — the socket state is unknown)."""
    conns = getattr(_tls, "conns", None)
    if conns is None:
        return
    c = conns.pop(base_url, None)
    if c is not None:
        try:
            c.close()
        except Exception:
            pass


def upstream_request(
    base_url: str,
    method: str,
    path_qs: str,
    headers: Dict[str, str],
    body=None,
    *,
    timeout: Optional[float] = None,
):
    """One request on this thread's keep-alive connection to ``base_url``;
    returns the live ``HTTPResponse`` (caller must fully read it before the
    next call on this thread). Transport errors close/forget the connection
    and re-raise; the per-host circuit breaker is consulted first, so a
    dead replica fails in microseconds (DESIGN.md §14)."""
    parts = urlsplit(base_url)
    brk = breaker_for(parts.hostname or "", parts.port)
    brk.check(base_url)
    conn = conn_for(base_url, timeout)
    try:
        conn.request(method, path_qs, body=body, headers=headers)
        resp = conn.getresponse()
        brk.record_success()
        return resp
    except ConnectionRefusedError:
        drop_conn(base_url)
        brk.record_refusal()
        raise
    except (OSError, http.client.HTTPException):
        drop_conn(base_url)
        raise


def monotonic() -> float:
    return time.monotonic()
