"""Async load-generation harness for the fleet tier (DESIGN.md §14).

Replays dataset-shaped read traces — ``gather`` (random block-aligned
ranges, the shuffled-training access pattern), ``rows`` (sequential
spans, sequential epochs), ``coldstart`` (whole objects largest-first,
the checkpoint-restore pattern) — from hundreds of concurrent clients
against any server speaking the RawArray byte-range dialect (origin,
edge, or router). Each client is one asyncio task holding one keep-alive
HTTP/1.1 connection, so a 300-client run costs 300 sockets and zero
threads; per-request latencies aggregate into p50/p99 milliseconds and
aggregate GB/s. ``benchmarks/bench_fleet.py`` drives this to produce
``BENCH_FLEET.json``; the CLI replays a trace against a live URL:
``python -m repro.fleet.loadgen http://router:8100 --mode gather``.
"""

from __future__ import annotations

import asyncio
import random
from typing import Dict, List, Optional, Sequence, Tuple
from urllib.parse import urlsplit

from ..core.spec import RawArrayError

# one trace entry: (url path, byte offset, byte length)
Request = Tuple[str, int, int]

_MAX_LINE = 1 << 16


# -- trace builders --------------------------------------------------------

def trace_gather(files: Sequence[Tuple[str, int]], *, req_bytes: int,
                 requests: int, seed: int = 0) -> List[Request]:
    """Random ``req_bytes``-aligned ranges across ``files`` — the shuffled
    gather pattern. ``files`` is ``[(path, size), ...]``."""
    rng = random.Random(seed)
    out: List[Request] = []
    usable = [(p, s) for p, s in files if s > 0]
    if not usable:
        raise RawArrayError("trace_gather: no non-empty files")
    for _ in range(requests):
        path, size = usable[rng.randrange(len(usable))]
        blocks = max(1, (size + req_bytes - 1) // req_bytes)
        off = rng.randrange(blocks) * req_bytes
        out.append((path, min(off, size - 1), min(req_bytes, size - min(off, size - 1))))
    return out


def trace_rows(files: Sequence[Tuple[str, int]], *, req_bytes: int,
               requests: int) -> List[Request]:
    """Sequential spans round-robined across files — the epoch-scan
    pattern. Wraps around each file as needed."""
    usable = [(p, s) for p, s in files if s > 0]
    if not usable:
        raise RawArrayError("trace_rows: no non-empty files")
    cursors = [0] * len(usable)
    out: List[Request] = []
    for i in range(requests):
        j = i % len(usable)
        path, size = usable[j]
        off = cursors[j] % size
        ln = min(req_bytes, size - off)
        out.append((path, off, ln))
        cursors[j] = (off + ln) % size
    return out


def trace_coldstart(files: Sequence[Tuple[str, int]], *,
                    req_bytes: int) -> List[Request]:
    """Every byte of every file, largest object first, chunked into
    ``req_bytes`` ranges — the checkpoint-restore pattern."""
    out: List[Request] = []
    for path, size in sorted(files, key=lambda fs: -fs[1]):
        for off in range(0, size, req_bytes):
            out.append((path, off, min(req_bytes, size - off)))
    return out


def files_from_stat(base_url: str, *, suffix: Optional[str] = None
                    ) -> List[Tuple[str, int]]:
    """File list for the trace builders from a live server's ``/stat/``
    directory listing (works through the router — ``/stat/`` routes by the
    underlying entity path)."""
    from ..remote.client import stat_dir

    entries = stat_dir(base_url.rstrip("/") + "/")
    out = [("/" + name, int(size)) for name, (size, _etag) in sorted(entries.items())
           if suffix is None or name.endswith(suffix)]
    if not out:
        raise RawArrayError(f"no files listed by {base_url}/stat/")
    return out


def build_trace(mode: str, files: Sequence[Tuple[str, int]], *, req_bytes: int,
                requests: int, seed: int = 0) -> List[Request]:
    if mode == "gather":
        return trace_gather(files, req_bytes=req_bytes, requests=requests, seed=seed)
    if mode == "rows":
        return trace_rows(files, req_bytes=req_bytes, requests=requests)
    if mode == "coldstart":
        return trace_coldstart(files, req_bytes=req_bytes)
    raise RawArrayError(f"unknown trace mode {mode!r} "
                        "(expected gather | rows | coldstart)")


# -- the async client -----------------------------------------------------

async def _read_response(reader: asyncio.StreamReader) -> Tuple[int, int]:
    """Parse one HTTP/1.1 response, drain the body, return
    ``(status, body_bytes)``. Assumes Content-Length framing (every server
    in this repo sets it on every status)."""
    status_line = await reader.readuntil(b"\r\n")
    status = int(status_line.split(b" ", 2)[1])
    clen = 0
    while True:
        line = await reader.readuntil(b"\r\n")
        if line == b"\r\n":
            break
        if len(line) > _MAX_LINE:
            raise RawArrayError("oversized response header")
        name, _, value = line.decode("latin-1").partition(":")
        if name.strip().lower() == "content-length":
            clen = int(value.strip())
    left = clen
    while left > 0:
        chunk = await reader.read(min(left, 1 << 20))
        if not chunk:
            raise RawArrayError("server closed mid-body")
        left -= len(chunk)
    return status, clen


async def _client(host: str, port: int, requests: Sequence[Request],
                  latencies: List[float], loop) -> Tuple[int, int]:
    """One keep-alive connection replaying its slice of the trace; returns
    ``(bytes_received, errors)``. One reconnect attempt per request."""
    reader = writer = None
    got = 0
    errors = 0

    async def connect():
        nonlocal reader, writer
        reader, writer = await asyncio.open_connection(host, port)

    for path, off, ln in requests:
        req = (f"GET {path} HTTP/1.1\r\nHost: {host}:{port}\r\n"
               f"Range: bytes={off}-{off + ln - 1}\r\n\r\n").encode()
        t0 = loop.time()
        for attempt in (0, 1):
            try:
                if writer is None:
                    await connect()
                writer.write(req)
                await writer.drain()
                status, nbytes = await _read_response(reader)
                break
            except (OSError, asyncio.IncompleteReadError, RawArrayError):
                if writer is not None:
                    writer.close()
                    reader = writer = None
                if attempt:
                    status, nbytes = 0, 0
        latencies.append(loop.time() - t0)
        if status in (200, 206):
            got += nbytes
        else:
            errors += 1
    if writer is not None:
        writer.close()
    return got, errors


async def _run_async(base_url: str, trace: Sequence[Request], clients: int
                     ) -> Dict[str, float]:
    parts = urlsplit(base_url)
    host, port = parts.hostname or "127.0.0.1", parts.port or 80
    loop = asyncio.get_running_loop()
    latencies: List[float] = []
    # interleave so every client mixes paths/offsets instead of one client
    # owning one file — that is what makes a herd a herd
    slices: List[List[Request]] = [list(trace[i::clients]) for i in range(clients)]
    t0 = loop.time()
    results = await asyncio.gather(
        *(_client(host, port, s, latencies, loop) for s in slices if s))
    elapsed = max(loop.time() - t0, 1e-9)
    total = sum(g for g, _ in results)
    errors = sum(e for _, e in results)
    latencies.sort()
    return {
        "clients": float(clients),
        "requests": float(len(latencies)),
        "errors": float(errors),
        "bytes": float(total),
        "seconds": elapsed,
        "gbps": total / elapsed / 1e9,
        "p50_ms": percentile(latencies, 0.50) * 1e3,
        "p99_ms": percentile(latencies, 0.99) * 1e3,
    }


def percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an ascending-sorted sequence (0 for an
    empty one — loadgen reports, it does not crash)."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return sorted_vals[i]


def run(base_url: str, trace: Sequence[Request], *, clients: int = 64
        ) -> Dict[str, float]:
    """Replay ``trace`` against ``base_url`` from ``clients`` concurrent
    keep-alive connections; returns the latency/throughput report dict
    (keys: requests, errors, bytes, seconds, gbps, p50_ms, p99_ms)."""
    if not trace:
        raise RawArrayError("empty trace")
    clients = max(1, min(clients, len(trace)))
    return asyncio.run(_run_async(base_url, trace, clients))


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet.loadgen",
        description="Replay a dataset-shaped read trace against a RawArray "
                    "origin, edge, or router URL.")
    ap.add_argument("url")
    ap.add_argument("--mode", choices=("gather", "rows", "coldstart"),
                    default="gather")
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--req-bytes", type=int, default=1 << 18)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--suffix", default=None,
                    help="only replay files with this suffix (e.g. .ra)")
    ap.add_argument("--json", default=None, help="also write the report here")
    args = ap.parse_args(argv)

    files = files_from_stat(args.url, suffix=args.suffix)
    trace = build_trace(args.mode, files, req_bytes=args.req_bytes,
                        requests=args.requests, seed=args.seed)
    report = run(args.url, trace, clients=args.clients)
    report["mode"] = args.mode
    print(f"{args.mode}: {int(report['requests'])} reqs, "
          f"{int(report['errors'])} errors, "
          f"{report['bytes'] / 1e6:.1f} MB in {report['seconds']:.2f}s "
          f"({report['gbps']:.3f} GB/s), "
          f"p50 {report['p50_ms']:.1f} ms, p99 {report['p99_ms']:.1f} ms")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    return 1 if report["errors"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
